"""Task-centric sparse-quantized GEMV — Pallas TPU kernel (paper §3.5).

GPU original: Stream-K work-centric decomposition over CTAs, gathering
surviving INT4 groups and their activation slices. TPU adaptation (see
DESIGN.md §2): the grid is a *1-D flattened work list* of equal-size
(row-block, group-chunk) items built offline at pack time. Scalar-prefetched
work arrays drive every BlockSpec index map, so each sequential grid step
DMAs exactly one [BN, BM] tile of BSR payload — equal work per step means a
bubble-free software pipeline, which is the TPU analogue of Stream-K's SM
load balancing. Output tiles are revisited by consecutive items of the same
row block and accumulated in VMEM (`first` flag zero-initializes).

Layouts (padded BSR, see core/bsr.py):
    x      [B, K]          activations (B <= 8 per chip in decode)
    idx    [N, M]  int32   kept group columns (sorted; -1 pad)
    vals   [N, M, G/2] u8  packed INT4 codes
    scale  [N, M]  f32     0 on padding => padded slots contribute nothing
    zero   [N, M]  f32
    y      [B, N]
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BLOCK_N = 128   # output rows per tile (lane dim)
DEFAULT_BLOCK_M = 8     # group slots per work item


def _kernel(row_block_ref, chunk_ref, first_ref,   # scalar prefetch
            idx_ref, vals_ref, scale_ref, zero_ref, x_ref,  # VMEM in
            y_ref,                                  # VMEM out (revisited)
            *, group_size: int, batch: int):
    w = pl.program_id(0)

    @pl.when(first_ref[w] == 1)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    bn, bm, _ = vals_ref.shape
    g = group_size

    # --- dequantize the INT4 payload tile ---------------------------------
    packed = vals_ref[...]                       # [BN, BM, G/2] uint8
    lo = (packed & 0xF).astype(jnp.float32)
    hi = ((packed >> 4) & 0xF).astype(jnp.float32)
    q = jnp.stack([lo, hi], axis=-1).reshape(bn, bm, g)
    wt = (q - zero_ref[...][..., None]) * scale_ref[...][..., None]

    # --- gather the matching activation groups ----------------------------
    x = x_ref[...]                               # [B, K]
    k = x.shape[-1]
    xg = x.reshape(batch, k // g, g)
    safe = jnp.maximum(idx_ref[...], 0).reshape(-1)          # [BN*BM]
    # NOTE(tpu): 1-D take lowers to Mosaic dynamic-gather; the MXU-friendly
    # fallback is a one-hot [BN*BM, K/G] matmul against xg.
    xt = jnp.take(xg, safe, axis=1)              # [B, BN*BM, G]
    xt = xt.reshape(batch, bn, bm, g)

    # --- multiply-reduce on the VPU (decode is bandwidth-bound; no MXU) ---
    acc = jnp.sum(wt[None, ...] * xt.astype(jnp.float32), axis=(2, 3))
    y_ref[...] += acc.astype(y_ref.dtype)        # [B, BN]


def gqsa_gemv_pallas(
    x: jnp.ndarray,
    idx: jnp.ndarray,
    vals: jnp.ndarray,
    scale: jnp.ndarray,
    zero: jnp.ndarray,
    work: Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray],
    *,
    group_size: int,
    block_n: int = DEFAULT_BLOCK_N,
    block_m: int = DEFAULT_BLOCK_M,
    interpret: bool = False,
) -> jnp.ndarray:
    """Inputs must be pre-padded: N % block_n == 0, M % block_m == 0.

    work = (row_block[W], chunk[W], first[W]) from core.bsr.build_work_list
    (items sorted by row_block so output revisits are consecutive).
    """
    b, k = x.shape
    n, m = idx.shape
    row_block, chunk, first = work
    n_items = row_block.shape[0]
    g = group_size

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_items,),
        in_specs=[
            pl.BlockSpec((block_n, block_m),
                         lambda w, rb, ch, fs: (rb[w], ch[w])),
            pl.BlockSpec((block_n, block_m, g // 2),
                         lambda w, rb, ch, fs: (rb[w], ch[w], 0)),
            pl.BlockSpec((block_n, block_m),
                         lambda w, rb, ch, fs: (rb[w], ch[w])),
            pl.BlockSpec((block_n, block_m),
                         lambda w, rb, ch, fs: (rb[w], ch[w])),
            pl.BlockSpec((b, k), lambda w, rb, ch, fs: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b, block_n),
                               lambda w, rb, ch, fs: (0, rb[w])),
    )
    kernel = functools.partial(_kernel, group_size=g, batch=b)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
    )(row_block, chunk, first, idx, vals, scale, zero, x)
