"""int8-KV decode attention — Pallas TPU kernel.

The decode hot-spot behind EXPERIMENTS.md §Perf cell C: one query token
attends over a 32k int8 KV cache. HBM traffic is the int8 payload (half of
bf16); dequantization happens on 128-wide cache tiles in VMEM; softmax is
the online (max, sum) accumulation across sequential S-blocks of the grid,
carried in VMEM scratch.

Layouts:
    q        [B, KH, R, D]      query heads grouped by their KV head
    k_cache  [B, S, KH, D] i8   (paper-layout cache, no transposes)
    k_scale  [B, S, KH]  f32    per token x head
    v_cache  [B, S, KH, D] i8
    v_scale  [B, S, KH]  f32
    out      [B, KH, R, D] f32

Grid: (B, KH, S/BS) — S innermost so the (m, l, acc) scratch carries the
online softmax across cache blocks of one (batch, kv-head) pair.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BLOCK_S = 512


def _kernel(len_ref,                                # scalar prefetch
            q_ref, k_ref, ks_ref, v_ref, vs_ref,    # VMEM in
            o_ref,                                  # VMEM out
            m_ref, l_ref, acc_ref,                  # scratch
            *, block_s: int):
    si = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # [R, D]
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)

    # dequantize this cache tile in VMEM (HBM traffic stays int8)
    k_i8 = k_ref[0, :, 0, :]                         # [BS, D] int8
    ks = ks_ref[0, :, 0]                             # [BS]
    k = k_i8.astype(jnp.float32) * ks[:, None]
    v_i8 = v_ref[0, :, 0, :]
    vs = vs_ref[0, :, 0]
    v = v_i8.astype(jnp.float32) * vs[:, None]

    sco = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32) * scale
    pos = si * block_s + jnp.arange(block_s)
    valid = pos < len_ref[0]
    sco = jnp.where(valid[None, :], sco, -jnp.inf)   # [R, BS]

    m_prev = m_ref[...]                              # [R, 1]... stored [R, 128]
    m_old = m_prev[:, 0]
    m_new = jnp.maximum(m_old, jnp.max(sco, axis=-1))
    m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
    p = jnp.exp(sco - m_safe[:, None])
    p = jnp.where(jnp.isinf(sco), 0.0, p)
    corr = jnp.exp(jnp.where(jnp.isinf(m_old), -jnp.inf, m_old) - m_safe)
    corr = jnp.where(jnp.isinf(m_old), 0.0, corr)

    l_new = l_ref[:, 0] * corr + jnp.sum(p, axis=-1)
    acc_new = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)
    acc_ref[...] = acc_new

    @pl.when(si == ns - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def kv_decode_attention_pallas(
    q: jnp.ndarray,            # [B, KH, R, D]
    k_cache: jnp.ndarray,      # [B, S, KH, D] int8
    k_scale: jnp.ndarray,      # [B, S, KH] f32
    v_cache: jnp.ndarray,
    v_scale: jnp.ndarray,
    length: jnp.ndarray,       # [] int32 valid prefix
    *,
    block_s: int = DEFAULT_BLOCK_S,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns [B, KH, R, D] f32. S % block_s == 0 (pad in ops wrapper)."""
    b, khn, r, d = q.shape
    s = k_cache.shape[1]
    ns = s // block_s
    grid = (b, khn, ns)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, r, d), lambda bi, ki, si, ln: (bi, ki, 0, 0)),
            pl.BlockSpec((1, block_s, 1, d),
                         lambda bi, ki, si, ln: (bi, si, ki, 0)),
            pl.BlockSpec((1, block_s, 1),
                         lambda bi, ki, si, ln: (bi, si, ki)),
            pl.BlockSpec((1, block_s, 1, d),
                         lambda bi, ki, si, ln: (bi, si, ki, 0)),
            pl.BlockSpec((1, block_s, 1),
                         lambda bi, ki, si, ln: (bi, si, ki)),
        ],
        out_specs=pl.BlockSpec((1, 1, r, d),
                               lambda bi, ki, si, ln: (bi, ki, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((r, 128), jnp.float32),   # running max (lane-padded)
            pltpu.VMEM((r, 128), jnp.float32),   # running denom
            pltpu.VMEM((r, d), jnp.float32),     # unnormalized output
        ],
    )
    length_arr = jnp.reshape(length, (1,)).astype(jnp.int32)
    return pl.pallas_call(
        functools.partial(_kernel, block_s=block_s),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, khn, r, d), jnp.float32),
        interpret=interpret,
    )(length_arr, q, k_cache, k_scale, v_cache, v_scale)
