"""Fused paged-attention decode kernel — Pallas TPU (DESIGN.md §7).

Decode attention computed *in place* on the paged KV pool: no dense
per-step page gather. The engine's previous hot path materialized every
slot's block-table pages into a `[B, MP*ps, KH, D]` copy each layer,
each step — O(B * max_pages) HBM traffic regardless of how long the
sequences actually are. This kernel streams only the *live* pages of
each slot through VMEM, so per-step attention traffic is O(live tokens).

Layouts (one layer's view of the pool):
    q             [B, KH, T*R, D]      query rows grouped by KV head,
                                       T-major inside the row dim
    k/v_pages     [P, ps, KH, D]       bf16/f32, or int8 with
    k/v_scale     [P, ps, KH] f32      per-token x head scales
    lengths       [B, T] int32         per-query valid prefix (staircase:
                                       query t of a slot sees cache
                                       positions < lengths[b, t])
    block_tables  [B, MP] int32        page ids; entries >= P are
                                       out-of-range sentinels
    live          [B] int32            number of live pages per slot
                                       (= ceil(max_t lengths / ps))
    out           [B, KH, T*R, D] f32

Grid: (B, KH, MP) — pages innermost so the (m, l, acc) VMEM scratch
carries the online softmax across one slot/kv-head's page stream.

Three mechanisms kill the dense gather's waste:

* **Scalar-prefetched block tables drive the DMA.** The K/V BlockSpec
  index maps read `block_tables[b, page_idx]` directly, so each grid
  step fetches one *pool page* — the copy to a dense per-slot buffer
  never exists.
* **Dead pages are never fetched.** For grid steps past a slot's live
  page count the index map clamps to the last live page; Pallas elides
  the DMA when consecutive steps map to the same block, and `pl.when`
  skips the compute entirely. Sentinel entries (>= P) clamp to page
  P - 1 — exactly XLA's OOB-gather clip, so the jnp reference and the
  kernel read identical (masked) garbage and stay bit-comparable.
* **int8 pages dequantize on VMEM tiles.** HBM traffic is the int8
  payload (half of bf16); the f32 dequant + contraction happen on the
  in-VMEM tile, mirroring the contiguous int8 decode kernel this module
  absorbed (the former ``kv_decode.py``; see
  :func:`ops.kv_decode_attention` for the degenerate one-page-table
  wrapper). The score/value contractions stay f32-after-dequant — an
  online softmax cannot know the global softmax-weight amax that the
  jnp ``decode_attention_int8`` path uses to re-quantize p, and the
  dequant form is what keeps the folded contiguous parity at 1e-4.

T > 1 covers the speculative-decoding verify step (T = K+1 per-slot
short-prefill): causality inside the block comes from the per-query
staircase ``lengths``, identical to the jnp reference's masking.

Token-TREE verification (DESIGN.md §8) adds an optional ancestor-bitmap
operand: the fed block is a flat BFS token tree written at cache
positions ``base .. base + window - 1``, and query t additionally
requires bit ``s - base`` of ``anc[b, t]`` for cache positions inside
that window — siblings/uncles in the block stay invisible. ``base`` [B]
rides the scalar-prefetch path next to the block tables; ``anc`` [B, T]
is a VMEM row operand like ``lengths``. With ``anc`` absent the compiled
kernel is UNCHANGED (the staircase is the chain special case —
`models/layers.py:ancestor_mask` is the shared mask definition).

MLA LATENT pages (DESIGN.md §9) are the ``v_pages=None`` mode: the pool
holds one [ps, kv_lora_rank + qk_rope_dim] latent row per token (a
single logical KV head), the value operand IS the key page (no V pool —
callers up-project through W_UV after slicing the leading R dims of the
output), and the score contraction is lane-dim tiled: R + rope = 576 at
DeepSeek scale exceeds one 128-lane MXU tile, so the dot runs as a
statically unrolled sum of 128-wide partial products. With ``v_pages``
present and D <= 128 the compiled kernel is UNCHANGED.

Rows (T*R) and D are used as-is — adequate for interpret mode (the
repo's off-TPU convention) and for MXU-friendly head dims; a deployment
at exotic head dims should pad rows to the sublane multiple in
``ops.paged_decode_attention``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(bt_ref, live_ref,                       # scalar prefetch
            len_ref, q_ref, k_ref, v_ref,           # VMEM in (bf16/f32)
            o_ref,                                  # VMEM out
            m_ref, l_ref, acc_ref,                  # scratch
            *, page_size: int, t: int, r: int,
            ks_ref=None, vs_ref=None,
            anc_ref=None, base_ref=None, window: int = 0):
    # ``v_ref is None`` is the LATENT mode (MLA, DESIGN.md §9): the pool
    # holds one latent row per token and the value IS that row (callers
    # slice the leading kv_lora_rank dims of the output) — V = K, one
    # page fetch instead of two.
    bi = pl.program_id(0)
    pi = pl.program_id(2)
    npg = pl.num_programs(2)

    @pl.when(pi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # pages at/above the live count were not (re)fetched — skip compute
    @pl.when(pi < live_ref[bi])
    def _update():
        q = q_ref[0, 0].astype(jnp.float32)          # [TR, D]
        d = q.shape[-1]
        scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)

        # dequantize this page tile in VMEM (HBM traffic stays int8)
        k = k_ref[0, :, 0, :].astype(jnp.float32)    # [ps, D]
        v = k if v_ref is None else v_ref[0, :, 0, :].astype(jnp.float32)
        if ks_ref is not None:
            k = k * ks_ref[0, :, 0][:, None]
            v = v * vs_ref[0, :, 0][:, None]

        def qk_dot(qc, kc):
            return jax.lax.dot_general(
                qc, kc, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)          # [TR, ps]

        if d > 128:
            # lane-dim tiling: the MLA latent head (kv_lora_rank +
            # qk_rope_dim = 576 at DeepSeek scale) exceeds one 128-lane
            # MXU tile, so the contraction runs as a statically unrolled
            # sum of 128-wide partial dots (the trailing ragged chunk is
            # narrower; Mosaic pads it). d <= 128 keeps the single-dot
            # program every pre-existing caller compiled to.
            sco = qk_dot(q[:, :128], k[:, :128])
            for lo in range(128, d, 128):
                sco += qk_dot(q[:, lo:lo + 128], k[:, lo:lo + 128])
            sco *= scale
        else:
            sco = qk_dot(q, k) * scale

        # per-query staircase mask: query t sees positions < lengths[b, t]
        pos = pi * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (t, page_size), 1)
        lq = len_ref[0]                              # [T]
        valid = pos < lq[:, None]                    # [T, ps]
        if anc_ref is not None:
            # token-tree window: positions base..base+window-1 hold the
            # fed BFS block; query t sees only its ancestor bits there
            fed = pos - base_ref[bi]                 # [T, ps]
            in_win = (fed >= 0) & (fed < window)
            bits = (anc_ref[0][:, None] >> jnp.clip(fed, 0, 31)) & 1
            valid &= jnp.logical_not(in_win) | (bits == 1)
        valid = jnp.broadcast_to(valid[:, None, :],
                                 (t, r, page_size)).reshape(t * r, page_size)
        sco = jnp.where(valid, sco, -jnp.inf)

        m_old = m_ref[:, 0]
        m_new = jnp.maximum(m_old, jnp.max(sco, axis=-1))
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p = jnp.exp(sco - m_safe[:, None])
        p = jnp.where(jnp.isinf(sco), 0.0, p)
        corr = jnp.exp(jnp.where(jnp.isinf(m_old), -jnp.inf, m_old) - m_safe)
        corr = jnp.where(jnp.isinf(m_old), 0.0, corr)

        l_new = l_ref[:, 0] * corr + jnp.sum(p, axis=-1)
        acc_new = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)
        acc_ref[...] = acc_new

    @pl.when(pi == npg - 1)
    def _finalize():
        # fully-masked rows (length 0, e.g. row padding) have l == 0 and
        # finalize to exact zeros rather than NaN
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def paged_attention_pallas(
    q: jnp.ndarray,            # [B, KH, T*R, D]
    k_pages: jnp.ndarray,      # [P, ps, KH, D] bf16/f32/int8
    v_pages: jnp.ndarray,
    lengths: jnp.ndarray,      # [B, T] int32 per-query valid prefix
    block_tables: jnp.ndarray,  # [B, MP] int32 (>= P entries = sentinel)
    live_pages: jnp.ndarray,   # [B] int32 live page count per slot
    k_scale_pages=None,        # [P, ps, KH] f32 (int8 pages only)
    v_scale_pages=None,
    *,
    t: int,
    anc=None,                  # [B, T] int32 ancestor bitmaps (tree verify)
    anc_base=None,             # [B] int32 cache position of the tree root
    anc_window: int = 0,       # fed-block width (bits used in anc)
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns [B, KH, T*R, D] f32. See module docstring for semantics.

    ``v_pages=None`` selects the LATENT mode (MLA latent pool, one
    logical KV head): the value operand is the key page itself, so each
    grid step DMAs one pool page instead of two; callers slice the
    leading ``kv_lora_rank`` dims of the output
    (`ops.paged_latent_attention`)."""
    b, khn, tr, d = q.shape
    r = tr // t
    num_pages, page_size = k_pages.shape[0], k_pages.shape[1]
    mp = block_tables.shape[1]
    int8 = k_scale_pages is not None
    tree = anc is not None
    latent = v_pages is None
    if latent and int8:
        raise NotImplementedError("int8 latent pages are a recorded "
                                  "follow-on (ROADMAP)")
    grid = (b, khn, mp)

    # index maps take the scalar-prefetch operands after the grid ids; the
    # tree variant prefetches a third array (the per-slot window base), so
    # trailing prefetch args are absorbed generically
    def page_map(bi, ki, pi, bt, live, *_):
        # steps past the live prefix re-map to the last live page so the
        # block index is unchanged and Pallas elides the DMA; sentinel
        # entries clamp to P - 1 (== XLA's OOB-gather clip)
        pe = jnp.minimum(pi, jnp.maximum(live[bi] - 1, 0))
        return (jnp.minimum(bt[bi, pe], num_pages - 1), 0, ki, 0)

    def scale_map(bi, ki, pi, bt, live, *_):
        pe = jnp.minimum(pi, jnp.maximum(live[bi] - 1, 0))
        return (jnp.minimum(bt[bi, pe], num_pages - 1), 0, ki)

    def row_map(bi, ki, pi, *_):
        return (bi, ki, 0, 0)

    def len_map(bi, ki, pi, *_):
        return (bi, 0)

    prefetch = [block_tables.astype(jnp.int32),
                live_pages.astype(jnp.int32)]
    if tree:
        prefetch.append(anc_base.astype(jnp.int32))
    in_specs = [pl.BlockSpec((1, t), len_map)]
    args = [lengths.astype(jnp.int32)]
    if tree:
        in_specs.append(pl.BlockSpec((1, t), len_map))
        args.append(anc.astype(jnp.int32))
    in_specs += [pl.BlockSpec((1, 1, tr, d), row_map),
                 pl.BlockSpec((1, page_size, 1, d), page_map)]
    args += [q, k_pages]
    if not latent:
        in_specs.append(pl.BlockSpec((1, page_size, 1, d), page_map))
        args.append(v_pages)
    if int8:
        in_specs += [pl.BlockSpec((1, page_size, 1), scale_map),
                     pl.BlockSpec((1, page_size, 1), scale_map)]
        args += [k_scale_pages, v_scale_pages]

    def kern(*refs):
        i = 2 + tree                     # bt, live[, base]
        base_ref = refs[2] if tree else None
        len_ref = refs[i]; i += 1
        anc_ref = None
        if tree:
            anc_ref = refs[i]; i += 1
        q_ref, k_ref = refs[i:i + 2]; i += 2
        v_ref = None
        if not latent:
            v_ref = refs[i]; i += 1
        ks_ref = vs_ref = None
        if int8:
            ks_ref, vs_ref = refs[i:i + 2]; i += 2
        o_ref, m_ref, l_ref, acc_ref = refs[i:i + 4]
        return _kernel(refs[0], refs[1], len_ref, q_ref, k_ref, v_ref,
                       o_ref, m_ref, l_ref, acc_ref,
                       page_size=page_size, t=t, r=r,
                       ks_ref=ks_ref, vs_ref=vs_ref,
                       anc_ref=anc_ref, base_ref=base_ref,
                       window=anc_window)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(prefetch),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, tr, d), row_map),
        scratch_shapes=[
            pltpu.VMEM((tr, 128), jnp.float32),   # running max (lane-padded)
            pltpu.VMEM((tr, 128), jnp.float32),   # running denom
            pltpu.VMEM((tr, d), jnp.float32),     # unnormalized output
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, khn, tr, d), jnp.float32),
        interpret=interpret,
    )(*prefetch, *args)
