"""Public kernel entry points: padding, work-list plumbing, CPU fallback.

``use_pallas`` selects the Pallas kernel (interpret=True off-TPU) vs the
pure-jnp reference (the GSPMD/dry-run path — identical math).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bsr import BSRMatrix, build_work_list
from repro.kernels import ref as kref
from repro.kernels.gqsa_gemv import (gqsa_gemv_pallas, DEFAULT_BLOCK_N,
                                     DEFAULT_BLOCK_M)
from repro.kernels.w4_matmul import (w4_matmul_pallas, DEFAULT_BLOCK_T,
                                     DEFAULT_BLOCK_K)
from repro.kernels.w4_matmul import DEFAULT_BLOCK_N as W4_BLOCK_N


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int, value=0) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# The GEMV kernel keeps ALL of x resident in VMEM per grid step and sizes
# its accumulator tile [B, BN] for decode batches — one sublane tile. The
# engine's speculative verify step flattens [slots, K+1] token rows into
# the batch dim, so B routinely exceeds this; larger batches are chunked
# explicitly rather than silently mis-tiled.
MAX_GEMV_BATCH = 8


def gqsa_gemv(
    x: jnp.ndarray,
    bsr: BSRMatrix,
    *,
    use_pallas: bool = True,
    block_n: int = DEFAULT_BLOCK_N,
    block_m: int = DEFAULT_BLOCK_M,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """y = x @ dense(bsr).T using the task-centric sparse kernel.

    x: [B, K], any B: rows are padded to the sublane multiple and batches
    beyond MAX_GEMV_BATCH are chunked over the kernel (the BSR payload
    pads and the work list build happen once, shared by every chunk).
    Returns [B, N].
    """
    if not use_pallas:
        return kref.gqsa_gemv_ref(x, bsr)
    if interpret is None:
        interpret = not _on_tpu()

    b, k = x.shape
    n, m = bsr.idx.shape

    idx = _pad_to(_pad_to(bsr.idx, 0, block_n, value=-1), 1, block_m, value=-1)
    vals = _pad_to(_pad_to(bsr.vals, 0, block_n), 1, block_m)
    scale = _pad_to(_pad_to(bsr.scale, 0, block_n), 1, block_m)
    zero = _pad_to(_pad_to(bsr.zero, 0, block_n), 1, block_m)
    wl = build_work_list(idx, block_n, block_m)

    def run(xc: jnp.ndarray) -> jnp.ndarray:
        bc = xc.shape[0]
        y = gqsa_gemv_pallas(
            _pad_to(xc, 0, MAX_GEMV_BATCH), idx, vals, scale, zero,
            (wl.row_block, wl.chunk, wl.first),
            group_size=bsr.group_size, block_n=block_n, block_m=block_m,
            interpret=interpret)
        return y[:bc, :n]

    if b <= MAX_GEMV_BATCH:
        return run(x)
    return jnp.concatenate([run(x[i:i + MAX_GEMV_BATCH])
                            for i in range(0, b, MAX_GEMV_BATCH)], axis=0)


def w4_matmul(
    x: jnp.ndarray,
    qw: jnp.ndarray,
    scale: jnp.ndarray,
    zero: jnp.ndarray,
    *,
    group_size: int,
    use_pallas: bool = True,
    block_t: int = DEFAULT_BLOCK_T,
    block_n: int = W4_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """y = x @ deq(qw).T (dense grouped-dequant). x: [T, K] -> [T, N]."""
    if not use_pallas:
        return kref.w4_matmul_ref(x, qw, scale, zero, group_size)
    if interpret is None:
        interpret = not _on_tpu()

    t, k = x.shape
    n = qw.shape[0]
    block_t = min(block_t, max(8, int(np.ceil(t / 8)) * 8))
    block_k = min(block_k, k) if k % group_size == 0 else block_k
    if block_k % group_size != 0 or k % block_k != 0:
        # fall back: single K block (K is a multiple of G by construction)
        block_k = k
    xp = _pad_to(_pad_to(x, 0, block_t), 1, block_k)
    qwp = _pad_to(_pad_to(qw, 0, block_n), 1, block_k // 2)
    sp = _pad_to(_pad_to(scale, 0, block_n), 1, block_k // group_size)
    zp = _pad_to(_pad_to(zero, 0, block_n), 1, block_k // group_size)
    y = w4_matmul_pallas(xp, qwp, sp, zp, group_size=group_size,
                         block_t=block_t, block_n=block_n, block_k=block_k,
                         interpret=interpret)
    return y[:t, :n]


def gemv_bytes_model(bsr: BSRMatrix, batch: int = 1) -> dict:
    """Static byte-traffic model for the roofline (per call, per chip):
    everything the kernel DMAs from HBM once, at *deployed* widths
    (paper/gguf convention: int16 group index, fp16 scale, u8 zero —
    the padded in-memory form above uses wider dev-side types)."""
    n, k = bsr.shape
    m = bsr.idx.shape[1]
    g = bsr.group_size
    payload = n * m * (g * bsr.bits // 8 + 2 + 2 + 1)
    x_bytes = batch * k * 2           # bf16 activations
    y_bytes = batch * n * 4
    flops = 2 * batch * n * m * g
    return dict(weight_bytes=payload, act_bytes=x_bytes + y_bytes,
                total_bytes=payload + x_bytes + y_bytes, flops=flops)


def dense_bytes_model(n: int, k: int, batch: int = 1,
                      bits: int = 16, group_size: int = 0) -> dict:
    """Byte model for dense (fp16 / W4) GEMV for the fig6 comparison."""
    wbytes = n * k * bits // 8
    if group_size:
        wbytes += n * (k // group_size) * 3  # fp16 scale + u8 zero
    x_bytes = batch * k * 2
    y_bytes = batch * n * 4
    return dict(weight_bytes=wbytes, act_bytes=x_bytes + y_bytes,
                total_bytes=wbytes + x_bytes + y_bytes,
                flops=2 * batch * n * k)


def kv_decode_attention(q, k_cache, k_scale, v_cache, v_scale, length, *,
                        use_pallas: bool = True, block_s: int = 512,
                        interpret: Optional[bool] = None):
    """int8-KV decode attention. q: [B, KH, R, D] -> [B, KH, R, D] f32."""
    from repro.kernels.kv_decode import kv_decode_attention_pallas
    if not use_pallas:
        return kref.kv_decode_attention_ref(q, k_cache, k_scale, v_cache,
                                            v_scale, length)
    if interpret is None:
        interpret = not _on_tpu()
    s = k_cache.shape[1]
    block_s = min(block_s, s)
    pad = (-s) % block_s
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_scale = jnp.pad(k_scale, ((0, 0), (0, pad), (0, 0)))
        v_scale = jnp.pad(v_scale, ((0, 0), (0, pad), (0, 0)))
    return kv_decode_attention_pallas(q, k_cache, k_scale, v_cache, v_scale,
                                      length, block_s=block_s,
                                      interpret=interpret)
