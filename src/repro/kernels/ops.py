"""Public kernel entry points: padding, work-list plumbing, CPU fallback.

``use_pallas`` selects the Pallas kernel (interpret=True off-TPU) vs the
pure-jnp reference (the GSPMD/dry-run path — identical math).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bsr import BSRMatrix, build_work_list
from repro.kernels import ref as kref
from repro.kernels.gqsa_gemv import (gqsa_gemv_pallas, DEFAULT_BLOCK_N,
                                     DEFAULT_BLOCK_M)
from repro.kernels.w4_matmul import (w4_matmul_pallas, DEFAULT_BLOCK_T,
                                     DEFAULT_BLOCK_K)
from repro.kernels.w4_matmul import DEFAULT_BLOCK_N as W4_BLOCK_N


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int, value=0) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# The GEMV kernel keeps ALL of x resident in VMEM per grid step and sizes
# its accumulator tile [B, BN] for decode batches — one sublane tile. The
# engine's speculative verify step flattens [slots, K+1] token rows into
# the batch dim, so B routinely exceeds this; larger batches are chunked
# explicitly rather than silently mis-tiled.
MAX_GEMV_BATCH = 8


def gqsa_gemv(
    x: jnp.ndarray,
    bsr: BSRMatrix,
    *,
    use_pallas: bool = True,
    block_n: int = DEFAULT_BLOCK_N,
    block_m: int = DEFAULT_BLOCK_M,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """y = x @ dense(bsr).T using the task-centric sparse kernel.

    x: [B, K], any B: rows are padded to the sublane multiple and batches
    beyond MAX_GEMV_BATCH are chunked over the kernel (the BSR payload
    pads and the work list build happen once, shared by every chunk).
    Returns [B, N].
    """
    if not use_pallas:
        return kref.gqsa_gemv_ref(x, bsr)
    if interpret is None:
        interpret = not _on_tpu()

    b, k = x.shape
    n, m = bsr.idx.shape

    idx = _pad_to(_pad_to(bsr.idx, 0, block_n, value=-1), 1, block_m, value=-1)
    vals = _pad_to(_pad_to(bsr.vals, 0, block_n), 1, block_m)
    scale = _pad_to(_pad_to(bsr.scale, 0, block_n), 1, block_m)
    zero = _pad_to(_pad_to(bsr.zero, 0, block_n), 1, block_m)
    wl = build_work_list(idx, block_n, block_m)

    def run(xc: jnp.ndarray) -> jnp.ndarray:
        bc = xc.shape[0]
        y = gqsa_gemv_pallas(
            _pad_to(xc, 0, MAX_GEMV_BATCH), idx, vals, scale, zero,
            (wl.row_block, wl.chunk, wl.first),
            group_size=bsr.group_size, block_n=block_n, block_m=block_m,
            interpret=interpret)
        return y[:bc, :n]

    if b <= MAX_GEMV_BATCH:
        return run(x)
    return jnp.concatenate([run(x[i:i + MAX_GEMV_BATCH])
                            for i in range(0, b, MAX_GEMV_BATCH)], axis=0)


def w4_matmul(
    x: jnp.ndarray,
    qw: jnp.ndarray,
    scale: jnp.ndarray,
    zero: jnp.ndarray,
    *,
    group_size: int,
    use_pallas: bool = True,
    block_t: int = DEFAULT_BLOCK_T,
    block_n: int = W4_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """y = x @ deq(qw).T (dense grouped-dequant). x: [T, K] -> [T, N]."""
    if not use_pallas:
        return kref.w4_matmul_ref(x, qw, scale, zero, group_size)
    if interpret is None:
        interpret = not _on_tpu()

    t, k = x.shape
    n = qw.shape[0]
    block_t = min(block_t, max(8, int(np.ceil(t / 8)) * 8))
    block_k = min(block_k, k) if k % group_size == 0 else block_k
    if block_k % group_size != 0 or k % block_k != 0:
        # fall back: single K block (K is a multiple of G by construction)
        block_k = k
    xp = _pad_to(_pad_to(x, 0, block_t), 1, block_k)
    qwp = _pad_to(_pad_to(qw, 0, block_n), 1, block_k // 2)
    sp = _pad_to(_pad_to(scale, 0, block_n), 1, block_k // group_size)
    zp = _pad_to(_pad_to(zero, 0, block_n), 1, block_k // group_size)
    y = w4_matmul_pallas(xp, qwp, sp, zp, group_size=group_size,
                         block_t=block_t, block_n=block_n, block_k=block_k,
                         interpret=interpret)
    return y[:t, :n]


def gemv_bytes_model(bsr: BSRMatrix, batch: int = 1) -> dict:
    """Static byte-traffic model for the roofline (per call, per chip):
    everything the kernel DMAs from HBM once, at *deployed* widths
    (paper/gguf convention: int16 group index, fp16 scale, u8 zero —
    the padded in-memory form above uses wider dev-side types)."""
    n, k = bsr.shape
    m = bsr.idx.shape[1]
    g = bsr.group_size
    payload = n * m * (g * bsr.bits // 8 + 2 + 2 + 1)
    x_bytes = batch * k * 2           # bf16 activations
    y_bytes = batch * n * 4
    flops = 2 * batch * n * m * g
    return dict(weight_bytes=payload, act_bytes=x_bytes + y_bytes,
                total_bytes=payload + x_bytes + y_bytes, flops=flops)


def dense_bytes_model(n: int, k: int, batch: int = 1,
                      bits: int = 16, group_size: int = 0) -> dict:
    """Byte model for dense (fp16 / W4) GEMV for the fig6 comparison."""
    wbytes = n * k * bits // 8
    if group_size:
        wbytes += n * (k // group_size) * 3  # fp16 scale + u8 zero
    x_bytes = batch * k * 2
    y_bytes = batch * n * 4
    return dict(weight_bytes=wbytes, act_bytes=x_bytes + y_bytes,
                total_bytes=wbytes + x_bytes + y_bytes,
                flops=2 * batch * n * k)


def _paged_query_prep(lengths, block_tables, b: int, t: int,
                      page_size: int):
    """Shared preamble of the paged-attention dispatchers: broadcast the
    [] / [B] / [B, T] length spec to the kernel's [B, T] row operand and
    derive the live-page counts the scalar prefetch consumes — ONE
    definition so the GQA and latent entry points can never
    desynchronize on the rounding/sentinel convention."""
    from repro.models.layers import _query_lengths
    lq = _query_lengths(lengths, b, t).astype(jnp.int32)     # [B, T]
    mp = block_tables.shape[1]
    live = jnp.clip(
        (jnp.max(lq, axis=1) + page_size - 1) // page_size, 0, mp)
    return lq, live


def paged_decode_attention(q, k_pages, v_pages, lengths, block_tables,
                           k_scale_pages=None, v_scale_pages=None, *,
                           anc=None, anc_base=None, anc_window: int = 0,
                           use_pallas: bool = True,
                           interpret: Optional[bool] = None):
    """Fused decode attention directly on the paged KV pool.

    q: [B, T, H, D] (T=1 continuous-batching decode; T=K+1 speculative
    verify); k/v_pages: [P, ps, KH, D] (bf16/f32, or int8 with f32
    [P, ps, KH] scale pages); lengths: [] / [B] / [B, T] per-query valid
    prefix (the multi-token staircase); block_tables: [B, MP] page ids,
    entries >= P are out-of-range sentinels. Returns [B, T, H, D] f32.

    ``anc`` [B, T] / ``anc_base`` [B] / ``anc_window`` switch the fed
    block to token-TREE semantics (`models/layers.py:ancestor_mask`):
    query t additionally needs bit ``s - anc_base[b]`` of ``anc[b, t]``
    for cache positions inside the fed window.

    The Pallas path streams only each slot's live pages through VMEM —
    O(live tokens) HBM traffic; the jnp path is the dense-gather
    reference (`kernels/ref.py:paged_attention_ref` /
    `tree_attention_ref`, identical math).
    """
    if not use_pallas:
        return kref.paged_attention_ref(q, k_pages, v_pages, lengths,
                                        block_tables, k_scale_pages,
                                        v_scale_pages, anc=anc,
                                        anc_base=anc_base,
                                        anc_window=anc_window)
    if interpret is None:
        interpret = not _on_tpu()
    from repro.kernels.paged_attention import paged_attention_pallas
    b, t, h, d = q.shape
    page_size = k_pages.shape[1]
    khn = k_pages.shape[2]
    r = h // khn
    lq, live = _paged_query_prep(lengths, block_tables, b, t, page_size)
    # kernel row layout: [B, KH, T*R, D], T-major inside the row dim
    qh = q.reshape(b, t, khn, r, d).transpose(0, 2, 1, 3, 4) \
          .reshape(b, khn, t * r, d)
    o = paged_attention_pallas(qh, k_pages, v_pages, lq, block_tables,
                               live, k_scale_pages, v_scale_pages,
                               t=t, anc=anc, anc_base=anc_base,
                               anc_window=anc_window, interpret=interpret)
    return o.reshape(b, khn, t, r, d).transpose(0, 2, 1, 3, 4) \
            .reshape(b, t, h, d)


def paged_latent_attention(q, lat_pages, lengths, block_tables, *,
                           v_rank: int, anc=None, anc_base=None,
                           anc_window: int = 0, use_pallas: bool = True,
                           interpret: Optional[bool] = None):
    """Fused decode attention on the paged MLA LATENT pool (DESIGN.md §9).

    q: [B, T, H, R + rope] absorbed-W_UK queries, PRE-SCALED by
    sqrt(fake/true) (`models/mla.py:_absorbed_q` — the kernel divides by
    sqrt(R + rope)); lat_pages: [P, ps, R + rope] — one logical KV head,
    post-norm c_kv ++ post-RoPE k_rope per token; lengths / block_tables
    / anc semantics exactly as :func:`paged_decode_attention`. Returns
    the latent context [B, T, H, v_rank] f32: the value of a cached
    token is the leading ``v_rank`` (= kv_lora_rank) dims of its latent
    row — there is no V pool, and W_UV is applied by the caller AFTER
    attention.

    The Pallas path shares the scalar-prefetch/block-table machinery of
    the GQA kernel (``v_pages=None`` latent mode: V = K pages, lane-dim
    tiled scores for R + rope > 128) and computes the full R + rope
    value columns (sliced here — column independence makes the leading
    dims identical); the jnp path is the dense-gather reference
    (`kernels/ref.py:paged_latent_attention_ref`).
    """
    if not use_pallas:
        return kref.paged_latent_attention_ref(
            q, lat_pages, lengths, block_tables, v_rank, anc=anc,
            anc_base=anc_base, anc_window=anc_window)
    if interpret is None:
        interpret = not _on_tpu()
    from repro.kernels.paged_attention import paged_attention_pallas
    b, t, h, d = q.shape
    page_size = lat_pages.shape[1]
    lq, live = _paged_query_prep(lengths, block_tables, b, t, page_size)
    # kernel row layout: [B, KH=1, T*H, D], T-major inside the row dim
    qh = q.reshape(b, t * h, d)[:, None]
    o = paged_attention_pallas(qh, lat_pages[:, :, None, :], None, lq,
                               block_tables, live, t=t, anc=anc,
                               anc_base=anc_base, anc_window=anc_window,
                               interpret=interpret)
    return o.reshape(b, t, h, d)[..., :v_rank]


def kv_decode_attention(q, k_cache, k_scale, v_cache, v_scale, length, *,
                        use_pallas: bool = True, block_s: int = 512,
                        interpret: Optional[bool] = None):
    """int8-KV decode attention over a *contiguous* cache — the degenerate
    one-page-table case of the paged kernel: the [B, S, ...] cache is
    viewed as B*ceil(S/block_s) pages of ``block_s`` tokens with identity
    block tables (no data movement beyond the pad). q: [B, KH, R, D] ->
    [B, KH, R, D] f32."""
    if not use_pallas:
        return kref.kv_decode_attention_ref(q, k_cache, k_scale, v_cache,
                                            v_scale, length)
    b, khn, r, d = q.shape
    s = k_cache.shape[1]
    block_s = min(block_s, s)
    pad = (-s) % block_s
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_scale = jnp.pad(k_scale, ((0, 0), (0, pad), (0, 0)))
        v_scale = jnp.pad(v_scale, ((0, 0), (0, pad), (0, 0)))
    npg = (s + pad) // block_s

    def pages(buf):                      # [B, S, ...] -> [B*NP, bs, ...]
        return buf.reshape((b * npg, block_s) + buf.shape[2:])

    bt = jnp.arange(b * npg, dtype=jnp.int32).reshape(b, npg)
    o = paged_decode_attention(
        q.reshape(b, 1, khn * r, d), pages(k_cache), pages(v_cache),
        jnp.broadcast_to(jnp.reshape(length, (-1,)), (b,)), bt,
        pages(k_scale), pages(v_scale), use_pallas=True,
        interpret=interpret)
    return o.reshape(b, khn, r, d)
