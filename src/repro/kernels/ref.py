"""Pure-jnp oracles for the Pallas kernels.

These are also the *dry-run / GSPMD path*: identical math to the kernels,
expressed as gather + einsum so XLA can shard them (N on the `model` axis).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bsr import BSRMatrix
from repro.core.quant import unpack_int4


def gqsa_gemv_ref(x: jnp.ndarray, bsr: BSRMatrix,
                  dtype=jnp.float32) -> jnp.ndarray:
    """Sparse-quantized GEMV / skinny GEMM.

    x: [B, K]  (B = decode slots, or slots x (K+1) draft rows in the
    speculative verify step)
    returns y: [B, N] with y[b,n] = sum_m deq(vals[n,m]) . x[b, idx[n,m]G:+G]

    The surviving groups are dequantized once and scattered into a dense
    [N, K] operand, then contracted with ONE matmul: traffic is
    row-count-independent (~2.5x the BSR payload). The previous
    formulation gathered activation groups per output row into a
    [B, N, M, G] tensor — the whole payload times B — which made
    multi-row calls (the verify step) pay for their rows twice over.
    Padding slots carry idx -1 -> clamped to group 0 with scale 0, so
    they scatter-add zeros.
    """
    n, k = bsr.shape
    g = bsr.group_size
    q = unpack_int4(bsr.vals).astype(jnp.float32)              # [N, M, G]
    w = (q - bsr.zero[..., None]) * bsr.scale[..., None]       # [N, M, G]
    safe = jnp.maximum(bsr.idx, 0)                              # [N, M]
    rows = jnp.arange(n)[:, None]
    # duplicates only occur among padding slots (all-zero contributions),
    # so scatter-ADD is order-independent and exact
    wd = jnp.zeros((n, k // g, g), jnp.float32).at[rows, safe].add(w)
    y = x.astype(jnp.float32) @ wd.reshape(n, k).T
    return y.astype(dtype)


def w4_matmul_ref(x: jnp.ndarray, qw: jnp.ndarray, scale: jnp.ndarray,
                  zero: jnp.ndarray, group_size: int,
                  dtype=jnp.float32) -> jnp.ndarray:
    """Dense grouped-dequant matmul (W4A16 baseline / prefill path).

    x: [B, K]; qw: packed uint8 [N, K/2]; scale/zero: [N, K/G].
    y = x @ deq(qw).T
    """
    n = qw.shape[0]
    q = unpack_int4(qw).astype(jnp.float32)                    # [N, K]
    k = q.shape[1]
    qg = q.reshape(n, k // group_size, group_size)
    w = (qg - zero[..., None]) * scale[..., None]
    w = w.reshape(n, k)
    return (x.astype(jnp.float32) @ w.T).astype(dtype)


def kv_decode_attention_ref(q, k_cache, k_scale, v_cache, v_scale, length,
                            dtype=jnp.float32):
    """Oracle for the int8-KV decode attention kernel.

    q: [B, KH, R, D]; k/v_cache: int8 [B, S, KH, D]; scales [B, S, KH].
    """
    b, s, khn, d = k_cache.shape
    k = k_cache.astype(jnp.float32) * k_scale[..., None]
    v = v_cache.astype(jnp.float32) * v_scale[..., None]
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    sco = jnp.einsum("bkrd,bskd->bkrs", q.astype(jnp.float32), k) * scale
    pos = jnp.arange(s)
    valid = pos[None, :] < jnp.reshape(length, (-1, 1))
    sco = jnp.where(valid[:, None, None, :], sco, -jnp.inf)
    p = jax.nn.softmax(sco, axis=-1)
    o = jnp.einsum("bkrs,bskd->bkrd", p, v)
    return o.astype(dtype)


def paged_attention_ref(q, k_pages, v_pages, lengths, block_tables,
                        k_scale_pages=None, v_scale_pages=None,
                        dtype=jnp.float32, *, anc=None, anc_base=None,
                        anc_window: int = 0):
    """Oracle + GSPMD/dry-run path for the paged decode attention kernel.

    Dense page gather (what the kernel avoids) followed by staircase
    attention — identical math to the Pallas kernel: dequantize int8
    pages, f32 score/value contractions, per-query length mask.

    q: [B, T, H, D] (T=1 decode, T=K+1 speculative verify);
    k/v_pages: [P, ps, KH, D] (int8 variants add [P, ps, KH] scales);
    lengths: [] / [B] / [B, T] per-query valid prefix; block_tables:
    [B, MP] page ids — entries >= P are sentinels and clamp to P - 1
    (XLA's OOB-gather clip), their positions masked by ``lengths``.
    ``anc``/``anc_base``/``anc_window``: optional token-tree ancestor
    bitmaps (`models/layers.py:ancestor_mask`; see
    :func:`tree_attention_ref`).
    Rows whose length is 0 softmax over an empty set and return NaN
    (the kernel returns 0 there); callers mask such rows either way.
    """
    from repro.models.layers import ancestor_mask
    b, t, h, d = q.shape
    num_pages, ps, khn, _ = k_pages.shape
    r = h // khn

    def view(buf):                       # [P, ps, ...] -> [B, MP*ps, ...]
        g = buf[jnp.minimum(block_tables, num_pages - 1)]
        return g.reshape((b, -1) + buf.shape[2:])

    k = view(k_pages).astype(jnp.float32)
    v = view(v_pages).astype(jnp.float32)
    if k_scale_pages is not None:
        k = k * view(k_scale_pages)[..., None]
        v = v * view(v_scale_pages)[..., None]
    s = k.shape[1]
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    qh = q.reshape(b, t, khn, r, d).astype(jnp.float32)
    sco = jnp.einsum("btkrd,bskd->bkrts", qh, k) * scale
    valid = ancestor_mask(lengths, anc, anc_base, anc_window,
                          b, t, s)                         # [B, T, S]
    sco = jnp.where(valid[:, None, None, :, :], sco, -jnp.inf)
    p = jax.nn.softmax(sco, axis=-1)                       # [B,KH,R,T,S]
    o = jnp.einsum("bkrts,bskd->btkrd", p, v)
    return o.reshape(b, t, h, d).astype(dtype)


def paged_latent_attention_ref(q, lat_pages, lengths, block_tables,
                               v_rank: int, dtype=jnp.float32, *,
                               anc=None, anc_base=None,
                               anc_window: int = 0):
    """Oracle + GSPMD/dry-run path for the paged LATENT attention kernel
    (MLA, DESIGN.md §9).

    q: [B, T, H, R + rope] absorbed pre-scaled queries; lat_pages:
    [P, ps, R + rope] — a single logical KV head whose value is the
    leading ``v_rank`` dims of the same row (no V pool). Dense page
    gather followed by single-head attention with the shared
    staircase/ancestor masks; sentinel block-table entries clamp to
    P - 1 exactly like :func:`paged_attention_ref`. Returns
    [B, T, H, v_rank].
    """
    from repro.models.layers import ancestor_mask
    b, t, h, d = q.shape
    num_pages, ps, dl = lat_pages.shape
    g = lat_pages[jnp.minimum(block_tables, num_pages - 1)]
    k = g.reshape(b, -1, dl).astype(jnp.float32)           # [B, S, R+rope]
    s = k.shape[1]
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    sco = jnp.einsum("bthd,bsd->bhts", q.astype(jnp.float32), k) * scale
    valid = ancestor_mask(lengths, anc, anc_base, anc_window,
                          b, t, s)                         # [B, T, S]
    sco = jnp.where(valid[:, None, :, :], sco, -jnp.inf)
    p = jax.nn.softmax(sco, axis=-1)                       # [B, H, T, S]
    o = jnp.einsum("bhts,bsd->bthd", p, k[..., :v_rank])
    return o.astype(dtype)


def tree_attention_ref(q, k_pages, v_pages, lengths, block_tables,
                       anc, anc_base, anc_window: int,
                       k_scale_pages=None, v_scale_pages=None,
                       dtype=jnp.float32):
    """Oracle for token-TREE paged attention (DESIGN.md §8).

    The T fed queries are a flat BFS token tree written at cache
    positions ``anc_base .. anc_base + anc_window - 1``; ``anc`` [B, T]
    carries each query's root-to-self path as a bitmap over that window
    (bit i = BFS slot i visible). Everything else is
    :func:`paged_attention_ref` — the staircase is the degenerate chain
    (every bitmap a prefix of ones)."""
    return paged_attention_ref(q, k_pages, v_pages, lengths, block_tables,
                               k_scale_pages, v_scale_pages, dtype,
                               anc=anc, anc_base=anc_base,
                               anc_window=anc_window)
