"""Grouped-dequant dense W4 matmul — Pallas TPU kernel.

The quantization-only baseline (paper's W4A16 rows) and the prefill/training
path for GQS layers: dequantize per-group INT4 tiles in VMEM and feed the MXU.

    x      [T, K]         activations (T = tokens)
    qw     [N, K/2] u8    packed INT4 codes (dense; pruned groups are zeros)
    scale  [N, K/G] f32
    zero   [N, K/G] f32
    y      [T, N]

Grid (T/BT, N/BN, K/BK): K innermost, accumulated in the revisited out tile.
BK must be a multiple of the quant group size G so scale tiles align.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_T = 256
DEFAULT_BLOCK_N = 128
DEFAULT_BLOCK_K = 512


def _kernel(x_ref, qw_ref, scale_ref, zero_ref, y_ref, *, group_size: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    bn = qw_ref.shape[0]
    packed = qw_ref[...]                              # [BN, BK/2]
    lo = (packed & 0xF).astype(jnp.float32)
    hi = ((packed >> 4) & 0xF).astype(jnp.float32)
    q = jnp.stack([lo, hi], axis=-1).reshape(bn, -1)  # [BN, BK]
    bk = q.shape[1]
    g = group_size
    qg = q.reshape(bn, bk // g, g)
    w = (qg - zero_ref[...][..., None]) * scale_ref[...][..., None]
    w = w.reshape(bn, bk)                             # [BN, BK] f32

    x = x_ref[...].astype(jnp.float32)                # [BT, BK]
    y_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32).astype(y_ref.dtype)


def w4_matmul_pallas(
    x: jnp.ndarray,
    qw: jnp.ndarray,
    scale: jnp.ndarray,
    zero: jnp.ndarray,
    *,
    group_size: int,
    block_t: int = DEFAULT_BLOCK_T,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jnp.ndarray:
    """Pre-padded: T%BT == 0, N%BN == 0, K%BK == 0, BK%G == 0."""
    t, k = x.shape
    n = qw.shape[0]
    g = group_size
    assert block_k % g == 0

    grid = (t // block_t, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_kernel, group_size=g),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_n, block_k // 2), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((block_n, block_k // g), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((block_n, block_k // g), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((block_t, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        interpret=interpret,
    )(x, qw, scale, zero)
