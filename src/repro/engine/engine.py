"""The continuous-batching inference engine (DESIGN.md §3).

One jitted *batched prefill* runs each admission group's full prompts
through flash attention and scatters their K/V into the paged cache; one
jitted *fused decode step* advances every slot at its own position and
samples the next token on device. The sampled token array is fed straight
back into the next decode call (device-side token feedback) — the host
never pulls tokens mid-flight. Because stopping is purely budget-based,
host control flow needs no per-step sync: the loop dispatches a whole
decode *segment* (until the earliest active request exhausts its budget)
and blocks once at the segment boundary, which is also where timestamps
are taken and slots are evicted/refilled.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.kv_cache import PagedKVCache
from repro.engine.metrics import EngineMetrics
from repro.engine.sampling import SamplingParams, sample
from repro.engine.scheduler import DECODE, Request, Scheduler
from repro.models.registry import get_model


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    num_slots: int = 4
    max_seq: int = 64                 # per-request prompt + budget cap
    page_size: int = 16
    num_pages: Optional[int] = None   # None: num_slots * max_seq / page_size
    prompt_bucket_min: int = 8        # prefill pad bucket floor (pow2 above)
    use_pallas: bool = False
    seed: int = 0


def _bucket(n: int, lo: int) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


@functools.lru_cache(maxsize=32)
def _step_fns(cfg, sampling: SamplingParams, use_pallas: bool):
    """Jitted prefill/decode steps, shared across engine instances with the
    same (model config, sampling, backend) — a fresh engine per workload
    must not recompile (both keys are frozen dataclasses)."""
    api = get_model(cfg)

    def prefill_fn(params, cache, tokens, lengths, block_tables, rng):
        logits, cache = api.prefill(params, cache, tokens, lengths,
                                    block_tables, cfg, None, use_pallas)
        rng, sub = jax.random.split(rng)
        first = sample(logits[:, -1, :], sub, sampling)
        return first, cache, rng

    def decode_fn(params, cache, tokens, positions, block_tables,
                  active, rng):
        logits, cache = api.decode_step(params, cache, tokens[:, None],
                                        positions, cfg, None, use_pallas,
                                        block_tables=block_tables)
        rng, sub = jax.random.split(rng)
        nxt = sample(logits[:, -1, :], sub, sampling)
        return nxt, positions + active, cache, rng

    return jax.jit(prefill_fn), jax.jit(decode_fn)


class InferenceEngine:
    def __init__(self, cfg, params, engine_cfg: EngineConfig = EngineConfig(),
                 sampling: SamplingParams = SamplingParams()):
        api = get_model(cfg)
        if api.prefill is None or api.init_paged_cache is None:
            raise NotImplementedError(
                f"family {cfg.family!r} lacks prefill/paged-cache support")
        self.cfg = cfg
        self.params = params
        self.ecfg = engine_cfg
        self.sampling = sampling
        self.api = api
        if engine_cfg.use_pallas and cfg.kv_cache_dtype == "int8":
            import warnings
            warnings.warn(
                "paged decode attention has no pallas kernel yet: linears "
                "run the pallas path but int8 decode attention falls back "
                "to the jnp reference", stacklevel=2)
        self.kv = PagedKVCache(cfg, api, engine_cfg.num_slots,
                               engine_cfg.max_seq, engine_cfg.page_size,
                               engine_cfg.num_pages)
        self.scheduler = Scheduler(engine_cfg.num_slots, self.kv,
                                   engine_cfg.max_seq)
        self.metrics = EngineMetrics()
        self._rng = jax.random.PRNGKey(engine_cfg.seed)
        b = engine_cfg.num_slots
        self._tokens = jnp.zeros((b,), jnp.int32)      # device-side feedback
        self._positions = jnp.zeros((b,), jnp.int32)
        self._active = jnp.zeros((b,), jnp.int32)
        self._block_tables = self.kv.device_block_tables()
        self._token_log: List[jnp.ndarray] = []        # [B] arrays, lazy
        self._prefill_fn, self._decode_fn = _step_fns(
            cfg, sampling, engine_cfg.use_pallas)

    # -- API ----------------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int) -> int:
        rid = self.scheduler.submit(prompt, max_new_tokens)
        self.metrics.record_enqueue(rid)
        return rid

    def run(self) -> Dict:
        """Serve until the queue and all slots drain. Returns
        {"results": [...], "metrics": {...}} (results in completion order)."""
        sch = self.scheduler
        self.metrics.run_started()
        while sch.has_work():
            admitted = sch.admit()
            if admitted:
                self._do_prefill(admitted)
            actives = [r for r in sch.active() if r.state == DECODE]
            if not actives:
                if sch.waiting and not sch.active():
                    head = sch.waiting[0]
                    raise RuntimeError(
                        f"request {head.rid} needs "
                        f"{self.kv.pages_needed(head.total_tokens)} pages "
                        f"but the pool only has {self.kv.num_pages}")
                continue
            # decode segment: no slot can exceed its budget before the
            # earliest one finishes, so no host sync inside the segment
            seg = max(1, min(r.max_new_tokens - r.produced for r in actives))
            finished: List[Request] = []
            for _ in range(seg):
                self._tokens, self._positions, self.kv.data, self._rng = \
                    self._decode_fn(self.params, self.kv.data, self._tokens,
                                    self._positions, self._block_tables,
                                    self._active, self._rng)
                idx = len(self._token_log)
                self._token_log.append(self._tokens)
                for r in sch.active():
                    r.log_entries.append(idx)
                finished.extend(sch.step_decoded())
            jax.block_until_ready(self._tokens)        # segment boundary
            t = self.metrics.now()
            self.metrics.decode_steps += seg
            for r in finished:
                self.metrics.record_finish(r.rid, t, r.produced)
                sch.finish(r)
            if finished:
                self._sync_slot_state()
        self.metrics.run_finished()
        return {"results": self._materialize(), "metrics":
                self.metrics.summary()}

    # -- internals ----------------------------------------------------------

    def _do_prefill(self, admitted: List[Request]) -> None:
        b = self.ecfg.num_slots
        # cap the pow2 bucket at max_seq: prompt_len <= max_seq is enforced
        # at submit, and wider buckets are pure waste (FLOPs + a compile)
        s = min(_bucket(max(r.prompt_len for r in admitted),
                        self.ecfg.prompt_bucket_min), self.ecfg.max_seq)
        tokens = np.zeros((b, s), np.int32)
        lengths = np.zeros((b,), np.int32)
        # decoding slots must be invisible to the prefill scatter: their
        # rows get length 0 + all-sentinel block tables
        bt = np.full_like(self.kv.block_tables, self.kv.sentinel)
        mask = np.zeros((b,), bool)
        for r in admitted:
            self.metrics.record_admit(r.rid)
            tokens[r.slot, :r.prompt_len] = r.prompt
            lengths[r.slot] = r.prompt_len
            bt[r.slot] = self.kv.block_tables[r.slot]
            mask[r.slot] = True
        first, self.kv.data, self._rng = self._prefill_fn(
            self.params, self.kv.data, jnp.asarray(tokens),
            jnp.asarray(lengths), jnp.asarray(bt), self._rng)
        jax.block_until_ready(first)
        t = self.metrics.now()
        idx = len(self._token_log)
        self._token_log.append(first)
        done_now = []
        for r in admitted:
            r.state = DECODE
            r.produced = 1                       # prefill produced token #1
            r.log_entries = [idx]
            self.metrics.record_first_token(r.rid, t)
            if r.produced >= r.max_new_tokens:   # max_new_tokens == 1
                self.metrics.record_finish(r.rid, t, r.produced)
                done_now.append(r)
        for r in done_now:
            self.scheduler.finish(r)
        # merge the admitted slots into the device-side decode state
        m = jnp.asarray(mask)
        self._tokens = jnp.where(m, first, self._tokens)
        self._positions = jnp.where(m, jnp.asarray(lengths), self._positions)
        self._sync_slot_state()

    def _sync_slot_state(self) -> None:
        """Refresh device copies of the block tables + active mask after a
        scheduling event (admission or eviction)."""
        self._block_tables = self.kv.device_block_tables()
        act = np.zeros((self.ecfg.num_slots,), np.int32)
        for i, slot in enumerate(self.scheduler.slots):
            if slot.request is not None and slot.request.state == DECODE:
                act[i] = 1
        self._active = jnp.asarray(act)

    def _materialize(self) -> List[Dict]:
        """One host sync: stack the token log and slice every request's
        generated tokens out of it (completion order)."""
        if self._token_log:
            mat = np.asarray(jnp.stack(self._token_log))
        else:
            mat = np.zeros((0, self.ecfg.num_slots), np.int32)
        out = []
        for r in self.scheduler.finished:
            toks = mat[np.asarray(r.log_entries, np.int64), r.slot] \
                if r.log_entries else np.zeros((0,), np.int32)
            toks = toks[:r.produced]
            r.output = toks.astype(np.int32)
            out.append({"rid": r.rid, "prompt_len": r.prompt_len,
                        "tokens": r.output, "n_generated": r.produced})
        return out
