"""The continuous-batching inference engine (DESIGN.md §3).

One jitted *batched prefill* runs each admission group's full prompts
through flash attention and scatters their K/V into the paged cache; one
jitted *fused decode step* advances every slot at its own position and
samples the next token on device. The sampled token array is fed straight
back into the next decode call (device-side token feedback) — the host
never pulls tokens mid-flight. Because stopping is purely budget-based,
host control flow needs no per-step sync: the loop dispatches a whole
decode *segment* (until the earliest active request exhausts its budget)
and blocks once at the segment boundary, which is also where timestamps
are taken and slots are evicted/refilled.

With ``spec_k > 0`` the segment interleaves draft/verify *rounds*
instead of single-token steps (self-speculative decoding, DESIGN.md §4):
a fused K-step greedy draft call with the aggressively-compressed draft
parameter set, then one multi-token verify call that emits 1..K+1 tokens
per slot. Budgets are clamped on device, so segments stay sync-free.
``spec_fanout`` upgrades the round to a token TREE (DESIGN.md §8):
top-k branches per draft depth, one T = N+1 tree-attention verify, and
an accepted-path KV compaction — optionally retuned online per segment
from the observed acceptance rate (``spec_adaptive``).

With ``prefill_chunk_tokens > 0`` prefill stops being atomic
(Sarathi-style chunked prefill, DESIGN.md §14): an admitted prompt
whose unshared tail exceeds the budget enters a ``PREFILLING`` state
and feeds one token-budget chunk per scheduling boundary through the
ragged ``tail_fn`` path — a chunk is just a tail whose shared boundary
is the previous chunk's end — while the other slots keep decoding
(segments clamp to one step so chunks interleave at token granularity).
The decode loop itself runs *two-deep*: each segment's boundary sync
waits on the PREVIOUS segment's tokens (a trailing copy), so the host
schedules segment N+1 while N still executes and issues strictly fewer
``block_until_ready`` calls than segments dispatched.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.kv_cache import PagedKVCache
from repro.engine.metrics import EngineMetrics
from repro.engine.resilience import (ChaosDeviceError, PRESSURE_CRITICAL,
                                     PRESSURE_ELEVATED, ResilienceConfig,
                                     choose_victims, make_injector,
                                     pressure_level)
from repro.engine.sampling import SamplingParams, sample
from repro.engine.scheduler import DECODE, PREFILLING, Request, Scheduler
from repro.engine.telemetry import Telemetry
from repro.models.registry import get_model


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    num_slots: int = 4
    max_seq: int = 64                 # per-request prompt + budget cap
    page_size: int = 16
    num_pages: Optional[int] = None   # None: num_slots * max_seq / page_size
    prompt_bucket_min: int = 8        # prefill pad bucket floor (pow2 above)
    use_pallas: bool = False
    seed: int = 0
    # shared-prefix KV reuse (engine/prefix_cache.py, DESIGN.md §13):
    # admission maps cached full-page prompt blocks to existing pages
    # (refcounted, copy-on-write) and prefills only the unshared tail.
    # Greedy outputs are bit-identical on/off (pinned by test).
    prefix_cache: bool = False
    # overload resilience (engine/resilience/, DESIGN.md §12): preemption
    # + shedding + pressure degrade + optional chaos injection. None uses
    # the all-defaults ResilienceConfig (inert without priority
    # inversions, deadlines or a chaos spec).
    resilience: Optional[ResilienceConfig] = None
    # speculative decoding: draft K tokens per round with the (separately
    # compressed) draft parameter set, verify all K in one multi-token
    # target step. 0 disables; > 0 requires draft_params at engine
    # construction (engine/spec/, DESIGN.md §4). spec_draft_layers: the
    # drafter's depth for depth-pruned draft profiles (None = full depth;
    # must match core.model_compress.draft_layers of the profile used).
    spec_k: int = 0
    spec_draft_layers: Optional[int] = None
    # token-TREE drafting (engine/spec/tree.py, DESIGN.md §8): fanout per
    # draft depth, e.g. (4, 2, 2) = 28 nodes / 16 leaves / depth 3 — the
    # round's verify block is all N+1 tree slots and 1..depth+1 tokens
    # emerge per slot. Overrides spec_k (which stays the CHAIN path).
    spec_fanout: Optional[Tuple[int, ...]] = None
    # retune the tree online from a per-slot EWMA of the observed
    # acceptance rate: thrash shrinks to a chain K=1, sustained
    # acceptance widens back to the full spec_fanout profile
    spec_adaptive: bool = False
    # chunked prefill (DESIGN.md §14): split each admitted prompt into
    # chunks of at most this many tokens and interleave them into the
    # decode loop (one chunk per scheduling boundary) instead of one
    # monolithic admission prefill — bounds the TPOT jitter prefills
    # inject into co-resident decodes. 0 = monolithic (the historical
    # behaviour); greedy outputs are bit-identical on/off (pinned).
    prefill_chunk_tokens: int = 0


def _bucket(n: int, lo: int) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


def plan_chunks(start: int, prompt_len: int,
                budget: int) -> List[Tuple[int, int]]:
    """Chunk planner (DESIGN.md §14): split prompt positions
    [start, prompt_len) into ``(chunk_start, chunk_len)`` pieces of at
    most ``budget`` tokens, covering every position exactly once. The
    last chunk always ends exactly at ``prompt_len`` — its sampled
    token is the request's first output token, so the final chunk is
    never empty. ``budget <= 0`` means monolithic: one chunk."""
    if budget <= 0:
        return [(start, prompt_len - start)]
    out = []
    p = start
    while p < prompt_len:
        n = min(budget, prompt_len - p)
        out.append((p, n))
        p += n
    return out


@functools.lru_cache(maxsize=32)
def _step_fns(cfg, sampling: SamplingParams, use_pallas: bool):
    """Jitted prefill/decode steps, shared across engine instances with the
    same (model config, sampling, backend) — a fresh engine per workload
    must not recompile (both keys are frozen dataclasses)."""
    api = get_model(cfg)

    # jax.named_scope: trace-time-only phase names so device profiler
    # traces line up with the host spans (telemetry, DESIGN.md §10) —
    # no runtime cost once compiled
    def prefill_fn(params, cache, tokens, lengths, block_tables, rng):
        with jax.named_scope("engine_prefill"):
            logits, cache = api.prefill(params, cache, tokens, lengths,
                                        block_tables, cfg, None, use_pallas)
            rng, sub = jax.random.split(rng)
            first = sample(logits[:, -1, :], sub, sampling)
        return first, cache, rng

    def decode_fn(params, cache, tokens, positions, block_tables,
                  active, rng, max_live):
        with jax.named_scope("engine_decode"):
            logits, cache = api.decode_step(params, cache, tokens[:, None],
                                            positions, cfg, None, use_pallas,
                                            block_tables=block_tables,
                                            max_live_pages=max_live)
            rng, sub = jax.random.split(rng)
            with jax.named_scope("engine_sample"):
                nxt = sample(logits[:, -1, :], sub, sampling)
        return nxt, positions + active, cache, rng

    def tail_fn(params, cache, tokens, positions, feed_len, block_tables,
                rng, max_live):
        # prefix-cache tail prefill (DESIGN.md §13): slots whose prompt
        # prefix is served from cached pages feed only the unshared tail
        # — a ragged multi-token decode block (token t writes/attends at
        # positions + t, rows padded to one T and sentinel-masked past
        # feed_len). First-token logits come from each row's LAST real
        # token, so the clamp in assign guarantees feed_len >= 1.
        with jax.named_scope("engine_prefill_tail"):
            logits, cache = api.decode_step(params, cache, tokens,
                                            positions, cfg, None, use_pallas,
                                            block_tables=block_tables,
                                            max_live_pages=max_live,
                                            feed_len=feed_len)
            last = jnp.take_along_axis(
                logits, jnp.maximum(feed_len - 1, 0)[:, None, None],
                axis=1)[:, 0, :]
            rng, sub = jax.random.split(rng)
            first = sample(last, sub, sampling)
        return first, cache, rng

    # max_live is static: it clamps the block tables to the batch's max
    # occupied page count (pow2-bucketed by the engine, so at most
    # log2(max_pages_per_slot) retraces per engine lifetime)
    return (jax.jit(prefill_fn), jax.jit(decode_fn, static_argnums=(7,)),
            jax.jit(tail_fn, static_argnums=(7,)))


class InferenceEngine:
    # adaptive tree control (spec_adaptive): per-slot EWMA of the round
    # acceptance fraction; below LOW the segment falls back to a chain
    # K=1, at/above HIGH it runs the full spec_fanout profile, between
    # them a depth-equal chain (cheap drafts, no width)
    SPEC_EWMA_INIT = 0.5
    SPEC_EWMA_BETA = 0.7
    SPEC_EWMA_LOW = 0.35
    SPEC_EWMA_HIGH = 0.65

    def __init__(self, cfg, params, engine_cfg: EngineConfig = EngineConfig(),
                 sampling: SamplingParams = SamplingParams(),
                 draft_params=None, telemetry: Optional[Telemetry] = None):
        api = get_model(cfg)
        if not api.supports_paged_cache:
            from repro.models.registry import paged_families
            raise NotImplementedError(
                f"family {cfg.family!r} lacks prefill/paged-cache support "
                f"(supported: {', '.join(paged_families())})")
        self._spec_tree = engine_cfg.spec_fanout is not None
        spec = engine_cfg.spec_k > 0 or self._spec_tree
        if spec and draft_params is None:
            raise ValueError("speculative decoding requires draft_params "
                             "(compress the same checkpoint with a draft "
                             "profile: core.model_compress.compress_draft)")
        self.cfg = cfg
        self.params = params
        self.draft_params = draft_params
        self.ecfg = engine_cfg
        self.sampling = sampling
        self.api = api
        self.spec = spec
        if self._spec_tree:
            from repro.engine.spec import TreeTemplate
            fan = tuple(int(f) for f in engine_cfg.spec_fanout)
            full = TreeTemplate(fan)
            # adaptive ladder: chain K=1 <- depth-equal chain <- full
            # tree. Rungs may coincide (e.g. a depth-1 fanout's mid rung
            # IS the low one) — kept positional, not deduped, so the
            # LOW/HIGH thresholds always map to the right rung; the
            # jitted step triple is lru-memoized per fanout, so
            # duplicate rungs never recompile.
            self._fanout_ladder = [(1,), (1,) * full.depth, fan] \
                if engine_cfg.spec_adaptive else [fan]
            lookahead = full.n_nodes       # verify writes all N tree slots
            self._spec_width = full.depth + 1
            self._tree_depth = full.depth
        else:
            lookahead = engine_cfg.spec_k
            self._spec_width = engine_cfg.spec_k + 1
        self._full_lookahead = lookahead
        self._accept_ewma = np.full((engine_cfg.num_slots,),
                                    self.SPEC_EWMA_INIT)
        # observability (DESIGN.md §10): one registry shared by the KV
        # cache, scheduler, spec ladder and metrics; tracing is off by
        # default and never changes the dispatch/sync structure
        self.tel = telemetry if telemetry is not None else Telemetry()
        reg = self.tel.registry
        self._c_retraces = reg.counter("jit.decode_retraces")
        self._c_ladder_flips = reg.counter("spec.ladder_transitions")
        self._g_ladder = reg.gauge("spec.ladder_rung")
        self._c_degraded = reg.counter("resil.degraded_segments")
        # chunked prefill (DESIGN.md §14): chunk dispatches + requests
        # preempted while still mid-prefill (their fold is empty — the
        # re-prefill restarts the chunk ladder from the fold point)
        self._c_chunks = reg.counter("engine.prefill_chunks")
        self._c_chunk_tokens = reg.counter("engine.prefill_chunk_tokens")
        self._c_midprefill_preempt = reg.counter(
            "resil.midprefill_preemptions")
        self._ladder_rung: Optional[int] = None
        self.rcfg = engine_cfg.resilience if engine_cfg.resilience \
            is not None else ResilienceConfig()
        self.chaos = make_injector(self.rcfg.chaos, reg)
        self.kv = PagedKVCache(cfg, api, engine_cfg.num_slots,
                               engine_cfg.max_seq, engine_cfg.page_size,
                               engine_cfg.num_pages,
                               lookahead=lookahead, registry=reg,
                               prefix_cache=engine_cfg.prefix_cache)
        self.kv.chaos = self.chaos
        self.scheduler = Scheduler(engine_cfg.num_slots, self.kv,
                                   engine_cfg.max_seq, registry=reg)
        self.metrics = EngineMetrics(registry=reg, tracer=self.tel.tracer)
        self._rng = jax.random.PRNGKey(engine_cfg.seed)
        b = engine_cfg.num_slots
        self._tokens = jnp.zeros((b,), jnp.int32)      # device-side feedback
        self._positions = jnp.zeros((b,), jnp.int32)
        self._active = jnp.zeros((b,), jnp.int32)
        self._remaining = jnp.zeros((b,), jnp.int32)   # per-slot budget left
        self._block_tables = self.kv.device_block_tables()
        self._max_live = self.kv.max_pages_per_slot    # static, pow2-bucketed
        self._source = None              # timed-admission stream, run() only
        # two-deep dispatch (DESIGN.md §14): token arrays of decode
        # segments dispatched but not yet synced. Each boundary retires
        # the PREVIOUS segment (trailing copy) and leaves the one just
        # dispatched in flight — at most one entry deep, so the host is
        # always scheduling segment N+1 while N executes.
        self._inflight: Deque[jnp.ndarray] = deque()
        self._token_log: List[jnp.ndarray] = []        # [B] arrays, lazy
        # spec mode log: (tokens [B, W], counts [B]) per prefill/round
        self._spec_log: List = []
        self._prefill_fn, self._decode_fn, self._tail_fn = _step_fns(
            cfg, sampling, engine_cfg.use_pallas)
        if self.spec and not self._spec_tree:
            from repro.engine.spec import spec_step_fns
            self._draft_fn, self._verify_fn = spec_step_fns(
                cfg, sampling, engine_cfg.use_pallas, engine_cfg.spec_k,
                engine_cfg.spec_draft_layers)

    # -- API ----------------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int,
               arrival_t: Optional[float] = None, priority: int = 0,
               deadline_t: Optional[float] = None) -> int:
        """Enqueue a request. ``arrival_t`` (a ``metrics.now()``-clock
        timestamp) backdates the enqueue to the request's TRUE arrival —
        the timed-admission loop polls its source at scheduling
        boundaries, so a request can arrive well before it is submitted,
        and queue wait / TTFT must be measured from arrival, not from
        the boundary that happened to notice it.

        ``priority``: admission band (higher served first; strictly
        higher may preempt, DESIGN.md §12.1). ``deadline_t``: absolute
        TTFT deadline on the metrics clock — a queued request past it is
        shed instead of served (defaults from the resilience config's
        ``deadline_ttft_ms``, measured from arrival). Malformed requests
        raise :class:`~repro.engine.resilience.RejectedRequest` and are
        never enqueued."""
        if deadline_t is None and self.rcfg.deadline_ttft_ms is not None:
            base = arrival_t if arrival_t is not None \
                else self.metrics.now()
            deadline_t = base + self.rcfg.deadline_ttft_ms / 1e3
        rid = self.scheduler.submit(prompt, max_new_tokens,
                                    arrival_t=arrival_t,
                                    priority=priority,
                                    deadline_t=deadline_t)
        self.metrics.record_enqueue(rid, t=arrival_t)
        return rid

    def run(self, source=None) -> Dict:
        """Serve until the queue and all slots drain. Returns
        {"results": [...], "metrics": {...}} (results in completion order).

        ``source`` (an :class:`~repro.engine.loadgen.ArrivalSource`)
        switches the loop to *timed admission* (open-loop serving,
        DESIGN.md §11): instead of draining a pre-submitted queue, the
        loop polls the source at every scheduling boundary, submits the
        requests whose arrival times have passed (backdated to their
        true arrivals), sleeps until the next arrival when idle, and
        feeds completions back (closed-loop sources schedule their next
        request off them). Requests therefore arrive MID-RUN, decode
        segments get interrupted by admissions, and queue wait measures
        real backpressure — the regime every SLO number must come from.
        """
        sch = self.scheduler
        tracer = self.tel.tracer
        self._source = source
        self.metrics.run_started()
        t0 = self.metrics.start_t
        interrupted = False
        try:
            while sch.has_work() or (source is not None
                                     and not source.exhausted):
                sch.tick_quarantine()
                if source is not None:
                    now = self.metrics.now()
                    for g in source.due(now - t0):
                        arr = t0 + g.arrival_s if g.arrival_s is not None \
                            else now
                        self.submit(g.prompt, g.max_new, arrival_t=arr,
                                    priority=getattr(g, "priority", 0))
                self._shed_pass(t0)
                if self.chaos is not None:
                    spike = self.chaos.latency_spike_s()
                    if spike > 0:
                        time.sleep(spike)
                la = self._admission_lookahead()
                with tracer.span("admit") as sp:
                    admitted = sch.admit(lookahead=la)
                    preempted = self._maybe_preempt(la)
                    if preempted:
                        admitted += sch.admit(lookahead=la)
                    sp.set(admitted=len(admitted), preempted=preempted,
                           queue_depth=len(sch.waiting))
                if admitted:
                    self._do_prefill(admitted)
                # chunked prefill (DESIGN.md §14): every PREFILLING slot
                # advances one prompt chunk per boundary; the final
                # chunk's sample is the first token and flips the slot
                # to DECODE in time for this boundary's segment
                self._feed_prefill_chunks()
                prefilling = any(r.state == PREFILLING
                                 for r in sch.active())
                actives = [r for r in sch.active() if r.state == DECODE]
                if not actives:
                    if sch.waiting and not sch.active():
                        head = sch.waiting[0]
                        need = self.kv.pages_needed(head.total_tokens,
                                                    lookahead=0)
                        if need > self.kv.num_pages:
                            # physically impossible, with the whole pool
                            # free — not backpressure, a config error
                            raise RuntimeError(
                                f"request {head.rid} needs {need} pages "
                                f"but the pool only has "
                                f"{self.kv.num_pages}")
                        # transient block (quarantined slots, injected
                        # alloc failure): retry at the next boundary
                        time.sleep(0.0005)
                        continue
                    if source is not None and not sch.has_work():
                        self._wait_for_arrival(source, t0)
                    continue
                if self.chaos is not None \
                        and self.chaos.cfg.nan_logits > 0:
                    pre_prod = {r.rid: r.produced for r in actives}
                else:
                    pre_prod = None
                # spec ladder interplay (DESIGN.md §14): no draft/verify
                # while any slot is mid-chunk — a plain one-step segment
                # keeps the chunk cadence token-granular, and plain
                # decode is the (lossless) floor of the degrade ladder
                if self.spec and not prefilling:
                    finished = self._spec_segment(actives)
                else:
                    finished = self._decode_segment(
                        actives, max_steps=1 if prefilling else None)
                if pre_prod is not None:
                    finished = self._inject_nan(actives, finished,
                                                pre_prod)
                t = self.metrics.now()
                with tracer.span("evict") as sp:
                    for r in finished:
                        self.metrics.record_finish(r.rid, t, r.produced)
                        sch.finish(r)
                        if source is not None:
                            source.on_finish(t - t0)
                        # an evicted slot's acceptance history dies with it
                        self._accept_ewma[r.slot] = self.SPEC_EWMA_INIT
                    if finished:
                        self._sync_slot_state()
                    sp.set(evicted=len(finished))
                self.tel.maybe_stats(self.metrics)
        except KeyboardInterrupt:
            # graceful shutdown (DESIGN.md §12): shed the queue, account
            # the in-flight requests with their tokens so far, free every
            # page — the caller still gets results/metrics/trace flushed
            interrupted = True
            self._drain_on_interrupt()
        self.metrics.run_finished()
        out = {"results": self._materialize(), "metrics":
               self.metrics.summary()}
        if interrupted:
            out["interrupted"] = True
        return out

    def _shed_pass(self, t0: float) -> None:
        """Boundary shed: drop queued requests whose TTFT deadline has
        already passed (first-class verdicts, DESIGN.md §12)."""
        sch = self.scheduler
        if not self.rcfg.shed or not sch.waiting:
            return
        now = self.metrics.now()
        for r in sch.shed_expired(now):
            self.metrics.record_shed(r.rid, now, "deadline")
            if self._source is not None:   # keep closed loops flowing
                self._source.on_finish(now - t0)

    def _admission_lookahead(self) -> Optional[int]:
        """Pressure-degraded admission (DESIGN.md §12.2): under KV-pool
        pressure, new reservations shrink their speculative lookahead
        (full -> chain K=1 -> none) so the pool serves more concurrent
        requests before any preemption fires. None = the full default."""
        if not self.spec or not self.rcfg.pressure_degrade:
            return None
        sch = self.scheduler
        head_blocked = bool(sch.waiting) and not self.kv.can_admit(
            sch.waiting[0].total_tokens, prompt=sch.waiting[0].prompt)
        lvl = pressure_level(self.kv, head_blocked,
                             self.rcfg.pressure_occupancy)
        if lvl == PRESSURE_CRITICAL:
            return 0
        if lvl == PRESSURE_ELEVATED:
            return 1
        return None

    def _maybe_preempt(self, la: Optional[int]) -> int:
        """KV-pressure preemption (DESIGN.md §12.1): the queue head has a
        free slot but cannot reserve pages — release strictly-lower-
        priority victims (their tokens fold into their prompts for
        lossless recompute) until it can. Returns the victim count."""
        sch = self.scheduler
        if not self.rcfg.preempt or not sch.waiting:
            return 0
        slot_free = any(s.free and i not in sch._quarantine
                        for i, s in enumerate(sch.slots))
        la_eff = self.kv.lookahead if la is None else la
        head = sch.waiting[0]
        if not slot_free or self.kv.can_admit(head.total_tokens, la_eff,
                                              prompt=head.prompt):
            return 0
        # mid-prefill slots are preemptible too (DESIGN.md §14): a
        # PREFILLING victim has the least sunk work per freed page (its
        # fold is empty — produced == folded — so recompute restarts
        # the chunk ladder from the fold point, losslessly)
        running = [r for r in sch.active()
                   if r.state in (DECODE, PREFILLING)]
        victims = choose_victims(head, running, self.kv, la_eff,
                                 self.rcfg.max_preemptions)
        for v in victims:
            self._preempt_request(v, "kv_pressure")
        return len(victims)

    def _drain_on_interrupt(self) -> None:
        """SIGINT landed mid-run: drop the queue (shed verdicts), account
        every in-flight request's tokens so far, release all pages."""
        sch = self.scheduler
        t = self.metrics.now()
        t0 = self.metrics.start_t or t
        for r in sch.shed_all():
            self.metrics.record_shed(r.rid, t, "shutdown")
            if self._source is not None:
                self._source.on_finish(t - t0)
        for r in list(sch.active()):
            if r.state == DECODE and r.produced > 0:
                self.metrics.record_finish(r.rid, t, r.produced)
            sch.finish(r)

    def _request_tokens(self, r: Request) -> np.ndarray:
        """Materialize the tokens ``r`` generated since its last fold
        (host sync — preemption is a slow path, not the decode loop)."""
        if not r.log_entries:
            return np.zeros((0,), np.int32)
        if self.spec:
            parts = []
            for i in r.log_entries:
                toks, cnt = self._spec_log[i]
                c = int(np.asarray(cnt)[r.slot])
                if c > 0:
                    parts.append(np.asarray(toks)[r.slot, :c])
            out = np.concatenate(parts) if parts \
                else np.zeros((0,), np.int32)
        else:
            mat = np.asarray(jnp.stack([self._token_log[i]
                                        for i in r.log_entries]))
            out = mat[:, r.slot]
        return out[:r.produced - r.folded].astype(np.int32)

    def _preempt_request(self, r: Request, reason: str) -> None:
        """Preempt-and-recompute (DESIGN.md §12.1): fold the tokens
        generated so far into the prompt and re-enqueue. Greedy prefill
        over (prompt + generated) writes the exact K/V a continued
        decode would have (the engine-vs-naive-forward parity test pins
        this), so the re-prefill resumes the request losslessly —
        bit-identical greedy outputs, pinned by test."""
        if r.state == PREFILLING:
            self._c_midprefill_preempt.inc()
        r.prompt = np.concatenate([r.prompt, self._request_tokens(r)]) \
            .astype(np.int32)
        r.folded = r.produced
        self.metrics.record_preempt(r.rid)
        self.tel.tracer.instant("preempt", rid=r.rid, reason=reason)
        self.scheduler.preempt(r)
        self._sync_slot_state()

    def _inject_nan(self, actives: List[Request], finished: List[Request],
                    pre_prod: Dict[int, int]) -> List[Request]:
        """Chaos ``nan_logits`` (DESIGN.md §12.3): a poisoned sampler for
        one slot's segment. Recovery = drop the segment's tokens for
        that slot (rewind to the pre-segment count; materialization
        trims to ``produced``), quarantine the slot for a few
        boundaries, and re-enqueue the request for lossless recompute —
        greedy outputs stay bit-identical to a fault-free run."""
        sch = self.scheduler
        for r in actives:
            if not self.chaos.fires("nan_logits"):
                continue
            r.produced = pre_prod[r.rid]
            if r in finished:
                finished.remove(r)
            slot = r.slot
            self._preempt_request(r, "nan_quarantine")
            sch.quarantine_slot(slot,
                                self.chaos.cfg.quarantine_boundaries)
        return finished

    def _wait_for_arrival(self, source, t0: float) -> None:
        """Engine idle, stream not exhausted: sleep until the next
        arrival is due (capped so a closed-loop source whose next due
        time depends on a completion re-polls promptly)."""
        nxt = source.next_at()
        if nxt is None:
            return
        dt = (t0 + nxt) - self.metrics.now()
        if dt > 0:
            time.sleep(min(dt, 0.05))

    def _dispatch(self, fn, *args):
        """Dispatch one jitted step, with chaos device-error injection +
        bounded exponential-backoff retry (the ``dist.fault.retrying``
        discipline). Safe to retry unconditionally: every step is
        functional — engine state is assigned only from its returns, so
        a failed dispatch leaves nothing half-written."""
        chaos = self.chaos
        if chaos is None or chaos.cfg.device_err <= 0:
            return fn(*args)
        attempt = 0
        while True:
            try:
                if chaos.fires("device_err"):
                    raise ChaosDeviceError("chaos: injected device error")
                return fn(*args)
            except ChaosDeviceError:
                attempt += 1
                if attempt >= chaos.cfg.device_max_retries:
                    raise
                chaos.count_retry()
                if chaos.cfg.device_backoff_s > 0:
                    time.sleep(chaos.cfg.device_backoff_s
                               * (2 ** (attempt - 1)))

    def _decode_segment(self, actives: List[Request],
                        max_steps: Optional[int] = None) -> List[Request]:
        """Plain decode segment: no slot can exceed its budget before the
        earliest one finishes, so no host sync inside the segment. Also
        the floor of the spec degrade ladder — when a spec engine runs it
        (some slot's reservation has no lookahead), tokens log into the
        spec log (width 1) so materialization stays uniform.

        ``max_steps`` clamps the segment (chunked prefill runs one-step
        segments so prompt chunks interleave at token granularity).

        Two-deep dispatch (DESIGN.md §14): the boundary does NOT wait
        for this segment's tokens — it retires the *previous* segment's
        final array (a trailing copy, typically already complete since
        this segment's dispatches queued behind it) and leaves this one
        in flight. Host accounting needs no token values (budgets are
        host-side counters; values are only read at materialization or
        a preemption fold, both of which sync implicitly), so the host
        is always one segment ahead of the device — and issues strictly
        fewer ``block_until_ready`` calls than segments dispatched,
        pinned by the telemetry sync-count test."""
        sch = self.scheduler
        tracer = self.tel.tracer
        t0 = self.metrics.now()
        seg = max(1, min(r.remaining for r in actives))
        if max_steps is not None:
            seg = min(seg, max_steps)
        finished: List[Request] = []
        with tracer.span("decode_segment") as seg_sp:
            with tracer.annotate("decode_segment"):
                for _ in range(seg):
                    self._tokens, self._positions, self.kv.data, \
                        self._rng = self._dispatch(
                            self._decode_fn,
                            self.params, self.kv.data, self._tokens,
                            self._positions, self._block_tables,
                            self._active, self._rng, self._max_live)
                    if self.spec:
                        idx = self._log_spec(self._tokens[:, None],
                                             self._active)
                    else:
                        idx = len(self._token_log)
                        self._token_log.append(self._tokens)
                    for r in sch.active():
                        if r.state == DECODE:
                            r.log_entries.append(idx)
                    finished.extend(sch.step_decoded())
            self._inflight.append(self._tokens)
            if len(self._inflight) > 1:
                with tracer.span("sync", cat="sync"):
                    while len(self._inflight) > 1:
                        jax.block_until_ready(self._inflight.popleft())
            seg_sp.set(steps=seg, slots=len(actives),
                       tokens=seg * len(actives))
            if tracer.enabled:
                for r in actives:
                    tracer.flow_point(r.rid, "decode_segment",
                                      t=seg_sp.t0)
        self.metrics.decode_steps += seg
        self.metrics.record_decode_segment(self.metrics.now() - t0,
                                           seg * len(actives))
        return finished

    def _spec_segment(self, actives: List[Request]) -> List[Request]:
        """Speculative segment: interleave fused draft calls with one
        multi-token verify call per round. Every round emits 1..K+1
        tokens per active slot (K = chain length or tree depth,
        device-clamped to the slot's budget), so
        ceil(min_remaining / (K+1)) rounds can never overshoot the
        earliest budget — the host syncs once at the boundary, exactly
        like the plain segment loop. Tree mode additionally picks the
        segment's fanout profile from the adaptive ladder (the jitted
        step pairs are memoized per fanout, so profile flips never
        recompile)."""
        sch = self.scheduler
        tracer = self.tel.tracer
        t0 = self.metrics.now()
        # pressure degrade (DESIGN.md §12.2): the segment's speculative
        # shape may not write past the SMALLEST lookahead reservation
        # among its active slots — degraded admissions clamp the whole
        # segment (to chain K=1, or to plain decode at lookahead 0)
        seg_la = min(self.kv.slot_lookahead(r.slot) for r in actives)
        if seg_la < self._full_lookahead:
            self._c_degraded.inc()
            if seg_la <= 0:
                return self._decode_segment(actives)
        if self._spec_tree:
            from repro.engine.spec import tree_step_fns
            if seg_la >= self._full_lookahead:
                fanout = self._segment_fanout()
            else:
                # deepest chain whose tentative verify writes fit the
                # smallest reservation
                fanout = (1,) * min(seg_la, self._tree_depth)
            draft_fn, verify_fn, tpl = tree_step_fns(
                self.cfg, self.sampling, self.ecfg.use_pallas, fanout,
                self.ecfg.spec_draft_layers)
            k, width = tpl.depth, tpl.n_nodes + 1
            draft_dispatches = tpl.depth          # root + frontier calls
        else:
            k = min(self.ecfg.spec_k, seg_la)
            if k == self.ecfg.spec_k:
                draft_fn, verify_fn = self._draft_fn, self._verify_fn
            else:
                from repro.engine.spec import spec_step_fns
                draft_fn, verify_fn = spec_step_fns(
                    self.cfg, self.sampling, self.ecfg.use_pallas, k,
                    self.ecfg.spec_draft_layers)
            width = k + 1
            draft_dispatches = 1                  # one fused K-step call
        rounds = max(1, -(-min(r.remaining for r in actives) // (k + 1)))
        round_idxs: List[int] = []
        with tracer.span("spec_segment") as seg_sp:
            for _ in range(rounds):
                # per-round spans are dispatch-only (cat "dispatch"): the
                # segment stays sync-free, so they time async enqueue,
                # not device work — the device side comes from the
                # profiler annotations / named scopes
                with tracer.span("draft", cat="dispatch"), \
                        tracer.annotate("draft"):
                    draft = self._dispatch(
                        draft_fn,
                        self.draft_params, self.kv.data, self._tokens,
                        self._positions, self._block_tables,
                        self._max_live)
                with tracer.span("verify", cat="dispatch"), \
                        tracer.annotate("verify"):
                    (out, n_new, self._tokens, self._positions,
                     self._remaining, self.kv.data, self._rng) = \
                        self._dispatch(
                        verify_fn,
                        self.params, self.kv.data, self._tokens, draft,
                        self._positions, self._block_tables, self._active,
                        self._remaining, self._rng, self._max_live)
                idx = self._log_spec(out, n_new)
                round_idxs.append(idx)
                for r in sch.active():
                    if r.state == DECODE:
                        r.log_entries.append(idx)
            with tracer.span("sync", cat="sync"):
                jax.block_until_ready(self._tokens)    # segment boundary
            # the round replay below reads n_new on the host, so spec
            # segments sync at their own boundary — anything a plain
            # segment left in flight is older than this sync (one
            # device stream) and retires with it
            self._inflight.clear()
            seg_tokens = 0
            for idx in round_idxs:                     # replay the rounds
                n_new_h = np.asarray(self._spec_log[idx][1])
                proposed, accepted = sch.step_spec_round(n_new_h, k)
                slot_rounds = int((n_new_h > 0).sum())
                self.metrics.record_spec_round(
                    proposed, accepted, slot_rounds=slot_rounds,
                    verify_tokens=width * slot_rounds)
                if self.ecfg.spec_adaptive:
                    self._update_accept_ewma(n_new_h, k)
                seg_tokens += int(n_new_h.sum())
            seg_sp.set(rounds=rounds, k=k, slots=len(actives),
                       tokens=seg_tokens)
            if tracer.enabled:
                for r in actives:
                    tracer.flow_point(r.rid, "spec_segment", t=seg_sp.t0)
        # draft dispatches + verify dispatches (for dispatch accounting;
        # spec_rounds tracks rounds)
        self.metrics.decode_steps += (draft_dispatches + 1) * rounds
        self.metrics.record_decode_segment(self.metrics.now() - t0,
                                           seg_tokens)
        return sch.collect_finished()

    def _segment_fanout(self) -> Tuple[int, ...]:
        """Adaptive tree budget: the MIN of the active slots' acceptance
        EWMAs picks the ladder rung (conservative — thrash anywhere
        shrinks the whole batch's tree; the tree shape is one static
        jitted program per segment, so per-slot budgets resolve at
        segment granularity)."""
        if len(self._fanout_ladder) == 1:
            return self._pick_rung(0)
        act = [i for i, s in enumerate(self.scheduler.slots)
               if s.request is not None and s.request.state == DECODE]
        a = min(self._accept_ewma[i] for i in act) if act else 1.0
        if a < self.SPEC_EWMA_LOW:
            return self._pick_rung(0)
        if a >= self.SPEC_EWMA_HIGH:
            return self._pick_rung(2)
        return self._pick_rung(1)

    def _pick_rung(self, idx: int) -> Tuple[int, ...]:
        """Publish the chosen ladder rung: transition counter + gauge +
        a trace instant marking the segment where the tree reshaped."""
        if idx != self._ladder_rung:
            if self._ladder_rung is not None:
                self._c_ladder_flips.inc()
            self._ladder_rung = idx
            self.tel.tracer.instant(
                "spec_ladder", rung=idx,
                fanout=str(self._fanout_ladder[idx]))
        self._g_ladder.set(idx)
        return self._fanout_ladder[idx]

    def _update_accept_ewma(self, n_new: np.ndarray, k: int) -> None:
        """Fold one round's per-slot acceptance fraction ((n_new - 1)/K,
        the budget-clamp tail reads as rejection — acceptable noise for a
        control signal) into the per-slot EWMAs."""
        reg = self.tel.registry
        for i in range(self.ecfg.num_slots):
            if n_new[i] > 0:
                rate = min(max((float(n_new[i]) - 1.0) / max(k, 1), 0.0),
                           1.0)
                self._accept_ewma[i] = (self.SPEC_EWMA_BETA
                                        * self._accept_ewma[i]
                                        + (1 - self.SPEC_EWMA_BETA) * rate)
                reg.gauge(f"spec.accept_ewma.slot{i}").set(
                    float(self._accept_ewma[i]))

    # -- internals ----------------------------------------------------------

    def _do_prefill(self, admitted: List[Request]) -> None:
        b = self.ecfg.num_slots
        tracer = self.tel.tracer
        # chunked prefill (DESIGN.md §14): an admitted prompt whose
        # unshared tail exceeds the chunk budget does NOT prefill here —
        # it enters PREFILLING and feeds one chunk per scheduling
        # boundary (_feed_prefill_chunks), interleaved with the other
        # slots' decode steps. Tails that fit one chunk keep the
        # monolithic paths below (their cost is bounded by the budget,
        # and the batched flash prefill keeps its MFU).
        budget = self.ecfg.prefill_chunk_tokens
        if budget > 0:
            rest = []
            for r in admitted:
                sh = self.kv.slot_shared_tokens(r.slot)
                if len(plan_chunks(sh, r.prompt_len, budget)) > 1:
                    r.state = PREFILLING
                    r.prefill_pos = sh
                    self.metrics.record_admit(r.rid)
                else:
                    rest.append(r)
            admitted = rest
            if not admitted:
                # PREFILLING slots changed the admission picture (their
                # device rows must mask out of decode dispatches)
                self._sync_slot_state()
                return
        # prefix-cache split (DESIGN.md §13): slots whose prompt prefix
        # was mapped to cached pages at admission prefill only the
        # unshared tail (a ragged multi-token decode block against the
        # already-populated paged prefix); the rest take the batched
        # flash prefill as before. Two dispatch groups, one boundary.
        shared = [r for r in admitted
                  if self.kv.slot_shared_tokens(r.slot) > 0]
        full = [r for r in admitted
                if self.kv.slot_shared_tokens(r.slot) == 0]
        merged = self._tokens
        lengths_all = np.zeros((b,), np.int32)
        mask_all = np.zeros((b,), bool)
        idx_of: Dict[int, int] = {}       # rid -> token-log index
        for r in admitted:
            self.metrics.record_admit(r.rid)
            lengths_all[r.slot] = r.prompt_len
            mask_all[r.slot] = True
        if full:
            # cap the pow2 bucket at max_seq: prompt_len <= max_seq is
            # enforced at submit, wider buckets are pure waste
            s = min(_bucket(max(r.prompt_len for r in full),
                            self.ecfg.prompt_bucket_min), self.ecfg.max_seq)
            tokens = np.zeros((b, s), np.int32)
            lengths = np.zeros((b,), np.int32)
            # non-group slots must be invisible to the prefill scatter:
            # their rows get length 0 + all-sentinel block tables
            bt = np.full_like(self.kv.block_tables, self.kv.sentinel)
            mask = np.zeros((b,), bool)
            for r in full:
                tokens[r.slot, :r.prompt_len] = r.prompt
                lengths[r.slot] = r.prompt_len
                bt[r.slot] = self.kv.block_tables[r.slot]
                mask[r.slot] = True
            with tracer.span("prefill") as sp, tracer.annotate("prefill"):
                first, self.kv.data, self._rng = self._dispatch(
                    self._prefill_fn,
                    self.params, self.kv.data, jnp.asarray(tokens),
                    jnp.asarray(lengths), jnp.asarray(bt), self._rng)
                jax.block_until_ready(first)
                sp.set(admitted=len(full), bucket=s,
                       tokens=len(full),
                       prompt_tokens=int(lengths.sum()))
                if tracer.enabled:
                    for r in full:
                        tracer.flow_point(r.rid, "prefill", t=sp.t0)
            if self.spec:
                idx = self._log_spec(first[:, None],
                                     jnp.asarray(mask.astype(np.int32)))
            else:
                idx = len(self._token_log)
                self._token_log.append(first)
            for r in full:
                idx_of[r.rid] = idx
            merged = jnp.where(jnp.asarray(mask), first, merged)
        if shared:
            # unshared tails, padded to one pow2 T; feed_len masks the
            # padding's K/V writes (sentinel convention), so rows of
            # different tail lengths ride one dispatch safely
            t_pad = min(_bucket(max(r.prompt_len
                                    - self.kv.slot_shared_tokens(r.slot)
                                    for r in shared),
                                self.ecfg.prompt_bucket_min),
                        self.ecfg.max_seq)
            toks = np.zeros((b, t_pad), np.int32)
            starts = np.zeros((b,), np.int32)
            feed = np.zeros((b,), np.int32)
            bt = np.full_like(self.kv.block_tables, self.kv.sentinel)
            mask = np.zeros((b,), bool)
            hit_tokens = 0
            for r in shared:
                sh = self.kv.slot_shared_tokens(r.slot)
                n = r.prompt_len - sh
                toks[r.slot, :n] = r.prompt[sh:]
                starts[r.slot] = sh
                feed[r.slot] = n
                bt[r.slot] = self.kv.block_tables[r.slot]
                mask[r.slot] = True
                hit_tokens += sh
            occ = int((bt != self.kv.sentinel).sum(1).max())
            max_live = min(_bucket(max(occ, 1), 1),
                           self.kv.max_pages_per_slot)
            with tracer.span("prefill_tail") as sp, \
                    tracer.annotate("prefill_tail"):
                first_t, self.kv.data, self._rng = self._dispatch(
                    self._tail_fn,
                    self.params, self.kv.data, jnp.asarray(toks),
                    jnp.asarray(starts), jnp.asarray(feed),
                    jnp.asarray(bt), self._rng, max_live)
                jax.block_until_ready(first_t)
                sp.set(admitted=len(shared), bucket=t_pad,
                       tail_tokens=int(feed.sum()),
                       shared_tokens=hit_tokens)
                if tracer.enabled:
                    for r in shared:
                        tracer.flow_point(r.rid, "prefill_tail", t=sp.t0)
            if self.spec:
                idx = self._log_spec(first_t[:, None],
                                     jnp.asarray(mask.astype(np.int32)))
            else:
                idx = len(self._token_log)
                self._token_log.append(first_t)
            for r in shared:
                idx_of[r.rid] = idx
            merged = jnp.where(jnp.asarray(mask), first_t, merged)
        # the prompts' full-page K/V blocks are now all written (cached
        # prefix + freshly prefilled remainder): cache them BEFORE any
        # budget-exhausted request below releases its pages
        if self.kv.prefix is not None:
            for r in admitted:
                self.kv.prefix_insert(r.slot, r.prompt)
        t = self.metrics.now()
        done_now = []
        for r in admitted:
            r.state = DECODE
            # prefill produced the NEXT token: #1 for a fresh request,
            # #folded+1 for a preempted one resuming from its folded
            # prompt (produced == folded at re-admission)
            r.produced += 1
            r.log_entries = [idx_of[r.rid]]
            self.metrics.record_first_token(r.rid, t)
            if r.produced >= r.max_new_tokens:   # budget exhausted already
                self.metrics.record_finish(r.rid, t, r.produced)
                done_now.append(r)
        for r in done_now:
            self.scheduler.finish(r)
            if self._source is not None:   # closed-loop completion feedback
                self._source.on_finish(t - self.metrics.start_t)
        # merge the admitted slots into the device-side decode state
        self._tokens = merged
        self._positions = jnp.where(jnp.asarray(mask_all),
                                    jnp.asarray(lengths_all),
                                    self._positions)
        self._sync_slot_state()

    def _feed_prefill_chunks(self) -> None:
        """Advance every PREFILLING slot by one prompt chunk (DESIGN.md
        §14). A chunk is a ragged ``tail_fn`` feed whose start is the
        previous chunk's end — exactly the prefix-cache tail-prefill
        dispatch, so the kernels need no new mode and a prefix-shared
        prompt's first chunk simply starts at its shared boundary. All
        mid-chunk slots ride ONE dispatch; intermediate chunks discard
        the sampled token (it is not the first token — only the final
        chunk, which ends exactly at the prompt length, samples from
        the last real position and flips the request to DECODE via the
        same completion protocol as monolithic prefill). Intermediate
        chunks add no host sync: the dispatch queues behind the decode
        pipeline and the boundary's trailing sync covers it."""
        sch = self.scheduler
        chunking = [r for r in sch.active() if r.state == PREFILLING]
        if not chunking:
            return
        budget = self.ecfg.prefill_chunk_tokens
        b = self.ecfg.num_slots
        tracer = self.tel.tracer
        lens = {r.rid: plan_chunks(r.prefill_pos, r.prompt_len,
                                   budget)[0][1] for r in chunking}
        t_pad = min(_bucket(max(lens.values()),
                            self.ecfg.prompt_bucket_min),
                    self.ecfg.max_seq)
        toks = np.zeros((b, t_pad), np.int32)
        starts = np.zeros((b,), np.int32)
        feed = np.zeros((b,), np.int32)
        bt = np.full_like(self.kv.block_tables, self.kv.sentinel)
        finals: List[Request] = []
        for r in chunking:
            n = lens[r.rid]
            toks[r.slot, :n] = r.prompt[r.prefill_pos:r.prefill_pos + n]
            starts[r.slot] = r.prefill_pos
            feed[r.slot] = n
            # chunk-granular page exposure: only pages covering tokens
            # this chunk can touch (prefix + fed-so-far + the chunk)
            bt[r.slot] = self.kv.slot_block_table(r.slot,
                                                  r.prefill_pos + n)
            r.prefill_pos += n
            if r.prefill_pos >= r.prompt_len:
                finals.append(r)
        occ = int((bt != self.kv.sentinel).sum(1).max())
        max_live = min(_bucket(max(occ, 1), 1),
                       self.kv.max_pages_per_slot)
        with tracer.span("prefill_chunk") as sp, \
                tracer.annotate("prefill_chunk"):
            first_t, self.kv.data, self._rng = self._dispatch(
                self._tail_fn,
                self.params, self.kv.data, jnp.asarray(toks),
                jnp.asarray(starts), jnp.asarray(feed),
                jnp.asarray(bt), self._rng, max_live)
            if finals:
                # completed prefills take their TTFT timestamp here, so
                # the first token must actually exist (same convention
                # as the monolithic prefill block)
                jax.block_until_ready(first_t)
            sp.set(slots=len(chunking), bucket=t_pad,
                   chunk_tokens=int(feed.sum()), completed=len(finals))
            if tracer.enabled:
                for r in chunking:
                    tracer.flow_point(r.rid, "prefill_chunk", t=sp.t0)
        self._c_chunks.inc(len(chunking))
        self._c_chunk_tokens.inc(int(feed.sum()))
        if not finals:
            return
        fmask = np.zeros((b,), bool)
        lengths = np.zeros((b,), np.int32)
        for r in finals:
            fmask[r.slot] = True
            lengths[r.slot] = r.prompt_len
        if self.spec:
            idx = self._log_spec(first_t[:, None],
                                 jnp.asarray(fmask.astype(np.int32)))
        else:
            idx = len(self._token_log)
            self._token_log.append(first_t)
        # prefix-insert timing audit (DESIGN.md §14): under chunking a
        # prompt's full-page blocks are only all written at its LAST
        # chunk — inserting earlier would cache pages whose K/V another
        # request could map before this slot writes them
        if self.kv.prefix is not None:
            for r in finals:
                self.kv.prefix_insert(r.slot, r.prompt)
        t = self.metrics.now()
        done_now = []
        for r in finals:
            r.state = DECODE
            r.produced += 1
            r.log_entries = [idx]
            self.metrics.record_first_token(r.rid, t)
            if r.produced >= r.max_new_tokens:   # budget exhausted already
                self.metrics.record_finish(r.rid, t, r.produced)
                done_now.append(r)
        for r in done_now:
            self.scheduler.finish(r)
            if self._source is not None:
                self._source.on_finish(t - self.metrics.start_t)
        self._tokens = jnp.where(jnp.asarray(fmask), first_t,
                                 self._tokens)
        self._positions = jnp.where(jnp.asarray(fmask),
                                    jnp.asarray(lengths),
                                    self._positions)
        self._sync_slot_state()

    def _log_spec(self, toks: jnp.ndarray, counts: jnp.ndarray) -> int:
        """Append a (tokens [B, W], counts [B]) pair to the spec log,
        width-padded to the max round width (chain K+1 / tree depth+1)
        so materialization is one stack per array."""
        w = self._spec_width
        if toks.shape[1] < w:
            toks = jnp.pad(toks, ((0, 0), (0, w - toks.shape[1])))
        self._spec_log.append((toks, counts))
        return len(self._spec_log) - 1

    def _sync_slot_state(self) -> None:
        """Refresh device copies of the block tables + active mask +
        per-slot budgets after a scheduling event (admission/eviction).

        PREFILLING slots (mid-chunk, DESIGN.md §14) get all-sentinel
        rows in the DECODE-side block tables: a decode/draft/verify
        dispatch samples every row, and without the mask its K/V
        scatter at the slot's stale position would corrupt the pages
        the chunk feeds are writing. Chunk dispatches build their own
        tables from the real ``kv.block_tables``."""
        # the copy is load-bearing under two-deep dispatch: jnp.asarray
        # of a host numpy array may be ZERO-COPY on CPU, and
        # kv.block_tables is mutated in place by assign/release — an
        # aliased device view would change under still-in-flight steps
        # (the old loop's per-boundary block_until_ready hid this)
        bts = self.kv.block_tables.copy()
        mid = [i for i, s in enumerate(self.scheduler.slots)
               if s.request is not None
               and s.request.state == PREFILLING]
        if mid:
            bts[mid, :] = self.kv.sentinel
        self._block_tables = jnp.asarray(bts)
        # static clamp for the decode-side page gather / kernel grid: the
        # batch's max occupied page count, pow2-bucketed so the jitted
        # steps retrace at most log2(max_pages_per_slot) times
        occ = int((self.kv.block_tables != self.kv.sentinel).sum(1).max())
        new_max_live = min(_bucket(max(occ, 1), 1),
                           self.kv.max_pages_per_slot)
        if new_max_live != self._max_live:
            # max_live is a static jit arg: every change retraces the
            # decode/draft/verify steps (pow2-bucketed, so bounded by
            # log2(max_pages_per_slot) over an engine lifetime)
            self._c_retraces.inc()
            self.tel.tracer.instant("jit_retrace", max_live=new_max_live)
        self._max_live = new_max_live
        act = np.zeros((self.ecfg.num_slots,), np.int32)
        rem = np.zeros((self.ecfg.num_slots,), np.int32)
        for i, slot in enumerate(self.scheduler.slots):
            if slot.request is not None and slot.request.state == DECODE:
                act[i] = 1
                rem[i] = slot.request.remaining
        self._active = jnp.asarray(act)
        self._remaining = jnp.asarray(rem)

    def _materialize(self) -> List[Dict]:
        """One host sync: stack the token log and slice every request's
        generated tokens out of it (completion order)."""
        if self.spec:
            return self._materialize_spec()
        if self._token_log:
            mat = np.asarray(jnp.stack(self._token_log))
        else:
            mat = np.zeros((0, self.ecfg.num_slots), np.int32)
        out = []
        for r in self.scheduler.finished:
            toks = mat[np.asarray(r.log_entries, np.int64), r.slot] \
                if r.log_entries else np.zeros((0,), np.int32)
            toks = toks[:r.produced - r.folded]
            if r.folded:
                # tokens generated before a preemption live in the folded
                # prompt — the output is their concatenation with the
                # post-resume log (DESIGN.md §12.1)
                toks = np.concatenate([r.prompt[r.orig_prompt_len:], toks])
            r.output = toks.astype(np.int32)
            out.append({"rid": r.rid, "prompt_len": r.orig_prompt_len,
                        "tokens": r.output, "n_generated": r.produced})
        return out

    def _materialize_spec(self) -> List[Dict]:
        """Spec-mode materialization: entries are (tokens [B, K+1],
        counts [B]) — a request's generation is the concatenation of its
        rounds' accepted slices (two host transfers total)."""
        if self._spec_log:
            mat = np.asarray(jnp.stack([a for a, _ in self._spec_log]))
            cnt = np.asarray(jnp.stack([c for _, c in self._spec_log]))
        else:
            mat = np.zeros((0, self.ecfg.num_slots, 1), np.int32)
            cnt = np.zeros((0, self.ecfg.num_slots), np.int32)
        out = []
        for r in self.scheduler.finished:
            if r.log_entries:
                toks = np.concatenate(
                    [mat[i, r.slot, :cnt[i, r.slot]] for i in r.log_entries])
            else:
                toks = np.zeros((0,), np.int32)
            toks = toks[:r.produced - r.folded]
            if r.folded:
                toks = np.concatenate([r.prompt[r.orig_prompt_len:], toks])
            r.output = toks.astype(np.int32)
            out.append({"rid": r.rid, "prompt_len": r.orig_prompt_len,
                        "tokens": r.output, "n_generated": r.produced})
        return out
