"""Self-speculative decoding (DESIGN.md §4).

The engine drafts K tokens per round with a second, more aggressively
compressed GQSA parameter set of the SAME checkpoint (the draft profile,
``core/model_compress.py:compress_draft``), then verifies all K in one
multi-token target step and keeps the longest accepted prefix plus a
correction/bonus token. Verification is lossless: greedy speculative
output is token-for-token identical to greedy non-speculative output
(``engine/sampling.py:spec_verify``).

    from repro.engine import EngineConfig, InferenceEngine
    from repro.core.model_compress import compress_draft
    draft = compress_draft(fp_params, cfg, profile="w4s75")
    eng = InferenceEngine(cfg, target_params,
                          EngineConfig(num_slots=4, spec_k=4),
                          draft_params=draft)

Token-TREE drafting (``EngineConfig.spec_fanout``, engine/spec/tree.py,
DESIGN.md §8) spends the same verify budget on top-k branches per draft
step — higher expected accepted length per verify dispatch whenever the
drafter's top-1 is unsure; ``spec_adaptive`` retunes the tree online
from the observed acceptance rate.
"""
from repro.engine.spec.drafter import build_draft_fn, spec_step_fns
from repro.engine.spec.tree import (TreeTemplate, build_tree_draft_fn,
                                    build_tree_verify_fn, compact_accepted,
                                    tree_step_fns)
from repro.engine.spec.verify import build_verify_fn

__all__ = ["build_draft_fn", "build_verify_fn", "spec_step_fns",
           "TreeTemplate", "build_tree_draft_fn", "build_tree_verify_fn",
           "compact_accepted", "tree_step_fns"]
