"""Token-TREE self-speculative decoding (DESIGN.md §8).

Chain drafting (engine/spec/drafter.py) wastes the verify dispatch on
every rejected suffix: one wrong token kills the whole tail. A token
TREE spends the same T = N+1 verify budget on top-k *branches* per draft
step — the target only has to match ONE of each node's children for the
walk to continue, so expected accepted length per verify dispatch rises
whenever the drafter's top-1 is unsure but its top-k covers the target.

Layout: the tree is flattened in BFS order into one block of N+1 tokens
(slot 0 = the pending token = the root; level ℓ's nodes contiguous,
children of a node contiguous). The block is written at cache positions
``pos .. pos + N`` — storage is slot-sequential, but RoPE runs at each
token's tree DEPTH and attention at its ANCESTOR BITMAP (bit i of
``anc[j]`` = BFS slot i on j's root path), so a node's K/V is rotated
for exactly the position it would hold in sequential decode and the
accepted root-to-leaf path can be *compacted* into the leading slots by
pure page-slot moves — no re-rotation, no page churn
(:func:`compact_accepted`).

One round = D+1 dispatches for 1..D+1 tokens (D = tree depth):

    draft:  1 root call + D-1 frontier calls (level ℓ feeds its n_ℓ
            nodes as one tree-attention block; top-f_ℓ expansion stays
            on device)
    verify: ONE T = N+1 tree-attention call with the target params;
            ``sampling.tree_verify`` walks the longest accepted path
            with sibling-set rejection sampling (lossless), then the
            path's K/V is compacted and the position advances by
            ``n_new`` — the rejected branches rewind by position only,
            exactly the chain invariant (DESIGN.md §4.2).

A chain is the fanout-all-1 special case and is bit-identical to the
PR 2 chain spec path (pinned by ``tests/test_spec_tree.py``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.sampling import SamplingParams, tree_verify
from repro.models.registry import get_model

# ancestor bitmaps ride in int32 lanes (kernel + jnp mask shift by the
# in-window offset), so a tree block can hold at most 31 fed tokens
MAX_TREE_TOKENS = 31


class TreeTemplate:
    """Static shape of a draft token tree: fanout per depth, BFS flat
    indexing, parent/child maps and per-node ancestor bitmaps.

    ``fanout = (4, 2, 2)`` means the root proposes 4 children, each of
    those 2, each of those 2 — 28 nodes, 16 leaves, depth 3, and a
    T = 29 verify block. ``(k,) * 1`` / ``(1,) * K`` are chains.
    """

    def __init__(self, fanout: Tuple[int, ...]):
        if not fanout or any(f < 1 for f in fanout):
            raise ValueError(f"fanout must be positive per depth: {fanout}")
        self.fanout = tuple(int(f) for f in fanout)
        self.depth = len(self.fanout)
        sizes = []
        n = 1
        for f in self.fanout:
            n *= f
            sizes.append(n)
        self.level_sizes = tuple(sizes)            # nodes per level 1..D
        self.n_nodes = sum(sizes)                  # N (root excluded)
        if self.n_nodes + 1 > MAX_TREE_TOKENS:
            raise ValueError(
                f"tree {fanout} needs {self.n_nodes + 1} fed tokens "
                f"(> {MAX_TREE_TOKENS}: ancestor bitmaps are int32)")
        # level_starts[ℓ] = BFS flat index of level ℓ's first node
        starts = [0, 1]
        for s in sizes[:-1]:
            starts.append(starts[-1] + s)
        self.level_starts = tuple(starts)          # length D + 1
        n1 = self.n_nodes + 1
        self.depths = np.zeros(n1, np.int32)
        self.parents = np.full(n1, -1, np.int32)
        self.child_start = np.full(n1, -1, np.int32)
        self.anc = np.zeros(n1, np.int32)
        self.anc[0] = 1                            # root sees itself
        for lvl in range(1, self.depth + 1):
            st, sz = self.level_starts[lvl], sizes[lvl - 1]
            f_in = self.fanout[lvl - 1]            # branching INTO lvl
            for m in range(sz):
                i = st + m
                self.depths[i] = lvl
                self.parents[i] = (0 if lvl == 1
                                   else self.level_starts[lvl - 1]
                                   + m // f_in)
                self.anc[i] = self.anc[self.parents[i]] | (1 << i)
        for lvl in range(1, self.depth):           # child maps (non-leaf)
            st, sz = self.level_starts[lvl], sizes[lvl - 1]
            f_out = self.fanout[lvl]
            for m in range(sz):
                self.child_start[st + m] = self.level_starts[lvl + 1] \
                    + m * f_out
        self.child_start[0] = 1

    def level_tree(self, lvl: int) -> dict:
        """The ``decode_step(tree=...)`` spec for feeding level ``lvl``'s
        nodes: window covers every BFS slot written so far."""
        st, sz = self.level_starts[lvl], self.level_sizes[lvl - 1]
        return {"depths": jnp.asarray(self.depths[st:st + sz]),
                "anc": jnp.asarray(self.anc[st:st + sz]),
                "window": st + sz, "start": st}

    def verify_tree(self) -> dict:
        """The spec for the full T = N+1 verify block."""
        return {"depths": jnp.asarray(self.depths),
                "anc": jnp.asarray(self.anc),
                "window": self.n_nodes + 1, "start": 0}


def build_tree_draft_fn(cfg, api, use_pallas: bool, tpl: TreeTemplate,
                        draft_layers: Optional[int] = None):
    """Returns draft_fn(draft_params, cache, tokens, positions,
    block_tables, max_live) -> tree tokens [B, N] (BFS order).

    Level-by-level greedy top-k expansion: the root call is a plain
    decode step; level ℓ's n_ℓ nodes are then fed as ONE tree-attention
    block (each node attends to the committed prefix + its own root
    path) and each node's logits propose its top-f_ℓ children — distinct
    by construction, which is what makes the verify's sibling-set
    rejection sampling exact. Like the chain drafter, draft K/V written
    into the shared pool never survives the round (the verify re-writes
    every fed slot with target K/V) and the whole expansion is unrolled
    at trace time, so a draft round costs D dispatches regardless of
    tree width.
    """
    dl = draft_layers if draft_layers is not None else cfg.n_layers
    dcfg = dataclasses.replace(cfg, n_layers=dl) if dl != cfg.n_layers \
        else cfg

    def draft_fn(draft_params, cache, tokens, positions, block_tables,
                 max_live=None):
        # trace-time-only phase name for device profiler alignment
        # (telemetry, DESIGN.md §10)
        with jax.named_scope("spec_tree_draft"):
            dcache = jax.tree_util.tree_map(lambda c: c[:dl], cache) \
                if dl != cfg.n_layers else cache
            logits, dcache = api.decode_step(
                draft_params, dcache, tokens[:, None], positions, dcfg,
                None, use_pallas, block_tables=block_tables,
                max_live_pages=max_live)
            levels = []
            for lvl, f in enumerate(tpl.fanout):
                _, top = jax.lax.top_k(logits, f)   # [B, n_prev, f]
                toks = top.reshape(top.shape[0], -1).astype(jnp.int32)
                levels.append(toks)                 # level lvl+1 tokens
                if lvl + 1 == tpl.depth:
                    break
                spec = tpl.level_tree(lvl + 1)
                logits, dcache = api.decode_step(
                    draft_params, dcache, toks,
                    positions + spec["start"], dcfg, None, use_pallas,
                    block_tables=block_tables, max_live_pages=max_live,
                    tree=spec)
            return jnp.concatenate(levels, axis=1)

    return draft_fn


def compact_accepted(cache, block_tables, positions, path, n_new,
                     page_size: int):
    """Move the accepted root-to-leaf path's K/V into the leading slots.

    The verify writes target K/V for every fed tree slot at cache
    positions ``pos + i`` (BFS slot i); sequential decode would have the
    i-th *accepted* token at ``pos + i``-th... position ``pos + i`` of
    the PATH. ``path [B, D]`` holds the accepted nodes' BFS slots, so
    token i of the path moves ``pos + path[:, i] -> pos + 1 + i``. K was
    RoPE-rotated at tree depth == its final position, so the move is a
    pure gather/scatter through the block tables: reads happen before
    writes (functional update), sources are at-or-right-of their
    destinations (``path[:, i] >= i + 1``), and rows past the accepted
    length scatter to the page-id sentinel and are dropped — inactive
    slots and rejected branches never touch a page (rewind stays
    positional, DESIGN.md §4.2).
    """
    b, dmax = path.shape
    i = jnp.arange(dmax, dtype=jnp.int32)[None, :]
    valid = i < (n_new[:, None] - 1)               # accepted drafts only
    src_pos = positions[:, None] + jnp.maximum(path, 1)
    dst_pos = positions[:, None] + 1 + i
    num_pages = jax.tree_util.tree_leaves(cache)[0].shape[1]
    src_page = jnp.take_along_axis(block_tables, src_pos // page_size,
                                   axis=1)
    src_off = src_pos % page_size
    dst_page = jnp.where(
        valid, jnp.take_along_axis(block_tables, dst_pos // page_size,
                                   axis=1), num_pages)
    dst_off = dst_pos % page_size

    def move(buf):                                 # [L, P, ps, ...]
        vals = buf[:, src_page, src_off]           # [L, B, D, ...]
        return buf.at[:, dst_page, dst_off].set(vals)

    return jax.tree_util.tree_map(move, cache)


def build_tree_verify_fn(cfg, api, sampling: SamplingParams,
                         use_pallas: bool, tpl: TreeTemplate):
    """Returns verify_fn(params, cache, tokens, tree_tokens, positions,
    block_tables, active, remaining, rng, max_live) ->
    (out [B, D+1], n_new [B], tokens', positions', remaining', cache,
    rng) — the tree analogue of ``spec/verify.py:build_verify_fn``:
    same signature shape, same device-side budget clamps, plus the
    accepted-path KV compaction."""

    def verify_fn(params, cache, tokens, tree_tokens, positions,
                  block_tables, active, remaining, rng, max_live=None):
        # trace-time-only phase names for device profiler alignment
        # (telemetry, DESIGN.md §10)
        with jax.named_scope("spec_tree_verify"):
            feed = jnp.concatenate([tokens[:, None], tree_tokens], axis=1)
            logits, cache = api.decode_step(
                params, cache, feed, positions, cfg, None, use_pallas,
                block_tables=block_tables, max_live_pages=max_live,
                tree=tpl.verify_tree())
            rng, sub = jax.random.split(rng)
            n_acc, out, path = tree_verify(logits, feed, tpl.fanout,
                                           tpl.child_start, sub, sampling)
            n_new = jnp.minimum(n_acc + 1, remaining) * active  # [B]
            nxt = jnp.take_along_axis(
                out, jnp.maximum(n_new - 1, 0)[:, None], axis=1)[:, 0]
            tokens = jnp.where(n_new > 0, nxt, tokens)
            # leaves are [L, P, ps, ...] for every paged layout (K/V
            # pools or the MLA latent pool) — compaction is the same
            # block-table move
            page_size = jax.tree_util.tree_leaves(cache)[0].shape[2]
            with jax.named_scope("tree_compact"):
                cache = compact_accepted(cache, block_tables, positions,
                                         path, n_new, page_size)
            positions = positions + n_new
            remaining = remaining - n_new
        return out, n_new, tokens, positions, remaining, cache, rng

    return verify_fn


@functools.lru_cache(maxsize=32)
def tree_step_fns(cfg, sampling: SamplingParams, use_pallas: bool,
                  fanout: Tuple[int, ...],
                  draft_layers: Optional[int] = None):
    """Jitted (draft_fn, verify_fn, template) triple, memoized per (model
    config, sampling, backend, fanout, draft depth) — the adaptive
    controller flips between fanout profiles without recompiling."""
    api = get_model(cfg)
    tpl = TreeTemplate(fanout)
    draft_fn = build_tree_draft_fn(cfg, api, use_pallas, tpl, draft_layers)
    verify_fn = build_tree_verify_fn(cfg, api, sampling, use_pallas, tpl)
    return (jax.jit(draft_fn, static_argnums=(5,)),
            jax.jit(verify_fn, static_argnums=(9,)), tpl)
