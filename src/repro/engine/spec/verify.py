"""The verify step: one multi-token target pass over the K draft tokens.

Feeds ``[t_last, d_1 .. d_K]`` (K+1 tokens) at positions
``pos .. pos + K`` through the target model in a single jitted call —
a per-slot short-prefill reusing the paged decode path
(``transformer.decode_step`` with T = K+1). Position i's logits are the
target distribution after the first i drafts, so all K acceptance tests
AND the bonus distribution come from one dispatch.

Rollback of a rejected suffix is purely positional: the new position is
``pos + n_new`` and the stale K/V beyond it is never read (the per-query
length masks it) and is overwritten by the next round — no page
alloc/free ever happens mid-request (DESIGN.md §4).

This is the CHAIN verify (one draft per position, staircase mask). The
token-TREE verify (``engine/spec/tree.py:build_tree_verify_fn``,
DESIGN.md §8) feeds a whole BFS tree block under an ancestor-bitmap
mask and adds an accepted-path KV compaction before the position
advance; at fanout 1 it reproduces this path bit for bit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.engine.sampling import SamplingParams, spec_verify


def build_verify_fn(cfg, api, sampling: SamplingParams, use_pallas: bool,
                    k: int):
    """Returns verify_fn(params, cache, tokens, draft_tokens, positions,
    block_tables, active, remaining, rng, max_live) ->
    (out [B, K+1], n_new [B], tokens', positions', remaining', cache, rng).

    ``remaining`` [B] is each slot's generation budget left; ``n_new`` is
    the number of tokens the round produced for each slot (0 for inactive
    or budget-exhausted slots — the device clamps, so the host can run
    whole segments of rounds without syncing).
    """

    def verify_fn(params, cache, tokens, draft_tokens, positions,
                  block_tables, active, remaining, rng, max_live=None):
        # trace-time-only phase name for device profiler alignment
        # (telemetry, DESIGN.md §10)
        with jax.named_scope("spec_verify"):
            feed = jnp.concatenate([tokens[:, None], draft_tokens], axis=1)
            logits, cache = api.decode_step(
                params, cache, feed, positions, cfg, None, use_pallas,
                block_tables=block_tables, max_live_pages=max_live)
            rng, sub = jax.random.split(rng)
            n_acc, out = spec_verify(logits, draft_tokens, sub, sampling)
            n_new = jnp.minimum(n_acc + 1, remaining) * active  # [B]
            # the round's last produced token is the next step's feed;
            # slots that produced nothing keep their pending token
            nxt = jnp.take_along_axis(
                out, jnp.maximum(n_new - 1, 0)[:, None], axis=1)[:, 0]
            tokens = jnp.where(n_new > 0, nxt, tokens)
            positions = positions + n_new        # rejected suffix: rewind
            remaining = remaining - n_new
        return out, n_new, tokens, positions, remaining, cache, rng

    return verify_fn
