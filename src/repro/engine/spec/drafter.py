"""The drafter: K greedy draft steps from the compressed draft model.

The draft model shares the target's paged KV pool (self-speculative
serving: one pool, one block table per slot): its attention reads the
*target-written* history below each slot's position. The drafter's own
K/V (for the tokens it feeds inside a round) lives only in the call's
functional cache value and is DISCARDED when the round ends — the verify
step re-writes every fed position with target K/V, so draft-quality K/V
never survives a round and the pool's committed prefix always holds
exactly what sequential target decode would have written.

Depth-pruned draft profiles (``w4l25`` etc.) run only the first
``draft_layers`` layers: the drafter reads/writes the leading layer
slices of the pool and the deeper layers are untouched (their fed-range
contents are stale either way until the verify scatter).

All K steps run inside ONE jitted call (the loop is unrolled at trace
time — K is small and static), so a draft round costs a single dispatch
regardless of K; the greedy argmax feedback never leaves the device.

This is the CHAIN drafter (one token per step). The token-TREE drafter
(``engine/spec/tree.py``, DESIGN.md §8) generalizes it to top-k branches
per step and is bit-identical to this path at fanout 1.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.engine.sampling import SamplingParams
from repro.engine.spec.verify import build_verify_fn
from repro.models.registry import get_model


def build_draft_fn(cfg, api, use_pallas: bool, k: int,
                   draft_layers: Optional[int] = None):
    """Returns draft_fn(draft_params, cache, tokens, positions,
    block_tables, max_live) -> draft_tokens [B, K].

    ``tokens`` [B] is each slot's last sampled-but-unfed token;
    ``positions`` [B] its write position. Greedy by construction: the
    draft distribution is a point mass, which keeps the verify step's
    rejection sampling exact for any target temperature. The cache
    argument is read-only from the caller's perspective (draft K/V is
    local to the call, see module docstring).
    """
    dl = draft_layers if draft_layers is not None else cfg.n_layers
    dcfg = dataclasses.replace(cfg, n_layers=dl) if dl != cfg.n_layers \
        else cfg

    def draft_fn(draft_params, cache, tokens, positions, block_tables,
                 max_live=None):
        # trace-time-only phase name for device profiler alignment
        # (telemetry, DESIGN.md §10)
        with jax.named_scope("spec_draft"):
            dcache = jax.tree_util.tree_map(lambda c: c[:dl], cache) \
                if dl != cfg.n_layers else cache
            toks = tokens
            drafts = []
            for j in range(k):
                logits, dcache = api.decode_step(
                    draft_params, dcache, toks[:, None], positions + j,
                    dcfg, None, use_pallas, block_tables=block_tables,
                    max_live_pages=max_live)
                toks = jnp.argmax(logits[:, -1, :],
                                  axis=-1).astype(jnp.int32)
                drafts.append(toks)
            return jnp.stack(drafts, axis=1)

    return draft_fn


@functools.lru_cache(maxsize=32)
def spec_step_fns(cfg, sampling: SamplingParams, use_pallas: bool, k: int,
                  draft_layers: Optional[int] = None):
    """Jitted (draft_fn, verify_fn) pair, memoized per (model config,
    sampling, backend, K, draft depth) exactly like the engine's
    ``_step_fns`` — a fresh engine per workload must not recompile."""
    api = get_model(cfg)
    draft_fn = build_draft_fn(cfg, api, use_pallas, k, draft_layers)
    verify_fn = build_verify_fn(cfg, api, sampling, use_pallas, k)
    # the trailing max_live (occupied-page clamp, see engine._step_fns)
    # is static in both
    return (jax.jit(draft_fn, static_argnums=(5,)),
            jax.jit(verify_fn, static_argnums=(9,)))
