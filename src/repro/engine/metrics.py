"""Serving metrics: TTFT, TPOT, tokens/s, p50/p99 request latency.

Timestamps are taken at *synchronization points* of the engine loop
(after the prefill block and after each decode segment's block), so they
measure completed device work, not async dispatch.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else float("nan")


@dataclasses.dataclass
class RequestTiming:
    enqueue_t: float = 0.0
    admit_t: float = 0.0
    first_token_t: float = 0.0       # TTFT reference: end of prefill
    finish_t: float = 0.0
    n_generated: int = 0

    @property
    def ttft_s(self) -> float:
        return self.first_token_t - self.enqueue_t

    @property
    def tpot_s(self) -> float:
        """Mean time per output token after the first."""
        n = max(self.n_generated - 1, 1)
        return (self.finish_t - self.first_token_t) / n

    @property
    def latency_s(self) -> float:
        return self.finish_t - self.enqueue_t


class EngineMetrics:
    def __init__(self):
        self.requests: Dict[int, RequestTiming] = {}
        self.start_t: Optional[float] = None
        self.end_t: Optional[float] = None
        self.decode_steps = 0
        # speculative decoding: rounds dispatched, drafts proposed/accepted,
        # per-slot verify dispatches and their total fed-token budget (the
        # tree/chain comparison currency: accepted length PER verify
        # dispatch at equal verify token budget, DESIGN.md §8)
        self.spec_rounds = 0
        self.draft_proposed = 0
        self.draft_accepted = 0
        self.spec_slot_rounds = 0
        self.spec_verify_tokens = 0
        # decode-phase wall time + tokens -> mean inter-token latency (the
        # burst-aware latency speculative decoding actually changes: TPOT
        # per request divides by tokens that may arrive K+1 at a time)
        self.decode_time_s = 0.0
        self.decode_tokens = 0

    def record_decode_segment(self, seconds: float, tokens: int) -> None:
        self.decode_time_s += seconds
        self.decode_tokens += tokens

    def record_spec_round(self, proposed: int, accepted: int,
                          slot_rounds: int = 0,
                          verify_tokens: int = 0) -> None:
        self.spec_rounds += 1
        self.draft_proposed += proposed
        self.draft_accepted += accepted
        self.spec_slot_rounds += slot_rounds
        self.spec_verify_tokens += verify_tokens

    def now(self) -> float:
        return time.perf_counter()

    def record_enqueue(self, rid: int) -> None:
        self.requests[rid] = RequestTiming(enqueue_t=self.now())

    def record_admit(self, rid: int) -> None:
        self.requests[rid].admit_t = self.now()

    def record_first_token(self, rid: int, t: float) -> None:
        self.requests[rid].first_token_t = t

    def record_finish(self, rid: int, t: float, n_generated: int) -> None:
        self.requests[rid].finish_t = t
        self.requests[rid].n_generated = n_generated

    def run_started(self) -> None:
        if self.start_t is None:
            self.start_t = self.now()

    def run_finished(self) -> None:
        self.end_t = self.now()

    def summary(self) -> Dict[str, float]:
        done = [r for r in self.requests.values() if r.finish_t > 0]
        toks = sum(r.n_generated for r in done)
        dt = ((self.end_t or self.now()) - (self.start_t or 0.0)) \
            if self.start_t is not None else float("nan")
        ttfts = [r.ttft_s for r in done]
        tpots = [r.tpot_s for r in done if r.n_generated > 1]
        lats = [r.latency_s for r in done]
        return {
            "requests": len(done),
            "tokens": toks,
            "seconds": dt,
            "tok_per_s": toks / max(dt, 1e-9),
            "decode_steps": self.decode_steps,
            "ttft_ms_p50": _pct(ttfts, 50) * 1e3,
            "ttft_ms_p99": _pct(ttfts, 99) * 1e3,
            "tpot_ms_p50": _pct(tpots, 50) * 1e3,
            "tpot_ms_p99": _pct(tpots, 99) * 1e3,
            "latency_ms_p50": _pct(lats, 50) * 1e3,
            "latency_ms_p99": _pct(lats, 99) * 1e3,
            "itl_ms_mean": (self.decode_time_s / self.decode_tokens * 1e3
                            if self.decode_tokens else float("nan")),
            "spec_rounds": self.spec_rounds,
            "draft_proposed": self.draft_proposed,
            "draft_accepted": self.draft_accepted,
            "acceptance_rate": (self.draft_accepted / self.draft_proposed
                                if self.draft_proposed else float("nan")),
            # mean accepted DRAFTS per per-slot verify dispatch (the
            # emitted correction/bonus token is on top of this)
            "accepted_len_mean": (self.draft_accepted
                                  / self.spec_slot_rounds
                                  if self.spec_slot_rounds
                                  else float("nan")),
            "verify_tokens": self.spec_verify_tokens,
        }

    def format_summary(self) -> str:
        s = self.summary()
        line = (f"served {s['requests']} requests, {s['tokens']} tokens in "
                f"{s['seconds']:.2f}s -> {s['tok_per_s']:.1f} tok/s | "
                f"TTFT p50 {s['ttft_ms_p50']:.1f}ms "
                f"p99 {s['ttft_ms_p99']:.1f}ms | "
                f"TPOT p50 {s['tpot_ms_p50']:.2f}ms "
                f"p99 {s['tpot_ms_p99']:.2f}ms | "
                f"latency p99 {s['latency_ms_p99']:.1f}ms")
        if self.spec_rounds:
            line += (f" | spec: {s['spec_rounds']} rounds, "
                     f"acceptance {s['acceptance_rate']:.0%}, "
                     f"accepted/verify {s['accepted_len_mean']:.2f}, "
                     f"ITL {s['itl_ms_mean']:.2f}ms")
        return line
