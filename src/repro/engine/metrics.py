"""Serving metrics: TTFT, TPOT, tokens/s, p50/p99 request latency.

Timestamps are taken at *synchronization points* of the engine loop
(after the prefill block and after each decode segment's block), so they
measure completed device work, not async dispatch.

Rebased on the telemetry registry (DESIGN.md §10): every aggregate is a
registry counter and every latency distribution a streaming log-bucketed
histogram, so ``summary()`` quantiles cost O(buckets) memory regardless
of how many requests stream through — the per-request dict holds only
in-flight bookkeeping (the timestamps a later record call still needs),
and the ``summary()`` key set is unchanged from the pre-registry
implementation (plus ``queue_wait_ms_p50/p99``, the admission
backpressure signal). When the engine runs with tracing enabled the
record calls double as the per-request flow/async event source.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

from repro.engine.telemetry import MetricsRegistry, SpanTracer


@dataclasses.dataclass
class RequestTiming:
    enqueue_t: float = 0.0
    admit_t: float = 0.0
    first_token_t: float = 0.0       # TTFT reference: end of prefill
    finish_t: float = 0.0
    n_generated: int = 0
    # load shedding (DESIGN.md §12): a shed request never finishes —
    # ``shed_t`` set (with finish_t left 0) marks it for the SLO ledger's
    # first-class ``shed`` verdict
    shed_t: float = 0.0
    shed_reason: str = ""

    @property
    def queue_wait_s(self) -> float:
        return self.admit_t - self.enqueue_t

    @property
    def ttft_s(self) -> float:
        return self.first_token_t - self.enqueue_t

    @property
    def tpot_s(self) -> float:
        """Mean time per output token after the first."""
        n = max(self.n_generated - 1, 1)
        return (self.finish_t - self.first_token_t) / n

    @property
    def latency_s(self) -> float:
        return self.finish_t - self.enqueue_t


def _counter_property(attr):
    """Expose a registry counter as a ``+=``-able int attribute (the
    engine's accounting style predates the registry; keep it)."""

    def get(self):
        return getattr(self, attr).value

    def set_(self, v):
        getattr(self, attr).value = v

    return property(get, set_)


class EngineMetrics:
    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 tracer: Optional[SpanTracer] = None):
        self.registry = registry if registry is not None else \
            MetricsRegistry()
        self.tracer = tracer if tracer is not None else SpanTracer()
        self.requests: Dict[int, RequestTiming] = {}
        self.start_t: Optional[float] = None
        self.end_t: Optional[float] = None
        r = self.registry
        self._c_dispatches = r.counter("engine.dispatches")
        self._c_enqueued = r.counter("engine.requests_enqueued")
        self._c_finished = r.counter("engine.requests_finished")
        self._c_tokens = r.counter("engine.tokens_generated")
        # speculative decoding: rounds dispatched, drafts
        # proposed/accepted, per-slot verify dispatches and their total
        # fed-token budget (the tree/chain comparison currency: accepted
        # length PER verify dispatch at equal budget, DESIGN.md §8)
        self._c_spec_rounds = r.counter("spec.rounds")
        self._c_draft_proposed = r.counter("spec.draft_proposed")
        self._c_draft_accepted = r.counter("spec.draft_accepted")
        self._c_spec_slot_rounds = r.counter("spec.slot_rounds")
        self._c_spec_verify_tokens = r.counter("spec.verify_tokens")
        # decode-phase wall time + tokens -> mean inter-token latency
        # (the burst-aware latency speculative decoding actually changes:
        # TPOT per request divides by tokens arriving K+1 at a time)
        self._c_decode_time = r.counter("engine.decode_time_s")
        self._c_decode_tokens = r.counter("engine.decode_tokens")
        # resilience (DESIGN.md §12): sheds and preemptions are outcomes
        # a summary must account for, not silent drops
        self._c_shed = r.counter("engine.requests_shed")
        self._c_preemptions = r.counter("engine.preemptions")
        self._h_queue_wait = r.histogram("engine.queue_wait_ms")
        self._h_ttft = r.histogram("engine.ttft_ms")
        self._h_tpot = r.histogram("engine.tpot_ms")
        self._h_latency = r.histogram("engine.latency_ms")

    decode_steps = _counter_property("_c_dispatches")
    spec_rounds = _counter_property("_c_spec_rounds")
    draft_proposed = _counter_property("_c_draft_proposed")
    draft_accepted = _counter_property("_c_draft_accepted")
    spec_slot_rounds = _counter_property("_c_spec_slot_rounds")
    spec_verify_tokens = _counter_property("_c_spec_verify_tokens")
    decode_tokens = _counter_property("_c_decode_tokens")

    @property
    def decode_time_s(self) -> float:
        return self._c_decode_time.value

    def record_decode_segment(self, seconds: float, tokens: int) -> None:
        self._c_decode_time.value += seconds
        self._c_decode_tokens.inc(tokens)

    def record_spec_round(self, proposed: int, accepted: int,
                          slot_rounds: int = 0,
                          verify_tokens: int = 0) -> None:
        self._c_spec_rounds.inc()
        self._c_draft_proposed.inc(proposed)
        self._c_draft_accepted.inc(accepted)
        self._c_spec_slot_rounds.inc(slot_rounds)
        self._c_spec_verify_tokens.inc(verify_tokens)

    def now(self) -> float:
        return time.perf_counter()

    def record_enqueue(self, rid: int, t: Optional[float] = None) -> None:
        """``t`` backdates the enqueue to the request's true arrival
        (timed admission polls its source at scheduling boundaries, so
        submit can lag arrival) — queue wait and TTFT measure from it."""
        t = self.now() if t is None else t
        self.requests[rid] = RequestTiming(enqueue_t=t)
        self._c_enqueued.inc()
        if self.tracer.enabled:
            self.tracer.flow_point(rid, "enqueue", t=t)
            self.tracer.async_begin("queue_wait", rid, t=t)

    def record_admit(self, rid: int) -> None:
        t = self.now()
        rt = self.requests[rid]
        if rt.admit_t > 0:
            return       # re-admission after preemption: keep first admit
        rt.admit_t = t
        self._h_queue_wait.record(rt.queue_wait_s * 1e3)
        if self.tracer.enabled:
            self.tracer.async_end("queue_wait", rid, t=t)

    def record_first_token(self, rid: int, t: float) -> None:
        rt = self.requests[rid]
        if rt.first_token_t > 0:
            return       # resumed re-prefill: TTFT is the FIRST token
        rt.first_token_t = t
        self._h_ttft.record(rt.ttft_s * 1e3)

    def record_preempt(self, rid: int) -> None:
        self._c_preemptions.inc()
        if self.tracer.enabled:
            self.tracer.flow_point(rid, "preempt")

    def record_shed(self, rid: int, t: float, reason: str = "deadline") \
            -> None:
        """A queued request was dropped without service: marks the
        timing record so the SLO ledger emits a ``shed`` verdict."""
        rt = self.requests[rid]
        rt.shed_t = t
        rt.shed_reason = reason
        self._c_shed.inc()
        if self.tracer.enabled:
            self.tracer.async_end("queue_wait", rid, t=t)
            self.tracer.flow_point(rid, "shed", t=t, final=True)

    def record_finish(self, rid: int, t: float, n_generated: int) -> None:
        rt = self.requests[rid]
        rt.finish_t = t
        rt.n_generated = n_generated
        self._c_finished.inc()
        self._c_tokens.inc(n_generated)
        self._h_latency.record(rt.latency_s * 1e3)
        if n_generated > 1:
            self._h_tpot.record(rt.tpot_s * 1e3)
        if self.tracer.enabled:
            self.tracer.flow_point(rid, "finish", t=t, final=True)

    def run_started(self) -> None:
        if self.start_t is None:
            self.start_t = self.now()

    def run_finished(self) -> None:
        self.end_t = self.now()

    def summary(self) -> Dict[str, float]:
        toks = self._c_tokens.value
        dt = ((self.end_t or self.now()) - (self.start_t or 0.0)) \
            if self.start_t is not None else float("nan")
        proposed = self._c_draft_proposed.value
        slot_rounds = self._c_spec_slot_rounds.value
        return {
            "requests": self._c_finished.value,
            "shed": self._c_shed.value,
            "preemptions": self._c_preemptions.value,
            "tokens": toks,
            "seconds": dt,
            "tok_per_s": toks / max(dt, 1e-9),
            "decode_steps": self.decode_steps,
            "queue_wait_ms_p50": self._h_queue_wait.quantile(50),
            "queue_wait_ms_p99": self._h_queue_wait.quantile(99),
            "ttft_ms_p50": self._h_ttft.quantile(50),
            "ttft_ms_p99": self._h_ttft.quantile(99),
            "tpot_ms_p50": self._h_tpot.quantile(50),
            "tpot_ms_p99": self._h_tpot.quantile(99),
            "latency_ms_p50": self._h_latency.quantile(50),
            "latency_ms_p99": self._h_latency.quantile(99),
            "itl_ms_mean": (self.decode_time_s / self.decode_tokens * 1e3
                            if self.decode_tokens else float("nan")),
            "spec_rounds": self.spec_rounds,
            "draft_proposed": proposed,
            "draft_accepted": self.draft_accepted,
            "acceptance_rate": (self.draft_accepted / proposed
                                if proposed else float("nan")),
            # mean accepted DRAFTS per per-slot verify dispatch (the
            # emitted correction/bonus token is on top of this)
            "accepted_len_mean": (self.draft_accepted / slot_rounds
                                  if slot_rounds else float("nan")),
            "verify_tokens": self.spec_verify_tokens,
        }

    def format_summary(self) -> str:
        s = self.summary()
        line = (f"served {s['requests']} requests, {s['tokens']} tokens in "
                f"{s['seconds']:.2f}s -> {s['tok_per_s']:.1f} tok/s | "
                f"queue p50 {s['queue_wait_ms_p50']:.1f}ms "
                f"p99 {s['queue_wait_ms_p99']:.1f}ms | "
                f"TTFT p50 {s['ttft_ms_p50']:.1f}ms "
                f"p99 {s['ttft_ms_p99']:.1f}ms | "
                f"TPOT p50 {s['tpot_ms_p50']:.2f}ms "
                f"p99 {s['tpot_ms_p99']:.2f}ms | "
                f"latency p99 {s['latency_ms_p99']:.1f}ms")
        if self.spec_rounds:
            line += (f" | spec: {s['spec_rounds']} rounds, "
                     f"acceptance {s['acceptance_rate']:.0%}, "
                     f"accepted/verify {s['accepted_len_mean']:.2f}, "
                     f"ITL {s['itl_ms_mean']:.2f}ms")
        if s["shed"] or s["preemptions"]:
            line += (f" | resil: {int(s['shed'])} shed, "
                     f"{int(s['preemptions'])} preempted")
        return line

    def format_stats(self, interval=None) -> str:
        """One-line periodic snapshot for ``--stats-interval``: progress
        counters plus the live gauges other subsystems publish into the
        shared registry (queue depth, free pages, spec ladder).

        ``interval``: a ``(dt_s, counter_deltas)`` pair from a registry
        :class:`~repro.engine.telemetry.SnapshotWindow` tick — appended
        as *interval rates* (tok/s, admissions/s over the window, not
        lifetime averages, which hide stalls on long runs)."""
        g = self.registry.gauge
        dt = (self.now() - self.start_t) if self.start_t else 0.0
        toks = self._c_tokens.value
        line = (f"t={dt:6.2f}s reqs {self._c_finished.value}"
                f"/{self._c_enqueued.value} toks {toks}"
                f" ({toks / max(dt, 1e-9):.1f}/s)"
                f" queue {int(g('sched.queue_depth').value)}"
                f" pages_free {int(g('kv.pages_free').value)}"
                f" dispatches {self.decode_steps}")
        if self.spec_rounds:
            p = self._c_draft_proposed.value
            acc = self._c_draft_accepted.value / p if p else float("nan")
            line += (f" spec_rounds {self.spec_rounds} accept {acc:.0%}"
                     f" rung {int(g('spec.ladder_rung').value)}")
        if interval is not None:
            dt_w, d = interval
            dt_w = max(dt_w, 1e-9)
            line += (f" | interval"
                     f" {d.get('engine.decode_tokens', 0) / dt_w:.1f} tok/s"
                     f" {d.get('sched.admissions', 0) / dt_w:.1f} adm/s"
                     f" {d.get('engine.dispatches', 0) / dt_w:.1f} disp/s")
        return line
