"""Token sampling: greedy / temperature / top-k / top-p (nucleus), and
the speculative-decoding verifies (:func:`spec_verify` — lossless
rejection sampling of a draft CHAIN against the target distribution;
:func:`tree_verify` — its token-TREE generalization with recursive
rejection-resampling over each node's sibling set, DESIGN.md §8).

``sample``, ``spec_verify`` and ``tree_verify`` are pure and
shape-stable, so they live INSIDE the jitted prefill/decode/verify
steps — sampled tokens never round-trip to the host (device-side token
feedback, DESIGN.md §3.4).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0     # 0 => greedy
    top_k: int = 0               # 0 => disabled
    top_p: float = 1.0           # 1 => disabled

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


def filter_logits(logits: jnp.ndarray, sp: SamplingParams) -> jnp.ndarray:
    """Temperature + top-k + top-p filtering: [..., V] -> [..., V] f32
    with filtered entries at -inf. The *target distribution* of both
    :func:`sample` and the speculative verify is softmax of this."""
    logits = logits.astype(jnp.float32) / sp.temperature
    if sp.top_k > 0 and sp.top_k < logits.shape[-1]:
        kth = jnp.sort(logits, axis=-1)[..., -sp.top_k][..., None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if sp.top_p < 1.0:
        sorted_l = jnp.sort(logits, axis=-1)[..., ::-1]        # descending
        probs = jax.nn.softmax(sorted_l, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # keep the smallest prefix with cumulative mass >= top_p (always
        # keep the first token); cutoff = logit of the last kept entry
        keep = cum - probs < sp.top_p
        cutoff = jnp.min(jnp.where(keep, sorted_l, jnp.inf), axis=-1,
                         keepdims=True)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return logits


def sample(logits: jnp.ndarray, rng: jnp.ndarray,
           sp: SamplingParams) -> jnp.ndarray:
    """logits: [B, V] -> tokens [B] int32. ``sp`` is static (closed over
    at trace time), so disabled filters compile to nothing."""
    if sp.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(rng, filter_logits(logits, sp),
                                  axis=-1).astype(jnp.int32)


def spec_verify(logits: jnp.ndarray, draft: jnp.ndarray, rng: jnp.ndarray,
                sp: SamplingParams):
    """Speculative-decoding acceptance (DESIGN.md §4): lossless rejection
    sampling of K greedy draft tokens against K+1 target distributions.

    logits: [B, K+1, V] target logits at the K+1 fed positions (position i
    is the target distribution for the token AFTER the first i accepted
    drafts); draft: [B, K] greedy draft proposals. Returns
    ``(n_acc [B] int32, out [B, K+1] int32)``: ``n_acc`` in [0, K] is the
    accepted prefix length and ``out[:, :n_acc]`` are the accepted drafts,
    ``out[:, n_acc]`` the bonus/correction token — a round always yields
    ``n_acc + 1`` tokens; entries past that are unspecified.

    Losslessness: with temperature 0 a draft is accepted iff it equals the
    target argmax and the correction IS the target argmax, so the output
    is token-for-token the non-speculative greedy sequence. With
    temperature > 0 this is Leviathan-style rejection sampling with a
    point-mass draft distribution q = 1{x = draft}: accept with
    probability min(1, p(x)/q(x)) = p(draft); on rejection resample from
    the residual norm(max(p - q, 0)) = p with the rejected token zeroed.
    Either way each emitted token is distributed exactly as the target
    p — the draft model only ever changes throughput, never the output
    distribution.
    """
    b, k1, v = logits.shape
    k = k1 - 1
    tgt = jnp.argmax(logits, axis=-1).astype(jnp.int32)        # [B, K+1]
    if sp.greedy:
        match = (draft == tgt[:, :k]).astype(jnp.int32)
        n_acc = jnp.sum(jnp.cumprod(match, axis=-1), axis=-1)
        # accepted drafts == target argmaxes, and tgt[n_acc] is exactly the
        # correction (first mismatch) / bonus (all matched) token
        return n_acc.astype(jnp.int32), tgt
    probs = jax.nn.softmax(filter_logits(logits, sp), axis=-1)  # [B,K+1,V]
    p_draft = jnp.take_along_axis(probs[:, :k, :], draft[..., None],
                                  axis=-1)[..., 0]              # [B, K]
    r_accept, r_resample = jax.random.split(rng)
    u = jax.random.uniform(r_accept, (b, k))
    accept = (u < p_draft).astype(jnp.int32)
    n_acc = jnp.sum(jnp.cumprod(accept, axis=-1), axis=-1)      # [B]
    # residual distribution at every candidate stop index i < K: the
    # target with the rejected draft token's mass removed (q is a point
    # mass, so max(p - q, 0) just zeroes that token); index K (all
    # accepted) keeps the full target as the bonus distribution
    iota = jax.lax.broadcasted_iota(jnp.int32, (b, k1, v), 2)
    drafted = jnp.concatenate(
        [draft, jnp.full((b, 1), -1, jnp.int32)], axis=1)       # [B, K+1]
    residual = jnp.where(iota == drafted[..., None], 0.0, probs)
    resample = jax.random.categorical(
        r_resample, jnp.log(jnp.maximum(residual, 1e-30)),
        axis=-1).astype(jnp.int32)                              # [B, K+1]
    # out[:, i] = draft token for i < n_acc, the resampled correction at
    # i == n_acc (or the bonus draw at i == K)
    idx = jnp.arange(k1, dtype=jnp.int32)[None, :]
    draft_pad = jnp.concatenate(
        [draft, jnp.zeros((b, 1), jnp.int32)], axis=1)
    out = jnp.where(idx < n_acc[:, None], draft_pad, resample)
    return n_acc.astype(jnp.int32), out


def tree_verify(logits: jnp.ndarray, feed: jnp.ndarray, fanout, child_start,
                rng: jnp.ndarray, sp: SamplingParams):
    """Token-TREE speculative verification (DESIGN.md §8): walk the draft
    tree root-to-leaf, at each node rejection-sampling over its SIBLING
    SET, and emit the longest target-accepted path plus one
    correction/bonus token — lossless for any target temperature.

    logits: [B, N+1, V] target logits at the N+1 fed tree slots (slot i's
    logits are the target distribution AFTER the root-to-i path); feed:
    [B, N+1] the fed tokens (slot 0 = the pending token, slots 1..N the
    BFS tree); ``fanout`` (static tuple) and ``child_start`` (static
    [N+1] first-child flat index, -1 at leaves) describe the tree shape.
    Returns ``(n_acc [B], out [B, D+1], path [B, D])`` with D =
    len(fanout): ``out[:, :n_acc]`` are the accepted path tokens,
    ``out[:, n_acc]`` the correction/bonus (a round always yields
    ``n_acc + 1`` tokens; later entries are unspecified), ``path[:, i]``
    the flat tree slot of the i-th accepted token (for the engine's KV
    compaction; entries at/after ``n_acc`` are unspecified).

    Losslessness: at every node the candidates are the node's distinct
    children (greedy top-k drafts). Candidate j is accepted with
    probability ``r(d_j) / sum(r)`` where r is the target with all
    previously rejected siblings' mass zeroed — exactly chained
    point-mass rejection sampling, so each emitted token is distributed
    as the target regardless of the draft; if every sibling is rejected
    the correction is drawn from the final residual. At temperature 0
    this degenerates to "step to the child that IS the target argmax,
    else emit the argmax" — token-for-token sequential greedy. A chain
    (fanout all 1) reproduces :func:`spec_verify` exactly.
    """
    b, n1, v = logits.shape
    depth = len(fanout)
    cs = jnp.asarray(child_start, jnp.int32)                # [N+1]
    cur = jnp.zeros((b,), jnp.int32)                        # current node
    alive = jnp.ones((b,), jnp.bool_)
    n_acc = jnp.zeros((b,), jnp.int32)
    out = jnp.zeros((b, depth + 1), jnp.int32)
    path = jnp.zeros((b, depth), jnp.int32)

    def at(arr2d, idx):
        return jnp.take_along_axis(arr2d, idx[:, None], axis=1)[:, 0]

    if sp.greedy:
        tgt = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # [B, N+1]
        for i, f in enumerate(fanout):
            cb = jnp.take(cs, cur)                           # [B]
            t_cur = at(tgt, cur)
            cand = jnp.stack([at(feed, cb + j) for j in range(f)], 1)
            match = cand == t_cur[:, None]
            hit = jnp.any(match, axis=1)
            jidx = jnp.argmax(match, axis=1).astype(jnp.int32)
            step = alive & hit
            # accepted child token == target argmax == the correction on
            # a miss, so alive rows emit t_cur either way
            out = out.at[:, i].set(jnp.where(alive, t_cur, out[:, i]))
            path = path.at[:, i].set(jnp.where(step, cb + jidx, 0))
            n_acc = n_acc + step.astype(jnp.int32)
            cur = jnp.where(step, cb + jidx, cur)
            alive = step
        out = out.at[:, depth].set(
            jnp.where(alive, at(tgt, cur), out[:, depth]))
        return n_acc, out, path

    probs = jax.nn.softmax(filter_logits(logits, sp), axis=-1)  # [B,N+1,V]
    iota_v = jnp.arange(v, dtype=jnp.int32)[None, :]
    for i, f in enumerate(fanout):
        p = jnp.take_along_axis(probs, cur[:, None, None], axis=1)[:, 0]
        r = p                                                # residual
        acc = jnp.full((b,), -1, jnp.int32)
        cb = jnp.take(cs, cur)
        cand = []
        for j in range(f):
            tok_j = at(feed, cb + j)
            cand.append(tok_j)
            rs = jnp.maximum(jnp.sum(r, axis=-1), 1e-30)
            pj = jnp.take_along_axis(r, tok_j[:, None], axis=1)[:, 0] / rs
            rng, sub = jax.random.split(rng)
            u = jax.random.uniform(sub, (b,))
            acc = jnp.where((acc < 0) & (u < pj), j, acc)
            # rows still rejecting zero this sibling's mass (point-mass
            # residual: norm(max(p - q, 0)) = p with the token removed)
            r = jnp.where((acc < 0)[:, None] & (iota_v == tok_j[:, None]),
                          0.0, r)
        rng, sub = jax.random.split(rng)
        corr = jax.random.categorical(
            sub, jnp.log(jnp.maximum(r, 1e-30)), axis=-1).astype(jnp.int32)
        step = alive & (acc >= 0)
        jidx = jnp.maximum(acc, 0)
        tok_acc = at(jnp.stack(cand, 1), jidx)
        out = out.at[:, i].set(
            jnp.where(alive, jnp.where(step, tok_acc, corr), out[:, i]))
        path = path.at[:, i].set(jnp.where(step, cb + jidx, 0))
        n_acc = n_acc + step.astype(jnp.int32)
        cur = jnp.where(step, cb + jidx, cur)
        alive = step
    p_fin = jnp.take_along_axis(probs, cur[:, None, None], axis=1)[:, 0]
    rng, sub = jax.random.split(rng)
    bonus = jax.random.categorical(
        sub, jnp.log(jnp.maximum(p_fin, 1e-30)), axis=-1).astype(jnp.int32)
    out = out.at[:, depth].set(jnp.where(alive, bonus, out[:, depth]))
    return n_acc, out, path
