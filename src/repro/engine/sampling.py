"""Token sampling: greedy / temperature / top-k / top-p (nucleus).

``sample`` is pure and shape-stable, so it lives INSIDE the jitted
prefill/decode steps — the sampled token never round-trips to the host
(device-side token feedback, DESIGN.md §3.4).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0     # 0 => greedy
    top_k: int = 0               # 0 => disabled
    top_p: float = 1.0           # 1 => disabled

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


def sample(logits: jnp.ndarray, rng: jnp.ndarray,
           sp: SamplingParams) -> jnp.ndarray:
    """logits: [B, V] -> tokens [B] int32. ``sp`` is static (closed over
    at trace time), so disabled filters compile to nothing."""
    if sp.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / sp.temperature
    if sp.top_k > 0 and sp.top_k < logits.shape[-1]:
        kth = jnp.sort(logits, axis=-1)[:, -sp.top_k][:, None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if sp.top_p < 1.0:
        sorted_l = jnp.sort(logits, axis=-1)[:, ::-1]          # descending
        probs = jax.nn.softmax(sorted_l, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # keep the smallest prefix with cumulative mass >= top_p (always
        # keep the first token); cutoff = logit of the last kept entry
        keep = cum - probs < sp.top_p
        cutoff = jnp.min(jnp.where(keep, sorted_l, jnp.inf), axis=-1,
                         keepdims=True)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)
