"""Token sampling: greedy / temperature / top-k / top-p (nucleus), and
the speculative-decoding verify (:func:`spec_verify` — lossless
rejection sampling of draft tokens against the target distribution).

``sample`` and ``spec_verify`` are pure and shape-stable, so they live
INSIDE the jitted prefill/decode/verify steps — sampled tokens never
round-trip to the host (device-side token feedback, DESIGN.md §3.4).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0     # 0 => greedy
    top_k: int = 0               # 0 => disabled
    top_p: float = 1.0           # 1 => disabled

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


def filter_logits(logits: jnp.ndarray, sp: SamplingParams) -> jnp.ndarray:
    """Temperature + top-k + top-p filtering: [..., V] -> [..., V] f32
    with filtered entries at -inf. The *target distribution* of both
    :func:`sample` and the speculative verify is softmax of this."""
    logits = logits.astype(jnp.float32) / sp.temperature
    if sp.top_k > 0 and sp.top_k < logits.shape[-1]:
        kth = jnp.sort(logits, axis=-1)[..., -sp.top_k][..., None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if sp.top_p < 1.0:
        sorted_l = jnp.sort(logits, axis=-1)[..., ::-1]        # descending
        probs = jax.nn.softmax(sorted_l, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # keep the smallest prefix with cumulative mass >= top_p (always
        # keep the first token); cutoff = logit of the last kept entry
        keep = cum - probs < sp.top_p
        cutoff = jnp.min(jnp.where(keep, sorted_l, jnp.inf), axis=-1,
                         keepdims=True)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return logits


def sample(logits: jnp.ndarray, rng: jnp.ndarray,
           sp: SamplingParams) -> jnp.ndarray:
    """logits: [B, V] -> tokens [B] int32. ``sp`` is static (closed over
    at trace time), so disabled filters compile to nothing."""
    if sp.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(rng, filter_logits(logits, sp),
                                  axis=-1).astype(jnp.int32)


def spec_verify(logits: jnp.ndarray, draft: jnp.ndarray, rng: jnp.ndarray,
                sp: SamplingParams):
    """Speculative-decoding acceptance (DESIGN.md §4): lossless rejection
    sampling of K greedy draft tokens against K+1 target distributions.

    logits: [B, K+1, V] target logits at the K+1 fed positions (position i
    is the target distribution for the token AFTER the first i accepted
    drafts); draft: [B, K] greedy draft proposals. Returns
    ``(n_acc [B] int32, out [B, K+1] int32)``: ``n_acc`` in [0, K] is the
    accepted prefix length and ``out[:, :n_acc]`` are the accepted drafts,
    ``out[:, n_acc]`` the bonus/correction token — a round always yields
    ``n_acc + 1`` tokens; entries past that are unspecified.

    Losslessness: with temperature 0 a draft is accepted iff it equals the
    target argmax and the correction IS the target argmax, so the output
    is token-for-token the non-speculative greedy sequence. With
    temperature > 0 this is Leviathan-style rejection sampling with a
    point-mass draft distribution q = 1{x = draft}: accept with
    probability min(1, p(x)/q(x)) = p(draft); on rejection resample from
    the residual norm(max(p - q, 0)) = p with the rejected token zeroed.
    Either way each emitted token is distributed exactly as the target
    p — the draft model only ever changes throughput, never the output
    distribution.
    """
    b, k1, v = logits.shape
    k = k1 - 1
    tgt = jnp.argmax(logits, axis=-1).astype(jnp.int32)        # [B, K+1]
    if sp.greedy:
        match = (draft == tgt[:, :k]).astype(jnp.int32)
        n_acc = jnp.sum(jnp.cumprod(match, axis=-1), axis=-1)
        # accepted drafts == target argmaxes, and tgt[n_acc] is exactly the
        # correction (first mismatch) / bonus (all matched) token
        return n_acc.astype(jnp.int32), tgt
    probs = jax.nn.softmax(filter_logits(logits, sp), axis=-1)  # [B,K+1,V]
    p_draft = jnp.take_along_axis(probs[:, :k, :], draft[..., None],
                                  axis=-1)[..., 0]              # [B, K]
    r_accept, r_resample = jax.random.split(rng)
    u = jax.random.uniform(r_accept, (b, k))
    accept = (u < p_draft).astype(jnp.int32)
    n_acc = jnp.sum(jnp.cumprod(accept, axis=-1), axis=-1)      # [B]
    # residual distribution at every candidate stop index i < K: the
    # target with the rejected draft token's mass removed (q is a point
    # mass, so max(p - q, 0) just zeroes that token); index K (all
    # accepted) keeps the full target as the bonus distribution
    iota = jax.lax.broadcasted_iota(jnp.int32, (b, k1, v), 2)
    drafted = jnp.concatenate(
        [draft, jnp.full((b, 1), -1, jnp.int32)], axis=1)       # [B, K+1]
    residual = jnp.where(iota == drafted[..., None], 0.0, probs)
    resample = jax.random.categorical(
        r_resample, jnp.log(jnp.maximum(residual, 1e-30)),
        axis=-1).astype(jnp.int32)                              # [B, K+1]
    # out[:, i] = draft token for i < n_acc, the resampled correction at
    # i == n_acc (or the bonus draw at i == K)
    idx = jnp.arange(k1, dtype=jnp.int32)[None, :]
    draft_pad = jnp.concatenate(
        [draft, jnp.zeros((b, 1), jnp.int32)], axis=1)
    out = jnp.where(idx < n_acc[:, None], draft_pad, resample)
    return n_acc.astype(jnp.int32), out
