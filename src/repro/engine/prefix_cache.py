"""Radix prefix cache over the paged KV pool (DESIGN.md §13).

Production traffic is millions of users hitting the same system prompts
and few-shot templates; before this module every request prefilled and
stored its own pages. The cache is a radix tree keyed on hashed
*full-page token blocks*: node at depth ``i`` caches the KV page of a
prompt's ``i``-th ``page_size``-token block, so a path from the root is
exactly a shared prompt prefix at page granularity. Admission walks the
tree (`PagedKVCache.assign`), maps the request's shared prefix to the
cached pages (one ``incref`` per page — the block table is already
per-request indirection, so sharing is free), and prefills only the
unshared tail; TTFT drops to the tail and pages-per-request drops to
the unshared pages.

Lifecycle rules:

* **insert** — after a request's prefill, its prompt's full-page blocks
  enter the tree; each newly cached page takes a *cache reference*, so
  it survives the owning slot's release (``free`` is decref — a page
  returns to the free list only at refcount 0).
* **copy-on-write** — cached pages are immutable. A request whose tail
  begins *inside* a cached block (a page-aligned full-prompt hit: the
  engine always recomputes at least the last prompt token to produce
  first-token logits) gets a private device copy of that page before
  the tail prefill writes into it (`PagedKVCache._copy_page`).
* **eviction** — under pool pressure, *unreferenced* cached prefixes
  (allocator refcount 1: only the cache holds them) are dropped
  leaf-first in LRU order, feeding the resilience ladder (DESIGN.md
  §12) one rung before degrade/preempt: `PagedKVCache.can_admit` counts
  evictable pages as free, and ``assign`` evicts just enough to fit.

Hash keying: children are keyed by ``hash(block.tobytes())`` and
verified against the stored tokens, so a (vanishingly rare) collision
reads as a cache miss / stops an insert instead of aliasing two
different prefixes onto one page.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.engine.telemetry import MetricsRegistry


class _Node:
    """One cached full-page token block. ``page`` is a pool page id on
    which the cache holds one allocator reference."""
    __slots__ = ("tokens", "page", "children", "parent", "last_used")

    def __init__(self, tokens: Optional[np.ndarray], page: Optional[int],
                 parent: Optional["_Node"]):
        self.tokens = tokens
        self.page = page
        self.children: Dict[int, "_Node"] = {}
        self.parent = parent
        self.last_used = 0


class PrefixCache:
    """Radix tree of cached prompt-prefix pages (see module docstring).

    The cache never allocates pages itself: it adopts pages a slot's
    prefill already wrote (``insert`` increfs them) and returns them to
    the pool on eviction (``allocator.free`` — the plain decref path,
    so the PR8 conservation law stays exact, refcount-weighted)."""

    def __init__(self, page_size: int, allocator,
                 registry: Optional[MetricsRegistry] = None):
        self.page_size = page_size
        self.allocator = allocator
        self._root = _Node(None, None, None)
        self._clock = 0                   # monotonic LRU clock
        self._n_nodes = 0
        reg = registry if registry is not None else MetricsRegistry()
        self._c_inserted = reg.counter("prefix.inserted_pages")
        self._c_evicted = reg.counter("prefix.evicted_pages")
        self._g_cached = reg.gauge("prefix.cached_pages")

    @property
    def cached_pages(self) -> int:
        return self._n_nodes

    @staticmethod
    def _key(block: np.ndarray) -> int:
        return hash(block.tobytes())

    def _touch(self, node: _Node) -> None:
        self._clock += 1
        node.last_used = self._clock

    # -- lookup / insert ----------------------------------------------------

    def match(self, prompt: np.ndarray, touch: bool = True) -> List[_Node]:
        """Longest cached chain of the prompt's full-page blocks (root
        first). ``touch`` refreshes LRU recency on the matched nodes —
        pass False for purely speculative checks (``can_admit``)."""
        prompt = np.asarray(prompt, np.int32)
        ps = self.page_size
        node, out = self._root, []
        for i in range(len(prompt) // ps):
            block = prompt[i * ps:(i + 1) * ps]
            child = node.children.get(self._key(block))
            if child is None or not np.array_equal(child.tokens, block):
                break
            if touch:
                self._touch(child)
            out.append(child)
            node = child
        return out

    def insert(self, prompt: np.ndarray, pages: Sequence[int]) -> int:
        """Cache the prompt's full-page blocks backed by ``pages`` (the
        owning slot's block-table order, so block ``i`` <-> ``pages[i]``
        — valid K/V for every block fully inside the prompt). Blocks
        already cached keep their existing page; each NEW node takes a
        cache reference on the slot's page. Returns nodes added."""
        prompt = np.asarray(prompt, np.int32)
        ps = self.page_size
        node, added = self._root, 0
        for i in range(min(len(prompt) // ps, len(pages))):
            block = prompt[i * ps:(i + 1) * ps]
            k = self._key(block)
            child = node.children.get(k)
            if child is not None:
                if not np.array_equal(child.tokens, block):
                    break                 # hash collision: stop extending
                node = child
                continue
            self.allocator.incref([pages[i]])
            child = _Node(block.copy(), int(pages[i]), node)
            self._touch(child)
            node.children[k] = child
            node = child
            added += 1
            self._n_nodes += 1
        if added:
            self._c_inserted.inc(added)
            self._g_cached.set(self._n_nodes)
        return added

    # -- eviction -----------------------------------------------------------

    def _iter_nodes(self):
        stack = list(self._root.children.values())
        while stack:
            n = stack.pop()
            yield n
            stack.extend(n.children.values())

    def evictable_count(self, exclude: Sequence[_Node] = ()) -> int:
        """Pages an eviction cascade could return to the pool right now:
        nodes only the cache references (allocator refcount 1). A
        refcount-1 node's descendants are all refcount-1 too (a slot
        referencing a deep block references its whole ancestor chain),
        so leaf-first eviction can always realize this count."""
        ex = {id(n) for n in exclude}
        return sum(1 for n in self._iter_nodes()
                   if id(n) not in ex
                   and self.allocator.refcount(n.page) == 1)

    def evict_for(self, n_pages: int, exclude: Sequence[_Node] = ()) -> int:
        """Drop up to ``n_pages`` unreferenced cached prefixes,
        leaf-first in LRU order (evicting a leaf may expose its parent).
        ``exclude`` pins nodes an in-flight admission is about to
        reference. Returns pages actually returned to the pool."""
        ex = {id(n) for n in exclude}
        freed = 0
        while freed < n_pages:
            leaves = [n for n in self._iter_nodes()
                      if not n.children and id(n) not in ex
                      and self.allocator.refcount(n.page) == 1]
            if not leaves:
                break
            self._drop(min(leaves, key=lambda n: n.last_used))
            freed += 1
        return freed

    def _drop(self, node: _Node) -> None:
        del node.parent.children[self._key(node.tokens)]
        self.allocator.free([node.page])
        self._n_nodes -= 1
        self._c_evicted.inc()
        self._g_cached.set(self._n_nodes)

    def flush(self) -> int:
        """Drop every cache reference (shutdown / tests): pages still
        referenced by running slots survive with their slot reference;
        the rest return to the free list."""
        n = 0
        for node in list(self._iter_nodes()):
            self.allocator.free([node.page])
            n += 1
        self._root.children.clear()
        self._n_nodes = 0
        self._g_cached.set(0)
        return n
