"""Task-centric continuous-batching scheduler (DESIGN.md §3.3).

Request lifecycle::

    QUEUED --admit--> PREFILL --first token--> DECODE --budget--> FINISHED
              ^                                           |
              '------------- slot + pages freed ----------'

Admission is strict FIFO: the head of the queue is admitted as soon as a
slot AND its full page reservation (prompt + generation budget) are
available; if the head doesn't fit, nothing behind it jumps ahead
(no head-of-line bypass — arrival order is the service order, pinned by a
regression test). Slots are evicted and refilled without stopping the
decode loop: the other slots keep decoding through every admission.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Deque, List, Optional

import numpy as np

from repro.engine.kv_cache import PagedKVCache
from repro.engine.telemetry import MetricsRegistry

QUEUED, PREFILL, DECODE, FINISHED = "queued", "prefill", "decode", "finished"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [prompt_len] int32
    max_new_tokens: int
    state: str = QUEUED
    slot: Optional[int] = None
    produced: int = 0                  # generated tokens (incl. prefill's)
    output: Optional[np.ndarray] = None
    # indices into the engine's device-side token log (one per token in
    # plain decode; one per draft/verify round in speculative decode)
    log_entries: List[int] = dataclasses.field(default_factory=list)
    # speculative-decoding accounting (drafts proposed/accepted for this
    # request — per-request acceptance feeds the engine metrics)
    draft_proposed: int = 0
    draft_accepted: int = 0
    # true arrival timestamp (metrics.now() clock) under timed admission:
    # the loadgen source polls at scheduling boundaries, so the request
    # may have arrived well before submit() ran — queue wait and TTFT
    # are measured from here (None: arrival == submit, the offline path)
    arrival_t: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def total_tokens(self) -> int:
        """Worst-case KV footprint: prompt + full generation budget."""
        return self.prompt_len + self.max_new_tokens

    @property
    def remaining(self) -> int:
        """Generation budget left — the request's *draft budget*: a
        speculative round may propose at most ``remaining - 1`` useful
        drafts (the round always emits >= 1 token), and the device clamps
        acceptance to exactly this many tokens."""
        return max(self.max_new_tokens - self.produced, 0)


@dataclasses.dataclass
class Slot:
    request: Optional[Request] = None
    position: int = 0                  # next KV write position

    @property
    def free(self) -> bool:
        return self.request is None


class Scheduler:
    def __init__(self, num_slots: int, kv: PagedKVCache, max_seq: int,
                 registry: Optional[MetricsRegistry] = None):
        self.kv = kv
        self.max_seq = max_seq
        self.slots: List[Slot] = [Slot() for _ in range(num_slots)]
        self.waiting: Deque[Request] = deque()
        self._ids = itertools.count()
        self.admission_order: List[int] = []   # rids, in service order
        self.finished: List[Request] = []
        # queue depth / admissions / evictions into the shared registry
        # (telemetry, DESIGN.md §10)
        reg = registry if registry is not None else MetricsRegistry()
        self._g_queue = reg.gauge("sched.queue_depth")
        self._g_active = reg.gauge("sched.active_slots")
        self._c_submitted = reg.counter("sched.submitted")
        self._c_admissions = reg.counter("sched.admissions")
        self._c_evictions = reg.counter("sched.evictions")

    def _sync_gauges(self) -> None:
        self._g_queue.set(len(self.waiting))
        self._g_active.set(sum(not s.free for s in self.slots))

    # -- queue side ---------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int,
               arrival_t: Optional[float] = None) -> int:
        req = Request(rid=next(self._ids),
                      prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=int(max_new_tokens),
                      arrival_t=arrival_t)
        if req.total_tokens > self.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt+budget {req.total_tokens} "
                f"exceeds max_seq {self.max_seq}")
        self.waiting.append(req)               # FIFO: append at the tail...
        self._c_submitted.inc()
        self._sync_gauges()
        return req.rid

    def has_work(self) -> bool:
        return bool(self.waiting) or any(not s.free for s in self.slots)

    # -- slot side ----------------------------------------------------------

    def admit(self) -> List[Request]:
        """Move queue-head requests into free slots while pages last.

        Returns the newly admitted requests (state PREFILL, slot set).
        Stops at the first request that doesn't fit — FIFO order is the
        service order, so nothing bypasses a blocked head (backpressure).
        """
        admitted: List[Request] = []
        free_slots = [i for i, s in enumerate(self.slots) if s.free]
        while self.waiting and free_slots:
            head = self.waiting[0]             # ...and serve from the head
            if not self.kv.can_admit(head.total_tokens):
                break                          # out-of-pages backpressure
            self.waiting.popleft()
            slot = free_slots.pop(0)
            self.kv.assign(slot, head.total_tokens)
            head.state = PREFILL
            head.slot = slot
            self.slots[slot].request = head
            self.slots[slot].position = head.prompt_len
            self.admission_order.append(head.rid)
            admitted.append(head)
        if admitted:
            self._c_admissions.inc(len(admitted))
        self._sync_gauges()
        return admitted

    def active(self) -> List[Request]:
        return [s.request for s in self.slots if not s.free]

    def step_decoded(self) -> List[Request]:
        """Account one decode token for every active slot; returns requests
        that just hit their budget (still occupying their slot)."""
        done = []
        for s in self.slots:
            if s.free:
                continue
            r = s.request
            r.produced += 1
            s.position += 1
            if r.produced >= r.max_new_tokens or s.position >= self.max_seq:
                done.append(r)
        return done

    def step_spec_round(self, n_new: np.ndarray, k: int):
        """Account one speculative draft/verify round: slot ``i`` produced
        ``n_new[i]`` tokens (0 for free / budget-exhausted slots — the
        device clamps to the draft budget, so overshoot is impossible).
        ``k`` is the round's max accepted DRAFTS per slot: the chain
        length, or the tree depth (a token tree proposes one root-to-leaf
        path's worth of acceptable drafts however wide it fans out).
        A request with ``remaining`` budget can usefully accept at most
        ``remaining - 1`` drafts, so proposals are clamped to that when
        counting acceptance (a budget cut-off is not a rejection).
        Returns the round's ``(proposed, accepted)`` totals. Completion is
        detected by :meth:`collect_finished` after the segment's rounds
        are replayed (a request may finish mid-segment and idle until the
        boundary)."""
        proposed_t = accepted_t = 0
        for i, s in enumerate(self.slots):
            if s.free:
                continue
            n = int(n_new[i])
            if n <= 0:
                continue
            r = s.request
            proposed = min(k, max(r.remaining - 1, 0))
            r.produced += n
            s.position += n
            r.draft_proposed += proposed
            r.draft_accepted += n - 1
            proposed_t += proposed
            accepted_t += n - 1
        return proposed_t, accepted_t

    def collect_finished(self) -> List[Request]:
        """Requests that hit their budget (still occupying their slot)."""
        return [s.request for s in self.slots
                if not s.free and (s.request.produced >=
                                   s.request.max_new_tokens
                                   or s.position >= self.max_seq)]

    def finish(self, req: Request) -> None:
        """Evict: free the slot + pages; the loop refills via admit()."""
        slot = req.slot
        self.kv.release(slot)
        self.slots[slot].request = None
        self.slots[slot].position = 0
        req.state = FINISHED
        self.finished.append(req)
        self._c_evictions.inc()
        self._sync_gauges()
