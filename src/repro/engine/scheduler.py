"""Task-centric continuous-batching scheduler (DESIGN.md §3.3, §12).

Request lifecycle::

    QUEUED --admit--> PREFILL --first token--> DECODE --budget--> FINISHED
      ^  ^               |                        |
      |  |               '--> PREFILLING ---------'   (chunked prefill,
      |  |                     |      ^ chunk          DESIGN.md §14: one
      |  '---- preempt <-------'------'--feeds---.     prompt chunk per
      |        (pages freed, tokens               |    boundary; the last
      |         folded into prompt)               |    chunk's sample is
      '--- submit                                 '--  the first token)
                                                  QUEUED --deadline--> SHED

Admission is FIFO within a priority band: the head of the queue is
admitted as soon as a slot AND its full page reservation (prompt +
generation budget + lookahead) are available; if the head doesn't fit,
nothing behind it jumps ahead (no head-of-line bypass — arrival order is
the service order within a band, pinned by a regression test). All
requests default to priority 0, so the historical pure-FIFO behaviour is
unchanged unless a workload opts into priorities. Slots are evicted and
refilled without stopping the decode loop: the other slots keep decoding
through every admission.

Resilience extensions (DESIGN.md §12): ``preempt`` returns a victim's
pages and re-enqueues it ahead of later same-band arrivals (its original
rid keeps its place), ``shed_expired`` drops queued requests whose TTFT
deadline already passed before prefill was dispatched, quarantined slots
sit out admission for a few boundaries after a poisoned-sampler fault,
and malformed submissions raise a typed :class:`RejectedRequest` instead
of failing deep inside prefill.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.engine.kv_cache import PagedKVCache
from repro.engine.resilience import RejectedRequest, TransientAllocFailure
from repro.engine.telemetry import MetricsRegistry

QUEUED, PREFILL, PREFILLING, DECODE, FINISHED, SHED = (
    "queued", "prefill", "prefilling", "decode", "finished", "shed")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [prompt_len] int32
    max_new_tokens: int
    state: str = QUEUED
    slot: Optional[int] = None
    produced: int = 0                  # generated tokens (incl. prefill's)
    output: Optional[np.ndarray] = None
    # indices into the engine's device-side token log (one per token in
    # plain decode; one per draft/verify round in speculative decode)
    log_entries: List[int] = dataclasses.field(default_factory=list)
    # speculative-decoding accounting (drafts proposed/accepted for this
    # request — per-request acceptance feeds the engine metrics)
    draft_proposed: int = 0
    draft_accepted: int = 0
    # true arrival timestamp (metrics.now() clock) under timed admission:
    # the loadgen source polls at scheduling boundaries, so the request
    # may have arrived well before submit() ran — queue wait and TTFT
    # are measured from here (None: arrival == submit, the offline path)
    arrival_t: Optional[float] = None
    # resilience (DESIGN.md §12): admission priority band (higher wins;
    # preemption requires a strict inversion), optional absolute TTFT
    # deadline on the metrics clock, and preempt-and-recompute state —
    # ``folded`` counts already-generated tokens folded into ``prompt``
    # so a re-prefill resumes the request exactly where it stopped
    priority: int = 0
    deadline_t: Optional[float] = None
    preemptions: int = 0
    folded: int = 0
    # chunked prefill (DESIGN.md §14): prompt tokens already fed into
    # the KV cache while the request is PREFILLING — the next chunk
    # starts here. Meaningless outside PREFILLING; reset on preemption
    # (re-prefill restarts the chunk ladder from the fold point).
    prefill_pos: int = 0

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def orig_prompt_len(self) -> int:
        """Length of the prompt as submitted (before any preemption
        folded generated tokens into it)."""
        return self.prompt_len - self.folded

    @property
    def total_tokens(self) -> int:
        """Worst-case KV footprint: original prompt + full generation
        budget. Invariant under preemption: folding moves tokens from
        the "to generate" side to the prompt side, but the positions the
        request will ever write are the same."""
        return self.prompt_len + self.max_new_tokens - self.folded

    @property
    def remaining(self) -> int:
        """Generation budget left — the request's *draft budget*: a
        speculative round may propose at most ``remaining - 1`` useful
        drafts (the round always emits >= 1 token), and the device clamps
        acceptance to exactly this many tokens."""
        return max(self.max_new_tokens - self.produced, 0)

    def sort_key(self):
        """Queue order: priority band first (higher served earlier),
        then rid — a preempted request keeps its original rid, so it
        re-enters ahead of everything that arrived after it."""
        return (-self.priority, self.rid)


@dataclasses.dataclass
class Slot:
    request: Optional[Request] = None
    position: int = 0                  # next KV write position

    @property
    def free(self) -> bool:
        return self.request is None


class Scheduler:
    def __init__(self, num_slots: int, kv: PagedKVCache, max_seq: int,
                 registry: Optional[MetricsRegistry] = None):
        self.kv = kv
        self.max_seq = max_seq
        self.slots: List[Slot] = [Slot() for _ in range(num_slots)]
        self.waiting: Deque[Request] = deque()
        self._ids = itertools.count()
        self.admission_order: List[int] = []   # rids, in service order
        self.finished: List[Request] = []
        self.shed: List[Request] = []
        # slot id -> scheduling boundaries left in quarantine (poisoned
        # sampler cooldown, DESIGN.md §12.3)
        self._quarantine: Dict[int, int] = {}
        # queue depth / admissions / evictions into the shared registry
        # (telemetry, DESIGN.md §10)
        reg = registry if registry is not None else MetricsRegistry()
        self._g_queue = reg.gauge("sched.queue_depth")
        self._g_active = reg.gauge("sched.active_slots")
        self._c_submitted = reg.counter("sched.submitted")
        self._c_admissions = reg.counter("sched.admissions")
        self._c_evictions = reg.counter("sched.evictions")
        self._c_rejected = reg.counter("sched.rejected")
        self._c_shed = reg.counter("sched.shed")
        self._c_preemptions = reg.counter("sched.preemptions")
        self._c_quarantines = reg.counter("sched.quarantines")

    def _sync_gauges(self) -> None:
        self._g_queue.set(len(self.waiting))
        self._g_active.set(sum(not s.free for s in self.slots))

    # -- queue side ---------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int,
               arrival_t: Optional[float] = None, priority: int = 0,
               deadline_t: Optional[float] = None) -> int:
        prompt = np.asarray(prompt, np.int32)
        max_new_tokens = int(max_new_tokens)
        # typed rejection BEFORE the request enters the queue: a request
        # that can never be served must not cost a slot, pages, or a
        # prefill dispatch to discover that (DESIGN.md §12)
        if prompt.ndim != 1 or prompt.shape[0] == 0:
            self._c_rejected.inc()
            raise RejectedRequest(
                f"empty or non-1D prompt (shape {prompt.shape})")
        if max_new_tokens <= 0:
            self._c_rejected.inc()
            raise RejectedRequest(
                f"max_new_tokens must be positive, got {max_new_tokens}")
        if prompt.shape[0] >= self.max_seq:
            self._c_rejected.inc()
            raise RejectedRequest(
                f"prompt length {prompt.shape[0]} leaves no room to "
                f"generate within max_seq {self.max_seq}")
        req = Request(rid=next(self._ids), prompt=prompt,
                      max_new_tokens=max_new_tokens, arrival_t=arrival_t,
                      priority=int(priority), deadline_t=deadline_t)
        if req.total_tokens > self.max_seq:
            self._c_rejected.inc()
            raise RejectedRequest(
                f"request {req.rid}: prompt+budget {req.total_tokens} "
                f"exceeds max_seq {self.max_seq}")
        self._enqueue(req)
        self._c_submitted.inc()
        self._sync_gauges()
        return req.rid

    def _enqueue(self, req: Request) -> None:
        """Insert keeping the queue sorted by (priority band, rid). The
        common case — everything priority 0, fresh rid — is a pure
        append, preserving the historical FIFO behaviour."""
        key = req.sort_key()
        if not self.waiting or self.waiting[-1].sort_key() < key:
            self.waiting.append(req)
            return
        for i, w in enumerate(self.waiting):
            if key < w.sort_key():
                self.waiting.insert(i, req)
                return
        self.waiting.append(req)

    def has_work(self) -> bool:
        return bool(self.waiting) or any(not s.free for s in self.slots)

    def shed_expired(self, now: float) -> List[Request]:
        """Drop queued requests whose TTFT deadline has already passed:
        prefill hasn't been dispatched, so TTFT >= now - arrival and the
        deadline is provably unmeetable — spending prefill FLOPs on the
        request only steals them from ones that can still meet theirs.
        Returns the shed requests (state SHED); the engine turns them
        into first-class SLO verdicts."""
        dropped = [r for r in self.waiting
                   if r.deadline_t is not None and now >= r.deadline_t]
        if dropped:
            keep = [r for r in self.waiting
                    if r.deadline_t is None or now < r.deadline_t]
            self.waiting = deque(keep)
            for r in dropped:
                r.state = SHED
                self.shed.append(r)
            self._c_shed.inc(len(dropped))
            self._sync_gauges()
        return dropped

    def shed_all(self) -> List[Request]:
        """Drop every queued request (graceful shutdown): the queue will
        never be served, so each entry becomes a shed verdict."""
        dropped = list(self.waiting)
        self.waiting.clear()
        for r in dropped:
            r.state = SHED
            self.shed.append(r)
        if dropped:
            self._c_shed.inc(len(dropped))
            self._sync_gauges()
        return dropped

    # -- slot side ----------------------------------------------------------

    def quarantine_slot(self, slot: int, boundaries: int) -> None:
        """Take a slot out of admission rotation for ``boundaries``
        scheduling boundaries (poisoned-sampler cooldown)."""
        self._quarantine[slot] = max(self._quarantine.get(slot, 0),
                                     int(boundaries))
        self._c_quarantines.inc()

    def tick_quarantine(self) -> None:
        """One scheduling boundary elapsed: count quarantines down."""
        for slot in list(self._quarantine):
            self._quarantine[slot] -= 1
            if self._quarantine[slot] <= 0:
                del self._quarantine[slot]

    def admit(self, lookahead: Optional[int] = None) -> List[Request]:
        """Move queue-head requests into free slots while pages last.

        ``lookahead`` overrides the cache-wide speculative lookahead for
        these reservations (pressure degrade, DESIGN.md §12.2); None
        reserves the full default. Returns the newly admitted requests
        (state PREFILL, slot set). Stops at the first request that
        doesn't fit — within a priority band arrival order is the
        service order, so nothing bypasses a blocked head
        (backpressure) — and at the first injected transient allocation
        failure (the head stays queued and retries next boundary).
        """
        admitted: List[Request] = []
        free_slots = [i for i, s in enumerate(self.slots)
                      if s.free and i not in self._quarantine]
        while self.waiting and free_slots:
            head = self.waiting[0]             # serve from the head
            # the prompt rides along so the prefix cache can map shared
            # full-page blocks to existing pages (DESIGN.md §13); for a
            # preempt-fold re-admit the folded prompt re-matches its
            # original prefix, so recompute shrinks to the tail
            if not self.kv.can_admit(head.total_tokens, lookahead,
                                     prompt=head.prompt):
                break                          # out-of-pages backpressure
            slot = free_slots[0]
            try:
                self.kv.assign(slot, head.total_tokens, lookahead,
                               prompt=head.prompt)
            except TransientAllocFailure:
                break                          # chaos: retry next boundary
            self.waiting.popleft()
            free_slots.pop(0)
            head.state = PREFILL
            head.slot = slot
            self.slots[slot].request = head
            self.slots[slot].position = head.prompt_len
            self.admission_order.append(head.rid)
            admitted.append(head)
        if admitted:
            self._c_admissions.inc(len(admitted))
        self._sync_gauges()
        return admitted

    def active(self) -> List[Request]:
        return [s.request for s in self.slots if not s.free]

    def step_decoded(self) -> List[Request]:
        """Account one decode token for every DECODE slot; returns requests
        that just hit their budget (still occupying their slot).
        PREFILLING slots (mid-chunk, DESIGN.md §14) sit the step out:
        their device rows are masked inactive, so no token advanced."""
        done = []
        for s in self.slots:
            if s.free or s.request.state != DECODE:
                continue
            r = s.request
            r.produced += 1
            s.position += 1
            if r.produced >= r.max_new_tokens or s.position >= self.max_seq:
                done.append(r)
        return done

    def step_spec_round(self, n_new: np.ndarray, k: int):
        """Account one speculative draft/verify round: slot ``i`` produced
        ``n_new[i]`` tokens (0 for free / budget-exhausted slots — the
        device clamps to the draft budget, so overshoot is impossible).
        ``k`` is the round's max accepted DRAFTS per slot: the chain
        length, or the tree depth (a token tree proposes one root-to-leaf
        path's worth of acceptable drafts however wide it fans out).
        A request with ``remaining`` budget can usefully accept at most
        ``remaining - 1`` drafts, so proposals are clamped to that when
        counting acceptance (a budget cut-off is not a rejection).
        Returns the round's ``(proposed, accepted)`` totals. Completion is
        detected by :meth:`collect_finished` after the segment's rounds
        are replayed (a request may finish mid-segment and idle until the
        boundary)."""
        proposed_t = accepted_t = 0
        for i, s in enumerate(self.slots):
            if s.free or s.request.state != DECODE:
                continue
            n = int(n_new[i])
            if n <= 0:
                continue
            r = s.request
            proposed = min(k, max(r.remaining - 1, 0))
            r.produced += n
            s.position += n
            r.draft_proposed += proposed
            r.draft_accepted += n - 1
            proposed_t += proposed
            accepted_t += n - 1
        return proposed_t, accepted_t

    def collect_finished(self) -> List[Request]:
        """Requests that hit their budget (still occupying their slot)."""
        return [s.request for s in self.slots
                if not s.free and s.request.state == DECODE
                and (s.request.produced >= s.request.max_new_tokens
                     or s.position >= self.max_seq)]

    def finish(self, req: Request) -> None:
        """Evict: free the slot + pages; the loop refills via admit()."""
        slot = req.slot
        self.kv.release(slot)
        self.slots[slot].request = None
        self.slots[slot].position = 0
        req.state = FINISHED
        self.finished.append(req)
        self._c_evictions.inc()
        self._sync_gauges()

    def preempt(self, req: Request) -> None:
        """Release a running request's slot and pages and re-enqueue it.
        The caller (engine) has already folded the generated tokens into
        ``req.prompt`` (DESIGN.md §12.1), so the re-prefill resumes it
        losslessly; its original rid puts it back ahead of later
        arrivals in its priority band."""
        slot = req.slot
        self.kv.release(slot)
        self.slots[slot].request = None
        self.slots[slot].position = 0
        req.slot = None
        req.state = QUEUED
        req.preemptions += 1
        req.prefill_pos = 0          # chunk ladder restarts on re-admit
        req.log_entries = []
        self._enqueue(req)
        self._c_preemptions.inc()
        self._sync_gauges()
