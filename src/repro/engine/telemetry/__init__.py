"""Engine observability layer (DESIGN.md §10).

Three pieces, one facade:

* :class:`SpanTracer` — named phase spans at the engine's existing sync
  points, exported as Chrome trace-event JSON (Perfetto-loadable), with
  per-request flow events tying enqueue -> prefill -> segments -> finish
  together across slices.
* :class:`MetricsRegistry` — counters, gauges and streaming log-bucketed
  histograms (quantiles without storing samples) shared by the KV cache,
  scheduler, spec ladder and :class:`~repro.engine.metrics.EngineMetrics`.
* profiler hooks — ``tracer.annotate`` wraps jitted dispatches in
  ``jax.profiler.TraceAnnotation`` (and the step functions themselves
  carry ``jax.named_scope`` phase names) so device traces line up with
  the host spans.

Everything is off by default and adds no device syncs either way::

    from repro.engine import InferenceEngine, EngineConfig
    from repro.engine.telemetry import Telemetry
    tel = Telemetry(trace=True, stats_interval_s=5.0)
    eng = InferenceEngine(cfg, params, EngineConfig(), telemetry=tel)
    ...
    eng.run()
    tel.tracer.export("trace.json")     # -> ui.perfetto.dev
    tel.registry.snapshot()             # -> {name: value}
"""
from __future__ import annotations

import math
import time
from typing import Optional

from repro.engine.telemetry.registry import (Counter, Gauge, MetricsRegistry,
                                             SnapshotWindow,
                                             StreamingHistogram)
from repro.engine.telemetry.tracer import (NULL_SPAN, SpanTracer, TID_ENGINE,
                                           TID_REQUESTS)


class Telemetry:
    """The engine's observability bundle: one tracer + one registry +
    the periodic-stats policy. The default construction is fully
    disabled tracing with a live (but unexported) registry — counters
    and gauges are cheap enough to always record."""

    def __init__(self, trace: bool = False,
                 registry: Optional[MetricsRegistry] = None,
                 stats_interval_s: float = 0.0,
                 annotate_device: Optional[bool] = None):
        self.tracer = SpanTracer(enabled=trace,
                                 annotate_device=annotate_device)
        self.registry = registry if registry is not None else \
            MetricsRegistry()
        self.stats_interval_s = float(stats_interval_s)
        # first boundary after enabling always emits one line (so short
        # runs still produce a stats line for smoke tests)
        self._last_stats = -math.inf
        # counter-delta window so periodic lines report interval rates
        # (tok/s, admissions/s since the previous line), not lifetime
        # cumulative averages that flatten stalls away
        self._window = self.registry.window() if self.stats_interval_s \
            else None

    def maybe_stats(self, metrics) -> None:
        """Called by the engine at segment boundaries: emit a one-line
        stats snapshot every ``stats_interval_s`` seconds of wall time
        (0 disables; never syncs — reads host counters only)."""
        if not self.stats_interval_s:
            return
        now = time.perf_counter()
        if now - self._last_stats >= self.stats_interval_s:
            self._last_stats = now
            print("[stats] " + metrics.format_stats(
                interval=self._window.tick()), flush=True)


__all__ = ["Telemetry", "SpanTracer", "MetricsRegistry", "Counter",
           "Gauge", "StreamingHistogram", "SnapshotWindow", "NULL_SPAN",
           "TID_ENGINE", "TID_REQUESTS"]
