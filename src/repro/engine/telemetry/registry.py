"""Metrics registry: counters, gauges and *streaming* histograms.

The registry is the engine's one shared sink for runtime signals —
page-pool occupancy, scheduler queue depth, spec-ladder state, jit
retraces — that scheduling policies (chunked prefill, adaptive
speculation, dynamic sparsity) read online and operators read as a
snapshot. Everything here is plain host arithmetic: a counter increment
is an int add, a gauge set is an assignment, a histogram record is one
``math.log`` plus a dict increment. Nothing ever touches a device array
or forces a sync, so metrics can be recorded inside the engine loop
without perturbing its dispatch structure (DESIGN.md §10).

:class:`StreamingHistogram` keeps log-spaced buckets instead of samples,
so TTFT/TPOT/latency quantiles over millions of requests cost O(buckets)
memory with a bounded *relative* error: ``quantile(q)`` returns the
geometric midpoint of the bucket holding the ``floor(q/100 * (n-1))``-th
order statistic (numpy's ``method="lower"`` rank), which is within a
``rel_error_bound`` multiplicative factor of that sample (pinned by a
property test in ``tests/test_telemetry.py``).
"""
from __future__ import annotations

import math
import time
from typing import Dict, Optional, Tuple


class Counter:
    """Monotonic (by convention) accumulator. ``value`` is directly
    readable and writable — :class:`~repro.engine.metrics.EngineMetrics`
    exposes some counters through ``+=``-able properties."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n=1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value (queue depth, free pages,
    acceptance EWMA, ladder rung)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v) -> None:
        self.value = float(v)


class StreamingHistogram:
    """Log-bucketed streaming histogram for non-negative samples.

    Bucket ``i`` covers ``[growth**i, growth**(i+1))``; values ``<= 0``
    land in an exact zero bucket (negative inputs are clamp-counted
    there, with their true value still folded into min/max/sum).
    ``quantile`` answers are clamped into ``[min, max]`` so degenerate
    streams (empty, single sample, all-equal) stay exact at the edges.
    """

    __slots__ = ("name", "growth", "_log_g", "_buckets", "_zero",
                 "count", "sum", "min", "max")

    def __init__(self, name: str = "", growth: float = 1.1):
        if growth <= 1.0:
            raise ValueError(f"growth must be > 1: {growth}")
        self.name = name
        self.growth = float(growth)
        self._log_g = math.log(self.growth)
        self._buckets: Dict[int, int] = {}
        self._zero = 0
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    @property
    def rel_error_bound(self) -> float:
        """Guaranteed multiplicative quantile error vs the underlying
        order statistic. The geometric-midpoint representative is within
        ``sqrt(growth)`` of any sample in its bucket; ``growth - 1``
        leaves margin for float fuzz at bucket boundaries."""
        return self.growth - 1.0

    def record(self, v) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if v <= 0.0:
            self._zero += 1
        else:
            i = math.floor(math.log(v) / self._log_g)
            self._buckets[i] = self._buckets.get(i, 0) + 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else float("nan")

    def quantile(self, q: float) -> float:
        """Approximate ``q``-th percentile (0..100): the bucket
        representative of the ``floor(q/100 * (count-1))``-th order
        statistic — numpy's ``np.percentile(xs, q, method="lower")``
        rank — within :attr:`rel_error_bound` relative error of it."""
        if self.count == 0:
            return float("nan")
        rank = int(math.floor(q / 100.0 * (self.count - 1)))
        rank = min(max(rank, 0), self.count - 1)
        if rank < self._zero:
            # the zero bucket is exact for the non-negative contract;
            # clamp covers the (discouraged) negative-input case
            return float(min(max(0.0, self.min), self.max))
        cum = self._zero
        for i in sorted(self._buckets):
            c = self._buckets[i]
            if rank < cum + c:
                try:
                    rep = math.exp((i + 0.5) * self._log_g)
                except OverflowError:
                    rep = math.inf
                return float(min(max(rep, self.min), self.max))
            cum += c
        return float(self.max)

    def snapshot(self) -> Dict[str, float]:
        return {"count": self.count, "mean": self.mean,
                "p50": self.quantile(50), "p90": self.quantile(90),
                "p99": self.quantile(99),
                "min": self.min if self.count else float("nan"),
                "max": self.max if self.count else float("nan")}


class SnapshotWindow:
    """Delta view over a registry's counters for *interval* reporting.

    Cumulative counters answer "since the run started"; a periodic
    stats line wants "since the last line" (a stalled engine looks
    healthy forever on lifetime totals). :meth:`tick` returns
    ``(dt_seconds, {counter_name: delta})`` since the previous tick
    (or construction), then advances the window. Gauges are already
    instantaneous and histograms cumulative by design — only counters
    need the delta treatment.
    """

    __slots__ = ("_reg", "_last_t", "_last")

    def __init__(self, registry: "MetricsRegistry"):
        self._reg = registry
        self._last_t = time.perf_counter()
        self._last: Dict[str, float] = {
            n: c.value for n, c in registry._counters.items()}

    def tick(self) -> Tuple[float, Dict[str, float]]:
        now = time.perf_counter()
        dt = now - self._last_t
        cur = {n: c.value for n, c in self._reg._counters.items()}
        deltas = {n: v - self._last.get(n, 0) for n, v in cur.items()}
        self._last, self._last_t = cur, now
        return dt, deltas


class MetricsRegistry:
    """Get-or-create registry of named counters/gauges/histograms.

    Handles are cached by name, so hot paths fetch them once at
    construction and pay only the increment afterwards; ad-hoc readers
    (the --stats-interval line, tests) can resolve by name at any time.
    """

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, StreamingHistogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str,
                  growth: Optional[float] = None) -> StreamingHistogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = StreamingHistogram(
                name, growth if growth is not None else 1.1)
        return h

    def window(self) -> SnapshotWindow:
        """A counter-delta window starting now (interval rates for the
        periodic stats line)."""
        return SnapshotWindow(self)

    def snapshot(self) -> Dict[str, float]:
        """Flat ``{name: value}`` view (histograms expand to
        ``name.count/.mean/.p50/.p90/.p99``)."""
        out: Dict[str, float] = {}
        for n, c in self._counters.items():
            out[n] = c.value
        for n, g in self._gauges.items():
            out[n] = g.value
        for n, h in self._hists.items():
            for k, v in h.snapshot().items():
                out[f"{n}.{k}"] = v
        return out
