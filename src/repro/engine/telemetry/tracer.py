"""Phase-span tracer with Chrome trace-event export (Perfetto-loadable).

The engine loop is host-driven and syncs only at segment boundaries
(DESIGN.md §3), so the tracer records two honest kinds of host span:

* spans that END at an existing ``block_until_ready`` (``prefill``,
  ``decode_segment``/``spec_segment``, ``sync``) measure *completed
  device work* — the same convention ``EngineMetrics`` timestamps use;
* spans inside a segment (``draft``, ``verify`` rounds) bracket only the
  *dispatch* — they carry ``cat: "dispatch"`` so a trace reader knows
  the device work completes later, at the segment's ``sync`` span.

The tracer NEVER forces a sync of its own: enabling it changes
timestamps taken, not the dispatch structure (pinned by a test counting
``jax.block_until_ready`` calls with tracing on vs off).

Per-request *flow events* (``ph: s/t/f``, one id per request) tie a
request's enqueue -> prefill -> decode segments -> finish across slices,
and its queue wait is an async ``b``/``e`` pair on the request track —
both render as arrows/tracks in Perfetto (load the JSON at
https://ui.perfetto.dev or chrome://tracing).

When disabled (the default), every hook returns a shared no-op span and
records nothing — zero per-segment overhead beyond one attribute check.
"""
from __future__ import annotations

import contextlib
import json
import time
from pathlib import Path
from typing import Dict, List, Optional

try:                                    # host-side device-trace annotation
    from jax.profiler import TraceAnnotation as _TraceAnnotation
except ImportError:                     # pragma: no cover - ancient jax
    _TraceAnnotation = None

# thread ids of the exported trace (one process, two logical tracks)
TID_ENGINE = 0
TID_REQUESTS = 1


class _NullSpan:
    """Shared no-op span: context manager + ``set()`` sink. Returned by
    every tracer hook when tracing is off so call sites never branch."""

    __slots__ = ()
    t0 = 0.0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **args):
        pass


NULL_SPAN = _NullSpan()


class _Span:
    """Live span: ``set(**args)`` attaches args (token counts etc.) any
    time before exit; the complete event is recorded on ``__exit__``."""

    __slots__ = ("_tr", "name", "tid", "cat", "args", "t0")

    def __init__(self, tracer: "SpanTracer", name: str, tid: int, cat: str,
                 args: Optional[dict]):
        self._tr = tracer
        self.name = name
        self.tid = tid
        self.cat = cat
        self.args = dict(args) if args else {}
        self.t0 = 0.0

    def set(self, **args):
        self.args.update(args)

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._tr.record_span(self.name, self.t0, time.perf_counter(),
                             tid=self.tid, cat=self.cat, args=self.args)
        return False


class SpanTracer:
    def __init__(self, enabled: bool = False,
                 annotate_device: Optional[bool] = None):
        self.enabled = bool(enabled)
        # jax.profiler.TraceAnnotation wrapping of the jitted dispatches:
        # rides the same flag by default so host spans and device traces
        # line up whenever a trace is being taken, and costs nothing
        # when off (the profiler hooks are never constructed)
        self.annotate_device = (self.enabled if annotate_device is None
                                else bool(annotate_device))
        self._t0 = time.perf_counter()
        self.events: List[dict] = []
        self._flow_seen: set = set()

    # -- time -----------------------------------------------------------

    @property
    def origin(self) -> float:
        """The perf_counter timestamp of the trace's t=0 — readers
        correlating trace ``ts`` values with engine timestamps (the SLO
        ledger's interference attribution) subtract this."""
        return self._t0

    def _us(self, t: float) -> float:
        return (t - self._t0) * 1e6

    # -- host spans -----------------------------------------------------

    def span(self, name: str, tid: int = TID_ENGINE, cat: str = "phase",
             **args):
        """Context manager recording one complete ('X') event."""
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, tid, cat, args)

    def record_span(self, name: str, t_start: float, t_end: float,
                    tid: int = TID_ENGINE, cat: str = "phase",
                    args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        self.events.append({
            "name": name, "cat": cat, "ph": "X",
            "ts": self._us(t_start),
            "dur": max(self._us(t_end) - self._us(t_start), 0.0),
            "pid": 0, "tid": tid, "args": args or {}})

    def instant(self, name: str, tid: int = TID_ENGINE, **args) -> None:
        if not self.enabled:
            return
        self.events.append({"name": name, "cat": "phase", "ph": "i",
                            "ts": self._us(time.perf_counter()), "pid": 0,
                            "tid": tid, "s": "t", "args": args})

    # -- per-request flow + async events --------------------------------

    def flow_point(self, rid: int, phase: str, t: Optional[float] = None,
                   final: bool = False) -> None:
        """One flow event on request ``rid``'s arrow: first call is the
        flow start ('s'), later ones steps ('t'), ``final=True`` the
        finish ('f') — Perfetto draws the request's arrow through every
        slice these land in."""
        if not self.enabled:
            return
        ph = "f" if final else ("t" if rid in self._flow_seen else "s")
        self._flow_seen.add(rid)
        ev = {"name": "request", "cat": "request", "ph": ph, "id": rid,
              "ts": self._us(t if t is not None else time.perf_counter()),
              "pid": 0, "tid": TID_ENGINE, "args": {"phase": phase}}
        if final:
            ev["bp"] = "e"
        self.events.append(ev)

    def async_begin(self, name: str, aid: int,
                    t: Optional[float] = None) -> None:
        """Async ('b'/'e') spans overlap freely — used for per-request
        phases (queue_wait) that can't nest on one thread track."""
        if not self.enabled:
            return
        self.events.append({
            "name": name, "cat": "request", "ph": "b", "id": aid,
            "ts": self._us(t if t is not None else time.perf_counter()),
            "pid": 0, "tid": TID_REQUESTS, "args": {}})

    def async_end(self, name: str, aid: int,
                  t: Optional[float] = None) -> None:
        if not self.enabled:
            return
        self.events.append({
            "name": name, "cat": "request", "ph": "e", "id": aid,
            "ts": self._us(t if t is not None else time.perf_counter()),
            "pid": 0, "tid": TID_REQUESTS, "args": {}})

    # -- device-trace annotation ----------------------------------------

    def annotate(self, name: str):
        """``jax.profiler.TraceAnnotation`` around a dispatch so device
        profiler traces carry the engine's phase names. No-op (shared
        null span, nothing constructed) unless device annotation is on."""
        if not (self.enabled and self.annotate_device
                and _TraceAnnotation is not None):
            return NULL_SPAN
        return _TraceAnnotation(name)

    # -- reading / export -----------------------------------------------

    def phase_totals(self) -> Dict[str, Dict[str, float]]:
        """Aggregate the complete events: ``{name: {ms, count}}`` — the
        Table-6-style stage breakdown benchmarks emit per run."""
        out: Dict[str, Dict[str, float]] = {}
        for ev in self.events:
            if ev.get("ph") != "X":
                continue
            d = out.setdefault(ev["name"], {"ms": 0.0, "count": 0})
            d["ms"] += ev["dur"] / 1e3
            d["count"] += 1
        return out

    def export(self, path) -> Path:
        """Write Chrome trace-event JSON: ``{"traceEvents": [...]}`` with
        process/thread name metadata. Loadable by Perfetto as-is."""
        meta = [
            {"ph": "M", "pid": 0, "tid": TID_ENGINE, "name": "process_name",
             "args": {"name": "repro-engine"}},
            {"ph": "M", "pid": 0, "tid": TID_ENGINE, "name": "thread_name",
             "args": {"name": "engine"}},
            {"ph": "M", "pid": 0, "tid": TID_REQUESTS, "name": "thread_name",
             "args": {"name": "requests"}},
        ]
        path = Path(path)
        path.write_text(json.dumps(
            {"traceEvents": meta + self.events, "displayTimeUnit": "ms"}))
        return path
