"""Paged KV cache: a fixed pool of fixed-size pages + per-request block
tables + a refcounted free-list allocator (DESIGN.md §3.2, §13).

The device pool is allocated ONCE (`api.init_paged_cache`) and never
resized; requests borrow pages and return them on completion, so cache
memory is bounded and fragmentation-free regardless of how many requests
stream through. Block-table entries that hold no page carry the
out-of-range sentinel ``num_pages``: scatter-writes to a sentinel page are
dropped by XLA and gather-reads clip (and are masked by the per-slot
length), so inactive slots cost nothing and corrupt nothing.

Pages carry **refcounts** (DESIGN.md §13): a page may be mapped into
several slots' block tables at once (shared prompt prefixes) and
referenced by the radix :class:`~repro.engine.prefix_cache.PrefixCache`;
``free`` is a decref and a page returns to the free list only at
refcount 0. The PR8 conservation law survives refcount-weighted:
``num_free + num_outstanding == num_pages`` at every step, where
outstanding means refcount >= 1.

Resilience hooks (DESIGN.md §12): the allocator enforces its free-list
invariants (double-free / out-of-range frees raise instead of silently
corrupting the list — a preempt/re-admit storm must conserve ``num_free``
exactly), reservations carry a per-slot speculative *lookahead* so
admissions under pool pressure can reserve less than the full tree's
tentative-verify pages, and ``assign`` consults an optional chaos
injector to produce deterministic transient allocation failures.
"""
from __future__ import annotations

from collections import deque
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.resilience.chaos import TransientAllocFailure
from repro.engine.resilience.policy import OversizedRequest
from repro.engine.telemetry import MetricsRegistry


class PageAllocator:
    """Refcounted free-list page allocator. O(1) alloc/free, pages are
    reused LIFO so recently-touched pages (warm in cache) are handed out
    first.

    ``alloc`` hands out pages at refcount 1; ``incref`` adds references
    (prefix sharing); ``free`` drops one reference per page and returns a
    page to the free list only at refcount 0. Invariant-hardened: every
    page is either in the free list (refcount 0) or in the outstanding
    set (refcount >= 1), never both. ``free`` rejects decrefs of
    non-outstanding pages and out-of-range ids with :class:`ValueError`
    *before* touching any state, so a buggy caller cannot corrupt the
    list (and ``num_free + num_outstanding`` stays an exact conservation
    law under preempt/re-admit/evict churn)."""

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free: deque = deque(range(num_pages))
        self._outstanding: set = set()
        self._refcount = [0] * num_pages
        self._n_shared = 0     # pages with refcount >= 2

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_outstanding(self) -> int:
        return len(self._outstanding)

    @property
    def num_shared(self) -> int:
        return self._n_shared

    def refcount(self, page: int) -> int:
        return self._refcount[page]

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> List[int]:
        if not self.can_alloc(n):
            raise RuntimeError(
                f"out of KV pages: want {n}, have {len(self._free)}")
        pages = [self._free.pop() for _ in range(n)]
        self._outstanding.update(pages)
        for p in pages:
            self._refcount[p] = 1
        return pages

    def incref(self, pages: List[int]) -> None:
        """Add one reference per page (prefix sharing / cache adoption).
        Only outstanding pages can gain references — incref of a free
        page would resurrect it under a future alloc."""
        for p in pages:
            if not 0 <= p < self.num_pages:
                raise ValueError(
                    f"incref of out-of-range page id {p} "
                    f"(pool has {self.num_pages} pages)")
            if p not in self._outstanding:
                raise ValueError(
                    f"incref of non-outstanding page {p}")
        for p in pages:
            self._refcount[p] += 1
            if self._refcount[p] == 2:
                self._n_shared += 1

    def free(self, pages: List[int]) -> List[int]:
        """Drop one reference per page; pages reaching refcount 0 go back
        to the free list. Returns the pages actually freed (callers'
        telemetry must count returns, not decrefs)."""
        # validate the whole batch first: a partially-applied free would
        # itself corrupt the invariant it exists to protect
        for p in pages:
            if not 0 <= p < self.num_pages:
                raise ValueError(
                    f"free of out-of-range page id {p} "
                    f"(pool has {self.num_pages} pages)")
            if p not in self._outstanding:
                raise ValueError(
                    f"double-free of page {p}: not outstanding "
                    f"({len(self._outstanding)} pages are)")
        if len(set(pages)) != len(pages):
            raise ValueError(f"duplicate page ids in free batch: {pages}")
        freed = []
        for p in pages:
            self._refcount[p] -= 1
            if self._refcount[p] == 1:
                self._n_shared -= 1
            elif self._refcount[p] == 0:
                self._outstanding.discard(p)
                self._free.append(p)
                freed.append(p)
        return freed


class PagedKVCache:
    """Host-side manager of the device page pool.

    ``data`` is the device pytree from ``api.init_paged_cache`` (leaves
    [L, P, page_size, ...]); it flows through the jitted prefill/decode
    calls functionally and is stored back here each iteration.

    With ``prefix_cache=True`` a radix :class:`PrefixCache` sits on top:
    ``assign`` maps a request's cached prompt prefix to existing pages
    (incref — the per-slot block table is the indirection that makes
    sharing free), copy-on-writes the one page the tail prefill must
    write into, and evicts unreferenced cached prefixes when the free
    list alone cannot cover the unshared remainder (DESIGN.md §13).
    """

    def __init__(self, cfg, api, num_slots: int, max_seq: int,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 lookahead: int = 0,
                 registry: Optional[MetricsRegistry] = None,
                 prefix_cache: bool = False):
        if not api.supports_paged_cache:
            from repro.models.registry import paged_families
            raise NotImplementedError(
                f"model family {cfg.family!r} has no paged-cache support "
                f"(supported: {', '.join(paged_families())})")
        self.page_size = page_size
        # ``lookahead``: extra writable positions past a slot's budget for
        # speculative decoding — the verify step scatters its whole fed
        # block (K+1 chain tokens, or all N+1 slots of a token TREE) at
        # positions pos..pos+lookahead before acceptance is known, so a
        # slot's reservation must cover its worst case plus that many
        # tentative tokens. A rejected suffix/branch is rolled back by
        # *position rewind only* (engine rewinds its write position — a
        # tree additionally compacts the accepted path's K/V slots first;
        # the block table and the slot's page set never change
        # mid-request), so accept/reject churn can never leak or thrash
        # pages. Under pool pressure, admissions may reserve LESS than
        # this full lookahead per slot (resilience degrade ladder,
        # DESIGN.md §12.2); the engine then clamps each segment's spec
        # shape to the smallest reservation among its active slots.
        self.lookahead = lookahead
        self.max_pages_per_slot = -(-(max_seq + lookahead) // page_size)
        # default pool: every slot can grow to max_seq simultaneously
        self.num_pages = (num_slots * self.max_pages_per_slot
                          if num_pages is None else num_pages)
        self.sentinel = self.num_pages
        self.data = api.init_paged_cache(cfg, self.num_pages, page_size)
        self.allocator = PageAllocator(self.num_pages)
        self.block_tables = np.full((num_slots, self.max_pages_per_slot),
                                    self.sentinel, np.int32)
        self._slot_pages: List[List[int]] = [[] for _ in range(num_slots)]
        self._slot_lookahead = [lookahead] * num_slots
        # tokens of each slot's prompt served from cached pages (0 = the
        # slot prefills its whole prompt); the engine prefills only the
        # tail past this point (DESIGN.md §13)
        self._slot_shared = [0] * num_slots
        # deterministic fault injection (resilience chaos harness,
        # DESIGN.md §12.3): set by the engine when a chaos spec is active
        self.chaos = None
        # pool occupancy + free-list depth into the shared registry
        # (telemetry, DESIGN.md §10): the admission-backpressure signals
        # the chunked-prefill scheduler direction reads online
        reg = registry if registry is not None else MetricsRegistry()
        self._g_free = reg.gauge("kv.pages_free")
        self._g_occ = reg.gauge("kv.occupancy")
        self._g_shared = reg.gauge("kv.shared_pages")
        self._c_allocs = reg.counter("kv.page_allocs")
        self._c_frees = reg.counter("kv.page_frees")
        self._c_hits = reg.counter("prefix.hits")
        self._c_misses = reg.counter("prefix.misses")
        self._c_hit_tokens = reg.counter("prefix.hit_tokens")
        self._c_cow = reg.counter("prefix.cow_copies")
        reg.gauge("kv.num_pages").set(self.num_pages)
        if prefix_cache:
            from repro.engine.prefix_cache import PrefixCache
            self.prefix: Optional[PrefixCache] = PrefixCache(
                page_size, self.allocator, reg)
        else:
            self.prefix = None
        self._sync_gauges()

    def _sync_gauges(self) -> None:
        free = self.allocator.num_free
        self._g_free.set(free)
        self._g_occ.set(1.0 - free / max(self.num_pages, 1))
        self._g_shared.set(self.allocator.num_shared)

    def pages_needed(self, n_tokens: int,
                     lookahead: Optional[int] = None) -> int:
        """Worst-case pages for a request: prompt + budget + the
        speculative lookahead (tentative verify writes past the budget).
        ``lookahead`` overrides the cache-wide default (pressure-degraded
        admissions reserve less, DESIGN.md §12.2)."""
        la = self.lookahead if lookahead is None else lookahead
        return -(-(n_tokens + la) // self.page_size)

    def _prefix_plan(self, prompt, touch: bool = True) -> Tuple[list, object, int]:
        """Resolve a prompt against the radix cache: ``(kept_nodes,
        cow_node, shared_tokens)``. ``kept_nodes`` are the cached blocks
        the slot maps as-is; ``shared_tokens`` is the prompt prefix those
        cover, clamped to ``prompt_len - 1`` so the tail prefill always
        recomputes at least one token (first-token logits). When the
        clamp lands *inside* a cached block (a page-aligned full-prompt
        hit), that block is the ``cow_node``: the tail writes into it, so
        admission must device-copy it first."""
        if self.prefix is None or prompt is None:
            return [], None, 0
        nodes = self.prefix.match(prompt, touch=touch)
        if not nodes:
            return [], None, 0
        shared = min(len(nodes) * self.page_size, len(prompt) - 1)
        n_keep = shared // self.page_size
        cow = nodes[n_keep] if n_keep < len(nodes) else None
        return nodes[:n_keep], cow, shared

    def evictable_pages(self) -> int:
        """Cached-prefix pages an eviction cascade could return to the
        pool right now — the resilience ladder counts these as free
        (eviction is cheaper than degrade/preempt, DESIGN.md §13)."""
        return 0 if self.prefix is None else self.prefix.evictable_count()

    def can_admit(self, n_tokens: int,
                  lookahead: Optional[int] = None, prompt=None) -> bool:
        need = self.pages_needed(n_tokens, lookahead)
        if need > self.max_pages_per_slot:
            # assign would reject it outright — not admissible at any
            # pool occupancy (OversizedRequest, see assign)
            return False
        if self.prefix is None or prompt is None:
            return self.allocator.can_alloc(need)
        kept, cow, _ = self._prefix_plan(prompt, touch=False)
        pinned = kept + ([cow] if cow is not None else [])
        n_own = need - len(kept)
        return (self.allocator.num_free
                + self.prefix.evictable_count(exclude=pinned)) >= n_own

    def _copy_page(self, src: int, dst: int) -> None:
        """Device-copy one pool page (copy-on-write): every cache leaf is
        [L, P, page_size, ...], so copy index ``src``->``dst`` along the
        page axis in each leaf."""
        self.data = jax.tree_util.tree_map(
            lambda leaf: leaf.at[:, dst].set(leaf[:, src]), self.data)

    def assign(self, slot: int, n_tokens: int,
               lookahead: Optional[int] = None, prompt=None) -> None:
        """Reserve pages for a request's full lifetime (prompt + budget
        + lookahead) — admission-time reservation means neither decode
        nor a speculative verify write can ever hit OOM. With ``prompt``
        and the prefix cache enabled, the cached prefix maps to existing
        pages (incref) and only the remainder is allocated. Raises
        :class:`OversizedRequest` when the reservation can never fit a
        slot's block table (validated BEFORE any allocator mutation — a
        failed assign leaves allocator, block table, counters and gauges
        exactly as they were), and :class:`TransientAllocFailure` when
        the chaos harness injects an allocation fault."""
        if self.chaos is not None and self.chaos.fires("alloc_fail"):
            raise TransientAllocFailure(
                f"chaos: transient page-alloc failure for slot {slot}")
        la = self.lookahead if lookahead is None else lookahead
        need = self.pages_needed(n_tokens, la)
        if need > self.max_pages_per_slot:
            raise OversizedRequest(
                f"request needs {need} pages ({n_tokens} tokens "
                f"+ lookahead {la}) but a slot's block table holds at "
                f"most {self.max_pages_per_slot}")
        kept_nodes, cow, shared = self._prefix_plan(prompt)
        kept = [n.page for n in kept_nodes]
        n_own = need - len(kept)
        # pin the shared chain first: eviction below (and any interleaved
        # caller) must never reclaim pages this slot is adopting
        self.allocator.incref(kept)
        if self.prefix is not None and not self.allocator.can_alloc(n_own):
            pinned = kept_nodes + ([cow] if cow is not None else [])
            self.prefix.evict_for(n_own - self.allocator.num_free,
                                  exclude=pinned)
        try:
            own = self.allocator.alloc(n_own)
        except RuntimeError:
            self.allocator.free(kept)   # roll back the prefix increfs
            raise
        if cow is not None:
            # the tail prefill rewrites position `shared`, which lives in
            # this cached (immutable) block — give the slot its own copy
            self._copy_page(cow.page, own[0])
            self._c_cow.inc()
        pages = kept + own
        self._slot_pages[slot] = pages
        self._slot_lookahead[slot] = la
        self._slot_shared[slot] = shared
        self.block_tables[slot, :] = self.sentinel
        self.block_tables[slot, :len(pages)] = pages
        # telemetry only after every mutation above succeeded: a raising
        # assign must not move counters or leave gauges stale
        self._c_allocs.inc(len(own))
        if self.prefix is not None and prompt is not None:
            if shared > 0:
                self._c_hits.inc()
                self._c_hit_tokens.inc(shared)
            else:
                self._c_misses.inc()
        self._sync_gauges()

    def release(self, slot: int) -> None:
        freed = self.allocator.free(self._slot_pages[slot])
        self._slot_pages[slot] = []
        self._slot_lookahead[slot] = self.lookahead
        self._slot_shared[slot] = 0
        self.block_tables[slot, :] = self.sentinel
        # count *actual* page returns, and only after the free succeeded:
        # shared pages survive their other references, and a raising free
        # (double-free bug upstream) must not phantom-increment kv.page_frees
        self._c_frees.inc(len(freed))
        self._sync_gauges()

    def prefix_insert(self, slot: int, prompt) -> int:
        """Cache this slot's prompt prefix pages after its prefill wrote
        them (they are immutable from then on: positions below the prompt
        length are never rewritten). No-op without the prefix cache."""
        if self.prefix is None or prompt is None:
            return 0
        return self.prefix.insert(prompt, self._slot_pages[slot])

    def slot_shared_tokens(self, slot: int) -> int:
        """Prompt tokens this slot serves from cached pages — the engine
        prefills only positions >= this (DESIGN.md §13)."""
        return self._slot_shared[slot]

    def slot_page_count(self, slot: int) -> int:
        """Pages a preemption of this slot would actually return to the
        pool: shared pages (refcount > 1) survive their other
        references, so only count pages this slot holds exclusively."""
        return sum(1 for p in self._slot_pages[slot]
                   if self.allocator.refcount(p) == 1)

    def slot_block_table(self, slot: int,
                         n_tokens: Optional[int] = None) -> np.ndarray:
        """One slot's block-table row, optionally clamped to the pages
        covering positions [0, n_tokens) — entries past that carry the
        sentinel. Chunked prefill (DESIGN.md §14) dispatches each chunk
        against only the pages it can touch (prefix + tokens fed so
        far + the chunk itself), so the per-chunk page gather and the
        ``max_live`` clamp scale with fed tokens, not with the slot's
        full admission-time reservation."""
        row = self.block_tables[slot].copy()
        if n_tokens is not None:
            keep = -(-int(n_tokens) // self.page_size)
            row[keep:] = self.sentinel
        return row

    def slot_lookahead(self, slot: int) -> int:
        """The speculative lookahead this slot's reservation covers —
        the segment spec ladder may not exceed the minimum over its
        active slots (DESIGN.md §12.2)."""
        return self._slot_lookahead[slot]

    def device_block_tables(self) -> jnp.ndarray:
        return jnp.asarray(self.block_tables)
