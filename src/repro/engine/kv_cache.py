"""Paged KV cache: a fixed pool of fixed-size pages + per-request block
tables + a free-list allocator (DESIGN.md §3.2).

The device pool is allocated ONCE (`api.init_paged_cache`) and never
resized; requests borrow pages and return them on completion, so cache
memory is bounded and fragmentation-free regardless of how many requests
stream through. Block-table entries that hold no page carry the
out-of-range sentinel ``num_pages``: scatter-writes to a sentinel page are
dropped by XLA and gather-reads clip (and are masked by the per-slot
length), so inactive slots cost nothing and corrupt nothing.
"""
from __future__ import annotations

from collections import deque
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.engine.telemetry import MetricsRegistry


class PageAllocator:
    """Free-list page allocator. O(1) alloc/free, pages are reused LIFO so
    recently-touched pages (warm in cache) are handed out first."""

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free: deque = deque(range(num_pages))

    @property
    def num_free(self) -> int:
        return len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> List[int]:
        if not self.can_alloc(n):
            raise RuntimeError(
                f"out of KV pages: want {n}, have {len(self._free)}")
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: List[int]) -> None:
        self._free.extend(pages)


class PagedKVCache:
    """Host-side manager of the device page pool.

    ``data`` is the device pytree from ``api.init_paged_cache`` (leaves
    [L, P, page_size, ...]); it flows through the jitted prefill/decode
    calls functionally and is stored back here each iteration.
    """

    def __init__(self, cfg, api, num_slots: int, max_seq: int,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 lookahead: int = 0,
                 registry: Optional[MetricsRegistry] = None):
        if not api.supports_paged_cache:
            from repro.models.registry import paged_families
            raise NotImplementedError(
                f"model family {cfg.family!r} has no paged-cache support "
                f"(supported: {', '.join(paged_families())})")
        self.page_size = page_size
        # ``lookahead``: extra writable positions past a slot's budget for
        # speculative decoding — the verify step scatters its whole fed
        # block (K+1 chain tokens, or all N+1 slots of a token TREE) at
        # positions pos..pos+lookahead before acceptance is known, so a
        # slot's reservation must cover its worst case plus that many
        # tentative tokens. A rejected suffix/branch is rolled back by
        # *position rewind only* (engine rewinds its write position — a
        # tree additionally compacts the accepted path's K/V slots first;
        # the block table and the slot's page set never change
        # mid-request), so accept/reject churn can never leak or thrash
        # pages.
        self.lookahead = lookahead
        self.max_pages_per_slot = -(-(max_seq + lookahead) // page_size)
        # default pool: every slot can grow to max_seq simultaneously
        self.num_pages = (num_slots * self.max_pages_per_slot
                          if num_pages is None else num_pages)
        self.sentinel = self.num_pages
        self.data = api.init_paged_cache(cfg, self.num_pages, page_size)
        self.allocator = PageAllocator(self.num_pages)
        self.block_tables = np.full((num_slots, self.max_pages_per_slot),
                                    self.sentinel, np.int32)
        self._slot_pages: List[List[int]] = [[] for _ in range(num_slots)]
        # pool occupancy + free-list depth into the shared registry
        # (telemetry, DESIGN.md §10): the admission-backpressure signals
        # the chunked-prefill scheduler direction reads online
        reg = registry if registry is not None else MetricsRegistry()
        self._g_free = reg.gauge("kv.pages_free")
        self._g_occ = reg.gauge("kv.occupancy")
        self._c_allocs = reg.counter("kv.page_allocs")
        self._c_frees = reg.counter("kv.page_frees")
        reg.gauge("kv.num_pages").set(self.num_pages)
        self._sync_gauges()

    def _sync_gauges(self) -> None:
        free = self.allocator.num_free
        self._g_free.set(free)
        self._g_occ.set(1.0 - free / max(self.num_pages, 1))

    def pages_needed(self, n_tokens: int) -> int:
        """Worst-case pages for a request: prompt + budget + the
        speculative lookahead (tentative verify writes past the budget)."""
        return -(-(n_tokens + self.lookahead) // self.page_size)

    def can_admit(self, n_tokens: int) -> bool:
        return self.allocator.can_alloc(self.pages_needed(n_tokens))

    def assign(self, slot: int, n_tokens: int) -> None:
        """Reserve pages for a request's full lifetime (prompt + budget
        + lookahead) — admission-time reservation means neither decode
        nor a speculative verify write can ever hit OOM."""
        pages = self.allocator.alloc(self.pages_needed(n_tokens))
        self._slot_pages[slot] = pages
        self.block_tables[slot, :] = self.sentinel
        self.block_tables[slot, :len(pages)] = pages
        self._c_allocs.inc(len(pages))
        self._sync_gauges()

    def release(self, slot: int) -> None:
        self._c_frees.inc(len(self._slot_pages[slot]))
        self.allocator.free(self._slot_pages[slot])
        self._slot_pages[slot] = []
        self.block_tables[slot, :] = self.sentinel
        self._sync_gauges()

    def device_block_tables(self) -> jnp.ndarray:
        return jnp.asarray(self.block_tables)
