"""Paged KV cache: a fixed pool of fixed-size pages + per-request block
tables + a free-list allocator (DESIGN.md §3.2).

The device pool is allocated ONCE (`api.init_paged_cache`) and never
resized; requests borrow pages and return them on completion, so cache
memory is bounded and fragmentation-free regardless of how many requests
stream through. Block-table entries that hold no page carry the
out-of-range sentinel ``num_pages``: scatter-writes to a sentinel page are
dropped by XLA and gather-reads clip (and are masked by the per-slot
length), so inactive slots cost nothing and corrupt nothing.

Resilience hooks (DESIGN.md §12): the allocator enforces its free-list
invariants (double-free / out-of-range frees raise instead of silently
corrupting the list — a preempt/re-admit storm must conserve ``num_free``
exactly), reservations carry a per-slot speculative *lookahead* so
admissions under pool pressure can reserve less than the full tree's
tentative-verify pages, and ``assign`` consults an optional chaos
injector to produce deterministic transient allocation failures.
"""
from __future__ import annotations

from collections import deque
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.engine.resilience.chaos import TransientAllocFailure
from repro.engine.telemetry import MetricsRegistry


class PageAllocator:
    """Free-list page allocator. O(1) alloc/free, pages are reused LIFO so
    recently-touched pages (warm in cache) are handed out first.

    Invariant-hardened: every page is either in the free list or in the
    outstanding set, never both. ``free`` rejects double-frees and
    out-of-range ids with :class:`ValueError` *before* touching the free
    list, so a buggy caller cannot corrupt it (and ``num_free`` stays an
    exact conservation law under preempt/re-admit churn)."""

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free: deque = deque(range(num_pages))
        self._outstanding: set = set()

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_outstanding(self) -> int:
        return len(self._outstanding)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> List[int]:
        if not self.can_alloc(n):
            raise RuntimeError(
                f"out of KV pages: want {n}, have {len(self._free)}")
        pages = [self._free.pop() for _ in range(n)]
        self._outstanding.update(pages)
        return pages

    def free(self, pages: List[int]) -> None:
        # validate the whole batch first: a partially-applied free would
        # itself corrupt the invariant it exists to protect
        for p in pages:
            if not 0 <= p < self.num_pages:
                raise ValueError(
                    f"free of out-of-range page id {p} "
                    f"(pool has {self.num_pages} pages)")
            if p not in self._outstanding:
                raise ValueError(
                    f"double-free of page {p}: not outstanding "
                    f"({len(self._outstanding)} pages are)")
        if len(set(pages)) != len(pages):
            raise ValueError(f"duplicate page ids in free batch: {pages}")
        self._outstanding.difference_update(pages)
        self._free.extend(pages)


class PagedKVCache:
    """Host-side manager of the device page pool.

    ``data`` is the device pytree from ``api.init_paged_cache`` (leaves
    [L, P, page_size, ...]); it flows through the jitted prefill/decode
    calls functionally and is stored back here each iteration.
    """

    def __init__(self, cfg, api, num_slots: int, max_seq: int,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 lookahead: int = 0,
                 registry: Optional[MetricsRegistry] = None):
        if not api.supports_paged_cache:
            from repro.models.registry import paged_families
            raise NotImplementedError(
                f"model family {cfg.family!r} has no paged-cache support "
                f"(supported: {', '.join(paged_families())})")
        self.page_size = page_size
        # ``lookahead``: extra writable positions past a slot's budget for
        # speculative decoding — the verify step scatters its whole fed
        # block (K+1 chain tokens, or all N+1 slots of a token TREE) at
        # positions pos..pos+lookahead before acceptance is known, so a
        # slot's reservation must cover its worst case plus that many
        # tentative tokens. A rejected suffix/branch is rolled back by
        # *position rewind only* (engine rewinds its write position — a
        # tree additionally compacts the accepted path's K/V slots first;
        # the block table and the slot's page set never change
        # mid-request), so accept/reject churn can never leak or thrash
        # pages. Under pool pressure, admissions may reserve LESS than
        # this full lookahead per slot (resilience degrade ladder,
        # DESIGN.md §12.2); the engine then clamps each segment's spec
        # shape to the smallest reservation among its active slots.
        self.lookahead = lookahead
        self.max_pages_per_slot = -(-(max_seq + lookahead) // page_size)
        # default pool: every slot can grow to max_seq simultaneously
        self.num_pages = (num_slots * self.max_pages_per_slot
                          if num_pages is None else num_pages)
        self.sentinel = self.num_pages
        self.data = api.init_paged_cache(cfg, self.num_pages, page_size)
        self.allocator = PageAllocator(self.num_pages)
        self.block_tables = np.full((num_slots, self.max_pages_per_slot),
                                    self.sentinel, np.int32)
        self._slot_pages: List[List[int]] = [[] for _ in range(num_slots)]
        self._slot_lookahead = [lookahead] * num_slots
        # deterministic fault injection (resilience chaos harness,
        # DESIGN.md §12.3): set by the engine when a chaos spec is active
        self.chaos = None
        # pool occupancy + free-list depth into the shared registry
        # (telemetry, DESIGN.md §10): the admission-backpressure signals
        # the chunked-prefill scheduler direction reads online
        reg = registry if registry is not None else MetricsRegistry()
        self._g_free = reg.gauge("kv.pages_free")
        self._g_occ = reg.gauge("kv.occupancy")
        self._c_allocs = reg.counter("kv.page_allocs")
        self._c_frees = reg.counter("kv.page_frees")
        reg.gauge("kv.num_pages").set(self.num_pages)
        self._sync_gauges()

    def _sync_gauges(self) -> None:
        free = self.allocator.num_free
        self._g_free.set(free)
        self._g_occ.set(1.0 - free / max(self.num_pages, 1))

    def pages_needed(self, n_tokens: int,
                     lookahead: Optional[int] = None) -> int:
        """Worst-case pages for a request: prompt + budget + the
        speculative lookahead (tentative verify writes past the budget).
        ``lookahead`` overrides the cache-wide default (pressure-degraded
        admissions reserve less, DESIGN.md §12.2)."""
        la = self.lookahead if lookahead is None else lookahead
        return -(-(n_tokens + la) // self.page_size)

    def can_admit(self, n_tokens: int,
                  lookahead: Optional[int] = None) -> bool:
        return self.allocator.can_alloc(
            self.pages_needed(n_tokens, lookahead))

    def assign(self, slot: int, n_tokens: int,
               lookahead: Optional[int] = None) -> None:
        """Reserve pages for a request's full lifetime (prompt + budget
        + lookahead) — admission-time reservation means neither decode
        nor a speculative verify write can ever hit OOM. Raises
        :class:`TransientAllocFailure` (before touching the free list)
        when the chaos harness injects an allocation fault."""
        if self.chaos is not None and self.chaos.fires("alloc_fail"):
            raise TransientAllocFailure(
                f"chaos: transient page-alloc failure for slot {slot}")
        la = self.lookahead if lookahead is None else lookahead
        pages = self.allocator.alloc(self.pages_needed(n_tokens, la))
        self._slot_pages[slot] = pages
        self._slot_lookahead[slot] = la
        self.block_tables[slot, :] = self.sentinel
        self.block_tables[slot, :len(pages)] = pages
        self._c_allocs.inc(len(pages))
        self._sync_gauges()

    def release(self, slot: int) -> None:
        self._c_frees.inc(len(self._slot_pages[slot]))
        self.allocator.free(self._slot_pages[slot])
        self._slot_pages[slot] = []
        self._slot_lookahead[slot] = self.lookahead
        self.block_tables[slot, :] = self.sentinel
        self._sync_gauges()

    def slot_page_count(self, slot: int) -> int:
        """Pages a preemption of this slot would return to the pool."""
        return len(self._slot_pages[slot])

    def slot_lookahead(self, slot: int) -> int:
        """The speculative lookahead this slot's reservation covers —
        the segment spec ladder may not exceed the minimum over its
        active slots (DESIGN.md §12.2)."""
        return self._slot_lookahead[slot]

    def device_block_tables(self) -> jnp.ndarray:
        return jnp.asarray(self.block_tables)
