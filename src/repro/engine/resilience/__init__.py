"""Overload resilience for the serving engine (DESIGN.md §12).

Three capabilities that turn the capacity cliff from a collapse into a
slope:

* **preempt-and-recompute** — KV-pressure preemption with lossless
  resume: a victim's pages return to the pool, its generated tokens
  fold into its prompt, and a later re-prefill continues it exactly
  where it stopped (greedy outputs bit-identical to the unpreempted
  run, pinned by test).
* **deadline-aware admission + shedding** — requests carry optional
  TTFT deadlines; queue entries that provably cannot meet them are
  shed before prefill is dispatched and become first-class SLO
  verdicts (``shed`` vs ``miss`` vs ``met``). Under pool pressure the
  spec ladder degrades (full tree -> chain K=1 -> non-spec) to shrink
  lookahead reservations before any preemption fires.
* **deterministic chaos injection** — seeded, rate-parameterized fault
  classes (transient alloc failure, latency spikes, simulated device
  errors with retry/backoff, NaN-logit slot quarantine) that replay
  bit-identically at a fixed seed, so every recovery path is testable
  on demand.
"""
from repro.engine.resilience.chaos import (ChaosConfig, ChaosDeviceError,
                                           ChaosInjector, FAULTS,
                                           TransientAllocFailure,
                                           make_injector)
from repro.engine.resilience.policy import (PRESSURE_CRITICAL,
                                            PRESSURE_ELEVATED, PRESSURE_OK,
                                            OversizedRequest,
                                            RejectedRequest,
                                            ResilienceConfig,
                                            choose_victims, pressure_level)

__all__ = ["ChaosConfig", "ChaosInjector", "ChaosDeviceError",
           "TransientAllocFailure", "FAULTS", "make_injector",
           "ResilienceConfig", "RejectedRequest", "OversizedRequest",
           "choose_victims", "pressure_level", "PRESSURE_OK",
           "PRESSURE_ELEVATED", "PRESSURE_CRITICAL"]
