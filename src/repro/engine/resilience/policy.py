"""Overload policy: admission rejection, deadline shedding, KV-pressure
degradation and preempt-and-recompute victim selection (DESIGN.md §12).

The engine's only answer to pressure used to be "queue forever": a burst
that exhausted the paged pool inflated every request's queue wait
unboundedly. This module decides *what gives* instead, in escalation
order (cheapest reversible action first):

1. **reject** — malformed requests (empty prompt, oversized, non-positive
   budget) never enter the queue: :class:`RejectedRequest` at submit.
2. **shed** — a queued request whose TTFT deadline has already expired
   provably cannot meet it no matter what the engine does next (prefill
   hasn't even been dispatched), so it is dropped *before* spending
   prefill FLOPs on it. Sheds are first-class SLO verdicts, not silent
   drops.
3. **degrade** — under KV-pool pressure, new admissions reserve a
   smaller speculative lookahead (full tree -> chain K=1 -> non-spec),
   freeing the tentative-verify pages per slot; the spec ladder clamps
   each segment to the smallest reservation among its active slots, so
   degraded and full slots coexist losslessly (greedy spec == non-spec
   is already pinned).
4. **preempt** — when the queue head *still* cannot reserve pages and a
   slot is free, a strictly-lower-priority running request releases its
   pages and re-enqueues with its generated tokens folded into the
   prompt for lossless recompute (DESIGN.md §12.1). Equal-priority
   traffic never preempts: every running request arrived before the
   blocked head (FIFO admission), so evicting one for the other only
   thrashes — plain overload is handled by 2 and 3.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.engine.resilience.chaos import ChaosConfig


class RejectedRequest(ValueError):
    """Typed submit-time rejection: the request can never be served
    (empty prompt, prompt/budget beyond ``max_seq``, ``max_new <= 0``).
    Subclasses ``ValueError`` for backward compatibility."""


class OversizedRequest(RejectedRequest):
    """The reservation (tokens + speculative lookahead) needs more pages
    than one slot's block table holds — no pool occupancy can admit it.
    Raised by ``PagedKVCache.assign`` *before* any allocator mutation
    (DESIGN.md §13 bugfix: the pre-fix path allocated first and died in
    the block-table write, leaking the pages); ``RejectedRequest``-
    compatible so submit-side callers surface it as a rejection."""


# pressure levels, in escalation order
PRESSURE_OK, PRESSURE_ELEVATED, PRESSURE_CRITICAL = 0, 1, 2


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Knobs for the overload ladder. The defaults are safe for every
    existing workload: preemption needs a priority inversion to fire,
    shedding needs deadlines, chaos needs a spec — a default-configured
    engine behaves exactly as before until pressure or faults appear."""
    preempt: bool = True
    max_preemptions: int = 3       # per request; beyond this it is immune
    shed: bool = True              # deadline-expired queue entries drop
    # default TTFT deadline stamped on every submitted request (ms after
    # arrival); None leaves requests deadline-free unless submit() says
    # otherwise (the serve CLI wires --deadline / --slo here)
    deadline_ttft_ms: Optional[float] = None
    pressure_degrade: bool = True  # shrink spec lookahead under pressure
    pressure_occupancy: float = 0.85   # pool occupancy -> ELEVATED
    chaos: Optional[ChaosConfig] = None


def pressure_level(kv, head_blocked: bool,
                   occupancy_threshold: float) -> int:
    """Classify KV-pool pressure at a scheduling boundary.

    CRITICAL: the queue head cannot reserve pages right now (admission
    is actually blocked). ELEVATED: the pool is nearly full — new
    admissions should stop reserving speculative lookahead they may
    never use. OK otherwise."""
    if head_blocked:
        return PRESSURE_CRITICAL
    # unreferenced cached-prefix pages are reclaimable on demand
    # (DESIGN.md §13): a pool that is "full of cache" is not under
    # pressure, so count evictables as free before degrading admissions
    free = kv.allocator.num_free + getattr(
        kv, "evictable_pages", lambda: 0)()
    occ = 1.0 - free / max(kv.num_pages, 1)
    if occ >= occupancy_threshold:
        return PRESSURE_ELEVATED
    return PRESSURE_OK


def choose_victims(head, running: List, kv, lookahead: int,
                   max_preemptions: int) -> List:
    """Pick running requests to preempt so ``head`` can reserve pages.

    Eligibility: strictly lower priority than the head and not already
    preempted ``max_preemptions`` times (livelock guard: a request that
    keeps losing its slot eventually becomes immune and runs to
    completion). Victim order is lowest-priority first, then
    most-remaining-work (the least sunk prefill+decode investment per
    freed page), then latest arrival. Returns the *shortest prefix* of
    that order whose freed pages cover the head's reservation — or []
    when even preempting every eligible victim wouldn't (partial
    preemption is pure churn: pages freed, head still blocked)."""
    needed = kv.pages_needed(head.total_tokens, lookahead=lookahead)
    # prefix-cache eviction outranks preemption on the ladder: if
    # dropping unreferenced cached prefixes covers the reservation,
    # assign will evict them itself — no victim needed. slot_page_count
    # is refcount-aware, so shared pages a victim would NOT return to
    # the pool are never credited toward unblocking the head.
    free = kv.allocator.num_free + getattr(
        kv, "evictable_pages", lambda: 0)()
    if free >= needed:
        return []
    eligible = [r for r in running
                if r.priority < head.priority
                and r.preemptions < max_preemptions]
    eligible.sort(key=lambda r: (r.priority, -r.remaining, -r.rid))
    victims = []
    for r in eligible:
        victims.append(r)
        free += kv.slot_page_count(r.slot)
        if free >= needed:
            return victims
    return []
