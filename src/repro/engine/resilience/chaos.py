"""Deterministic chaos injection for the serving engine (DESIGN.md §12.3).

Every fault the engine claims to survive must be *producible on demand*,
or the recovery path rots untested. This module injects four named,
rate-parameterized fault classes at the engine's existing decision
points:

* ``alloc_fail``  — transient KV-page allocation failure: the admission
  reservation (:meth:`PagedKVCache.assign`) raises
  :class:`TransientAllocFailure` before touching the free list, so the
  scheduler sees exactly the backpressure a fragmented/raced allocator
  would produce and the head request retries at a later boundary.
* ``latency``     — a latency spike at the dispatch boundary (a host
  sleep before the segment/prefill dispatch), modelling a slow host,
  GC pause or contended interconnect.
* ``device_err``  — a simulated device error raised at the dispatch
  boundary (:class:`ChaosDeviceError`); the engine retries with the
  bounded-backoff discipline of ``dist.fault.retrying``. Because every
  jitted step is functional (state is assigned only from its returns),
  a pre-dispatch failure is always safely retryable.
* ``nan_logits``  — a poisoned sampler (NaN/Inf logits) for one slot's
  decode segment: the engine drops that segment's tokens for the slot,
  *quarantines* the slot for a few boundaries and re-enqueues the
  request for lossless recompute (DESIGN.md §12.1).

Seeding contract: one master seed, one independent
``np.random.Generator`` stream per fault class (spawned from the master
``SeedSequence`` in ``FAULTS`` order). Faults are drawn one Bernoulli
trial per *injection-point visit*, never per wall-clock tick, so a run
whose scheduling decisions are wall-clock-free (the offline
submit-everything path) replays **bit-identically**: same seed, same
faults, same preemptions, same tokens — pinned by test and by the CI
chaos smoke. A fault class with rate 0 draws nothing, and streams are
independent, so enabling one fault never perturbs another's sequence.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

FAULTS = ("alloc_fail", "latency", "device_err", "nan_logits")


class TransientAllocFailure(RuntimeError):
    """Injected transient KV-page allocation failure (retryable)."""


class ChaosDeviceError(RuntimeError):
    """Injected device error at a dispatch boundary (retryable)."""


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Rates are per injection-point visit (Bernoulli). Frozen (and
    therefore hashable) so it can ride inside ``EngineConfig``."""
    alloc_fail: float = 0.0
    latency: float = 0.0
    device_err: float = 0.0
    nan_logits: float = 0.0
    seed: int = 0
    latency_spike_s: float = 0.002      # injected sleep per latency fault
    device_max_retries: int = 4         # attempts before giving up
    device_backoff_s: float = 0.0       # exponential backoff base (host)
    quarantine_boundaries: int = 2      # slot cooldown after nan_logits

    def __post_init__(self):
        for f in FAULTS:
            r = getattr(self, f)
            if not 0.0 <= r <= 1.0:
                raise ValueError(f"chaos rate {f}={r} outside [0, 1]")
        if self.device_max_retries < 1:
            raise ValueError("device_max_retries must be >= 1")

    @property
    def enabled(self) -> bool:
        return any(getattr(self, f) > 0.0 for f in FAULTS)

    @classmethod
    def parse(cls, arg: str, seed: int = 0) -> "ChaosConfig":
        """``alloc_fail=0.05,latency=0.02`` — any subset of fault rates,
        plus the optional knobs ``latency_spike_ms``, ``retries``,
        ``backoff_ms`` and ``quarantine``. ``seed`` is the master chaos
        seed (the serve CLI passes ``--seed`` through)."""
        vals: Dict[str, float] = {}
        for item in arg.split(","):
            if not item:
                continue
            if "=" not in item:
                raise ValueError(f"--chaos wants k=v items, got {item!r}")
            k, v = item.split("=", 1)
            k = k.strip()
            if k in FAULTS:
                vals[k] = float(v)
            elif k == "latency_spike_ms":
                vals["latency_spike_s"] = float(v) / 1e3
            elif k == "retries":
                vals["device_max_retries"] = int(v)
            elif k == "backoff_ms":
                vals["device_backoff_s"] = float(v) / 1e3
            elif k == "quarantine":
                vals["quarantine_boundaries"] = int(v)
            else:
                raise ValueError(f"unknown chaos fault {k!r} "
                                 f"(want {'/'.join(FAULTS)})")
        if not vals:
            raise ValueError("empty --chaos spec")
        return cls(seed=seed, **vals)


class ChaosInjector:
    """Seeded fault source shared by every injection point of one engine.

    One master seed fans out into one independent rng stream per fault
    class (``SeedSequence.spawn`` in ``FAULTS`` order), so the trial
    sequence each injection point sees depends only on the master seed
    and on how many times *that* point was visited — the replay
    invariant the chaos smoke pins. Injection counts are published into
    the shared telemetry registry as ``chaos.<fault>`` counters.
    """

    def __init__(self, cfg: ChaosConfig, registry=None):
        self.cfg = cfg
        children = np.random.SeedSequence(cfg.seed).spawn(len(FAULTS))
        self._rngs = {f: np.random.default_rng(ss)
                      for f, ss in zip(FAULTS, children)}
        self._counters = {}
        if registry is not None:
            self._counters = {f: registry.counter(f"chaos.{f}")
                              for f in FAULTS}
            self._c_retries = registry.counter("chaos.device_retries")
        else:
            self._c_retries = None

    def fires(self, fault: str) -> bool:
        """One Bernoulli trial on ``fault``'s stream. Rate-0 faults draw
        nothing (their stream stays untouched)."""
        rate = getattr(self.cfg, fault)
        if rate <= 0.0:
            return False
        hit = float(self._rngs[fault].random()) < rate
        if hit and fault in self._counters:
            self._counters[fault].inc()
        return hit

    def latency_spike_s(self) -> float:
        """Sleep seconds to inject at this dispatch boundary (0 = none)."""
        return self.cfg.latency_spike_s if self.fires("latency") else 0.0

    def count_retry(self) -> None:
        if self._c_retries is not None:
            self._c_retries.inc()

    def snapshot(self) -> Dict[str, int]:
        """Injected-fault counts so far (replay pin surface)."""
        return {f: int(c.value) for f, c in self._counters.items()}


def make_injector(cfg: Optional[ChaosConfig], registry=None) \
        -> Optional[ChaosInjector]:
    """None when chaos is absent or all rates are 0 — the engine's hot
    path stays injection-free unless faults were asked for."""
    if cfg is None or not cfg.enabled:
        return None
    return ChaosInjector(cfg, registry=registry)
