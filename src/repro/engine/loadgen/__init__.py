"""Load-conditioned serving harness (DESIGN.md §11).

Two halves, one purpose — turning the engine's all-at-once offline
numbers into load-conditioned serving signals:

* :mod:`~repro.engine.loadgen.workload` — declarative, seeded workload
  specs (arrival processes, prompt/budget distributions, shared-prefix
  template pools) generating deterministic replayable request streams,
  consumed by the engine's timed-admission loop through an
  :class:`ArrivalSource`;
* :mod:`~repro.engine.loadgen.slo` — a per-request SLO ledger judging
  TTFT/TPOT/e2e deadlines into attainment, goodput and per-miss phase
  attribution, built on the telemetry timestamps the engine already
  takes.

::

    from repro.engine.loadgen import (WorkloadSpec, generate,
                                      make_source, SLO, SLOLedger)
    wl = generate(WorkloadSpec(process="poisson", rate=20,
                               requests=32), vocab=cfg.vocab)
    eng.run(source=make_source(wl))
    ledger = SLOLedger(SLO(ttft_ms=200, tpot_ms=25))
    ledger.judge(eng.metrics, eng.tel.tracer)
    print(ledger.format_summary())
"""
from repro.engine.loadgen.slo import DEADLINES, SLO, SLOLedger, Verdict
from repro.engine.loadgen.workload import (ArrivalSource, ClosedLoopSource,
                                           GeneratedRequest, OpenLoopSource,
                                           PROCESSES, Workload, WorkloadSpec,
                                           generate, make_source)

__all__ = ["WorkloadSpec", "Workload", "GeneratedRequest", "generate",
           "make_source", "ArrivalSource", "OpenLoopSource",
           "ClosedLoopSource", "PROCESSES", "SLO", "SLOLedger", "Verdict",
           "DEADLINES"]
