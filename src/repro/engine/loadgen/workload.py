"""Declarative, seeded workload specs -> deterministic request streams.

Every serving number this repo produced before this module was measured
with all requests submitted up front — the one regime real traffic never
takes. A :class:`WorkloadSpec` describes traffic instead: an *arrival
process* (open-loop Poisson, bursty gamma, or closed-loop with think
time), prompt-length and generation-budget distributions, and a
*shared-prefix template pool* (the measurement surface the prefix-reuse
roadmap item needs: TTFT vs prefix-share ratio). :func:`generate` turns
the spec into a bit-reproducible :class:`Workload` — same spec + seed
=> identical arrival times, prompts and budgets, pinned by a test — so
a load run is replayable and two engines can be compared on the *same*
traffic.

The stream is consumed through an :class:`ArrivalSource`:

* :class:`OpenLoopSource` — arrivals are wall-clock scheduled and keep
  coming whether or not the engine keeps up (offered load is an input,
  so saturation shows up as queue growth / SLO misses, not as a
  silently stretched benchmark);
* :class:`ClosedLoopSource` — a fixed population of users, each
  resubmitting *think_s* after its previous request completes (offered
  load is an output).

Specs parse from JSON files or an inline ``k=v`` shorthand
(``--workload 'process=poisson,rate=20,requests=16'``), DESIGN.md §11.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import List, Optional, Tuple

import numpy as np

PROCESSES = ("poisson", "bursty", "closed")


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Everything needed to regenerate a request stream bit-identically.

    ``rate`` is the open-loop offered load in requests/s (ignored by
    ``closed``); ``burstiness`` is the gamma shape of the ``bursty``
    process (< 1 clusters arrivals into bursts, 1 IS poisson; the mean
    inter-arrival stays 1/rate either way). ``closed`` runs
    ``concurrency`` users, each thinking ``think_s`` (exponential mean)
    between its completion and its next request. Prompt lengths and
    generation budgets draw uniformly from the inclusive ranges. With
    probability ``prefix_share`` a prompt starts with one of
    ``prefix_pool`` shared templates of ``prefix_len`` tokens (drawn
    once per workload), the rest of the prompt unique per request.
    """
    process: str = "poisson"
    rate: float = 8.0                   # req/s offered (open-loop)
    burstiness: float = 0.25            # gamma shape (bursty only)
    concurrency: int = 2                # users (closed only)
    think_s: float = 0.05               # mean think time (closed only)
    requests: int = 16
    prompt_min: int = 4
    prompt_max: int = 16
    max_new_min: int = 8
    max_new_max: int = 8
    prefix_pool: int = 0                # 0 disables shared prefixes
    prefix_len: int = 0
    prefix_share: float = 0.0
    # admission priority bands (DESIGN.md §12): 1 keeps every request at
    # priority 0 (pure FIFO, the historical behaviour — and no rng draw,
    # so existing specs regenerate bit-identically); > 1 draws each
    # request's band uniformly from [0, priority_levels)
    priority_levels: int = 1
    seed: int = 0

    def __post_init__(self):
        if self.process not in PROCESSES:
            raise ValueError(f"process must be one of {PROCESSES}: "
                             f"{self.process!r}")
        if self.requests < 1:
            raise ValueError(f"requests must be >= 1: {self.requests}")
        if not 0 <= self.prompt_min <= self.prompt_max:
            raise ValueError(f"bad prompt range "
                             f"[{self.prompt_min}, {self.prompt_max}]")
        if not 1 <= self.max_new_min <= self.max_new_max:
            raise ValueError(f"bad max_new range "
                             f"[{self.max_new_min}, {self.max_new_max}]")
        if not 0.0 <= self.prefix_share <= 1.0:
            raise ValueError(f"prefix_share must be in [0, 1]: "
                             f"{self.prefix_share}")
        if self.prefix_share > 0 and (self.prefix_pool < 1
                                      or self.prefix_len < 1):
            raise ValueError("prefix_share > 0 needs prefix_pool >= 1 "
                             "and prefix_len >= 1")
        if self.prefix_len > self.prompt_min:
            raise ValueError(f"prefix_len {self.prefix_len} exceeds "
                             f"prompt_min {self.prompt_min}")
        if self.priority_levels < 1:
            raise ValueError(f"priority_levels must be >= 1: "
                             f"{self.priority_levels}")

    # -- (de)serialization ----------------------------------------------

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2,
                          sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "WorkloadSpec":
        doc = json.loads(text)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(doc) - known
        if unknown:
            raise ValueError(f"unknown workload keys: {sorted(unknown)}")
        return cls(**doc)

    @classmethod
    def parse(cls, arg: str) -> "WorkloadSpec":
        """A path to a JSON spec file, or an inline ``k=v`` comma list
        (``process=poisson,rate=20,requests=16,prompt=4:12``; ``prompt``
        and ``max_new`` accept ``lo:hi`` range shorthands)."""
        p = Path(arg)
        if arg.endswith(".json") or p.is_file():
            return cls.from_json(p.read_text())
        doc = {}
        for item in arg.split(","):
            if not item:
                continue
            if "=" not in item:
                raise ValueError(f"inline workload wants k=v items, "
                                 f"got {item!r}")
            k, v = item.split("=", 1)
            k = k.strip()
            if k in ("prompt", "max_new"):
                lo, _, hi = v.partition(":")
                doc[f"{k}_min" if k == "prompt" else "max_new_min"] = \
                    int(lo)
                doc[f"{k}_max" if k == "prompt" else "max_new_max"] = \
                    int(hi or lo)
            elif k == "process":
                doc[k] = v
            elif k in ("rate", "burstiness", "think_s", "prefix_share"):
                doc[k] = float(v)
            else:
                doc[k] = int(v)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(doc) - known
        if unknown:
            raise ValueError(f"unknown workload keys: {sorted(unknown)}")
        return cls(**doc)


@dataclasses.dataclass
class GeneratedRequest:
    idx: int
    arrival_s: Optional[float]          # None for closed-loop
    think_s: Optional[float]            # None for open-loop
    prompt: np.ndarray                  # [prompt_len] int32
    max_new: int
    template: Optional[int] = None      # prefix-pool template id
    priority: int = 0                   # admission band (higher wins)


@dataclasses.dataclass
class Workload:
    spec: WorkloadSpec
    requests: List[GeneratedRequest]

    @property
    def offered_rate(self) -> Optional[float]:
        """Mean offered load (req/s) of an open-loop stream, None for
        closed-loop (where the rate is an outcome, not an input)."""
        if self.spec.process == "closed":
            return None
        last = self.requests[-1].arrival_s
        return len(self.requests) / last if last > 0 else float("inf")


def generate(spec: WorkloadSpec, vocab: int) -> Workload:
    """Deterministic stream: one rng, fixed draw order (arrivals, then
    templates, then per-request prompt/budget draws), so equal specs
    generate bit-identical workloads on any host."""
    rng = np.random.default_rng(spec.seed)
    n = spec.requests
    if spec.process == "poisson":
        gaps = rng.exponential(1.0 / max(spec.rate, 1e-9), size=n)
        arrivals = np.cumsum(gaps)
        thinks = np.full(n, np.nan)
    elif spec.process == "bursty":
        # gamma(shape k, scale 1/(rate*k)): mean gap 1/rate, CV 1/sqrt(k)
        # — k < 1 clusters arrivals into bursts separated by long gaps
        k = max(spec.burstiness, 1e-3)
        gaps = rng.gamma(k, 1.0 / (max(spec.rate, 1e-9) * k), size=n)
        arrivals = np.cumsum(gaps)
        thinks = np.full(n, np.nan)
    else:                               # closed
        arrivals = np.full(n, np.nan)
        thinks = rng.exponential(spec.think_s, size=n) \
            if spec.think_s > 0 else np.zeros(n)
    templates = [rng.integers(0, vocab, size=spec.prefix_len)
                 .astype(np.int32) for _ in range(spec.prefix_pool)]
    out: List[GeneratedRequest] = []
    for i in range(n):
        plen = int(rng.integers(spec.prompt_min, spec.prompt_max + 1))
        mnew = int(rng.integers(spec.max_new_min, spec.max_new_max + 1))
        tid = None
        # the prompt draws happen unconditionally so the stream past a
        # request is invariant to ITS template coin flip
        body = rng.integers(0, vocab, size=plen).astype(np.int32)
        shared = float(rng.random()) < spec.prefix_share
        if shared and templates:
            tid = int(rng.integers(0, len(templates)))
            body = body.copy()
            body[:spec.prefix_len] = templates[tid]
        # drawn last (and only when bands are enabled) so single-band
        # specs regenerate the exact historical streams
        prio = (int(rng.integers(0, spec.priority_levels))
                if spec.priority_levels > 1 else 0)
        out.append(GeneratedRequest(
            idx=i,
            arrival_s=None if np.isnan(arrivals[i]) else float(arrivals[i]),
            think_s=None if np.isnan(thinks[i]) else float(thinks[i]),
            prompt=body, max_new=mnew, template=tid, priority=prio))
    return Workload(spec=spec, requests=out)


class ArrivalSource:
    """Feeds a workload into the engine's timed-admission loop. The
    engine polls :meth:`due` with its relative clock at every scheduling
    boundary and reports completions via :meth:`on_finish` (closed-loop
    feedback); :meth:`next_at` bounds how long the engine may sleep when
    idle."""

    def due(self, now_s: float) -> List[GeneratedRequest]:
        raise NotImplementedError

    def on_finish(self, now_s: float) -> None:
        pass

    def next_at(self) -> Optional[float]:
        raise NotImplementedError

    @property
    def exhausted(self) -> bool:
        raise NotImplementedError


class OpenLoopSource(ArrivalSource):
    """Wall-clock scheduled arrivals (poisson/bursty): requests arrive
    at their precomputed times whether or not the engine keeps up."""

    def __init__(self, workload: Workload):
        if workload.spec.process == "closed":
            raise ValueError("closed-loop workload needs ClosedLoopSource")
        self._pending = list(workload.requests)   # arrival-sorted already
        self._i = 0

    def due(self, now_s: float) -> List[GeneratedRequest]:
        out = []
        while (self._i < len(self._pending)
               and self._pending[self._i].arrival_s <= now_s):
            out.append(self._pending[self._i])
            self._i += 1
        return out

    def next_at(self) -> Optional[float]:
        if self._i >= len(self._pending):
            return None
        return self._pending[self._i].arrival_s

    @property
    def exhausted(self) -> bool:
        return self._i >= len(self._pending)


class ClosedLoopSource(ArrivalSource):
    """``concurrency`` users in lock-step with the engine: each user
    issues its next request ``think_s`` after its previous one finishes
    (the classic interactive population — offered load adapts to service
    rate). The first ``concurrency`` requests are due at t=0; a
    completion schedules the stream's next request at
    ``now + its think_s``. Arrival timestamps are therefore assigned at
    run time, but WHICH prompts arrive in WHAT order is still fully
    determined by the spec."""

    def __init__(self, workload: Workload):
        if workload.spec.process != "closed":
            raise ValueError("open-loop workload needs OpenLoopSource")
        self._stream = list(workload.requests)
        self._i = 0
        self._due_at: List[Tuple[float, int]] = []
        for _ in range(min(workload.spec.concurrency, len(self._stream))):
            self._due_at.append((0.0, self._i))
            self._i += 1

    def due(self, now_s: float) -> List[GeneratedRequest]:
        ready = [(t, i) for t, i in self._due_at if t <= now_s]
        self._due_at = [(t, i) for t, i in self._due_at if t > now_s]
        out = []
        for t, i in sorted(ready):
            r = self._stream[i]
            r.arrival_s = t             # stamp the realized arrival
            out.append(r)
        return out

    def on_finish(self, now_s: float) -> None:
        if self._i < len(self._stream):
            nxt = self._stream[self._i]
            self._due_at.append((now_s + (nxt.think_s or 0.0), self._i))
            self._i += 1

    def next_at(self) -> Optional[float]:
        if not self._due_at:
            return None
        return min(t for t, _ in self._due_at)

    @property
    def exhausted(self) -> bool:
        return self._i >= len(self._stream) and not self._due_at


def make_source(workload: Workload) -> ArrivalSource:
    if workload.spec.process == "closed":
        return ClosedLoopSource(workload)
    return OpenLoopSource(workload)
