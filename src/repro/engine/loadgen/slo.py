"""Per-request SLO ledger: attainment, goodput, per-miss attribution.

A deadline turns latency distributions into a serving *verdict*: every
finished request is judged against the :class:`SLO`'s TTFT / TPOT / e2e
deadlines, and the run reports

* **attainment** — the fraction of requests that met every deadline;
* **goodput** — tokens of SLO-met requests per second (tokens delivered
  *within* deadline, not just tokens: a saturated engine can post a
  high tok/s while its goodput collapses — the distinction GQSA's
  serving claims live or die by under load);
* **per-miss phase attribution** — which engine phase ate the budget:
  ``queue_wait`` vs ``prefill`` for TTFT misses (straight from the
  request's admission timestamps), and ``prefill`` (interference) vs
  ``decode_segment`` for TPOT misses, by overlapping the request's
  decode window with the tracer's prefill spans when a trace was taken
  (the prefill/decode interference the ROADMAP's chunked-prefill item
  exists to fix — this ledger is its measurement surface).

The ledger reads the timestamps :class:`~repro.engine.metrics
.EngineMetrics` already takes at the engine's sync points and publishes
its verdict counters into the shared telemetry registry; it adds no
instrumentation of its own (DESIGN.md §11).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

DEADLINES = ("ttft", "tpot", "stall", "e2e")


@dataclasses.dataclass(frozen=True)
class SLO:
    """Deadlines in milliseconds; ``None`` leaves a dimension ungated.
    ``tpot_ms`` gates the request's MEAN time per output token after the
    first (the same statistic the metrics summary reports).
    ``stall_ms`` gates the LONGEST single prefill span overlapping the
    request's decode window — the inter-token-tail companion of the
    mean gate. A monolithic admission prefill stalls co-resident
    decodes for its full duration in one gap, which a mean over the
    whole window flattens away; chunked prefill (DESIGN.md §14) exists
    to bound exactly this statistic. Needs a trace (span durations are
    the measurement); without one the dimension never fires."""
    ttft_ms: Optional[float] = None
    tpot_ms: Optional[float] = None
    stall_ms: Optional[float] = None
    e2e_ms: Optional[float] = None

    @classmethod
    def parse(cls, arg: str) -> "SLO":
        """``ttft=200,tpot=25,e2e=2000`` (ms; any subset)."""
        vals: Dict[str, float] = {}
        for item in arg.split(","):
            if not item:
                continue
            if "=" not in item:
                raise ValueError(f"--slo wants k=v items, got {item!r}")
            k, v = item.split("=", 1)
            k = k.strip()
            if k not in DEADLINES:
                raise ValueError(f"unknown SLO dimension {k!r} "
                                 f"(want {'/'.join(DEADLINES)})")
            vals[f"{k}_ms"] = float(v)
        if not vals:
            raise ValueError("empty --slo spec")
        return cls(**vals)

    def limit(self, dim: str) -> Optional[float]:
        return getattr(self, f"{dim}_ms")


@dataclasses.dataclass
class Verdict:
    """One request's judgement: measured values, per-deadline pass/fail,
    and for each miss the phase that ate the budget."""
    rid: int
    n_tokens: int
    ttft_ms: float
    tpot_ms: float                       # nan when n_tokens <= 1
    e2e_ms: float
    queue_wait_ms: float
    prefill_ms: float
    decode_ms: float
    stall_ms: float = float("nan")       # nan without a trace
    met: bool = True
    # deadline -> attributed phase, e.g. {"ttft": "queue_wait"}
    misses: Dict[str, str] = dataclasses.field(default_factory=dict)
    # "met" | "miss" | "shed" — sheds are first-class outcomes
    # (DESIGN.md §12): a dropped request is accounted, not forgotten,
    # and met + miss + shed partitions every judged request
    verdict: str = "met"
    shed_reason: str = ""


def _overlap_ms(events, names, lo_us: float, hi_us: float) -> float:
    """Total duration (ms) of complete spans with a name in ``names``
    (one name or a tuple) overlapping the [lo_us, hi_us] window of the
    trace clock."""
    if isinstance(names, str):
        names = (names,)
    total = 0.0
    for ev in events:
        if ev.get("ph") != "X" or ev.get("name") not in names:
            continue
        a, b = ev["ts"], ev["ts"] + ev["dur"]
        total += max(0.0, min(b, hi_us) - max(a, lo_us))
    return total / 1e3


def _max_span_ms(events, names, lo_us: float, hi_us: float) -> float:
    """Longest SINGLE span (ms, full duration) with a name in ``names``
    overlapping the [lo_us, hi_us] window — the worst one-gap stall a
    co-resident request saw, as opposed to the summed overlap."""
    if isinstance(names, str):
        names = (names,)
    worst = 0.0
    for ev in events:
        if ev.get("ph") != "X" or ev.get("name") not in names:
            continue
        if ev["ts"] < hi_us and ev["ts"] + ev["dur"] > lo_us:
            worst = max(worst, ev["dur"])
    return worst / 1e3


# every span shape prompt ingestion takes: monolithic batched prefill,
# prefix-cache tail-only prefill, and chunked-prefill chunk feeds
# (DESIGN.md §14) — all of them steal the decode loop's boundary time,
# so all of them count as prefill interference for TPOT misses
PREFILL_SPANS = ("prefill", "prefill_tail", "prefill_chunk")


class SLOLedger:
    """Judges a finished run's requests against an :class:`SLO`.

    Construct, run the engine, then :meth:`judge` with the engine's
    metrics (and its tracer, if a trace was taken, for TPOT-miss
    interference attribution); :meth:`summary` /
    :meth:`format_summary` aggregate the verdicts.
    """

    def __init__(self, slo: SLO, registry=None):
        self.slo = slo
        self.verdicts: List[Verdict] = []
        self._seconds = float("nan")
        self._reg = registry
        if registry is not None:
            self._c_met = registry.counter("slo.requests_met")
            self._c_missed = registry.counter("slo.requests_missed")
            self._c_good = registry.counter("slo.goodput_tokens")
            self._c_shed = registry.counter("slo.requests_shed")

    # -- judging --------------------------------------------------------

    def judge(self, metrics, tracer=None) -> List[Verdict]:
        """Build one :class:`Verdict` per finished request from the
        metrics' per-request timings. ``tracer``: the run's span tracer
        (optional) — its prefill spans attribute TPOT misses to
        prefill interference where the overlap explains the overshoot.
        """
        self.verdicts = []
        end = metrics.end_t if metrics.end_t is not None else metrics.now()
        start = metrics.start_t if metrics.start_t is not None else end
        self._seconds = max(end - start, 0.0)
        events = tracer.events if tracer is not None \
            and getattr(tracer, "enabled", False) else []
        origin = getattr(tracer, "origin", 0.0)
        nan = float("nan")
        for rid, rt in sorted(metrics.requests.items()):
            if rt.finish_t <= 0.0:
                if rt.shed_t <= 0.0:
                    continue             # still in flight, never judged
                # shed before service: no tokens, no latency to judge —
                # but a first-class verdict (and an attainment hit)
                v = Verdict(
                    rid=rid, n_tokens=0, ttft_ms=nan, tpot_ms=nan,
                    e2e_ms=nan,
                    queue_wait_ms=(rt.shed_t - rt.enqueue_t) * 1e3,
                    prefill_ms=nan, decode_ms=nan, met=False,
                    verdict="shed", shed_reason=rt.shed_reason)
                self.verdicts.append(v)
                if self._reg is not None:
                    self._c_shed.inc()
                continue
            v = Verdict(
                rid=rid, n_tokens=rt.n_generated,
                ttft_ms=rt.ttft_s * 1e3,
                tpot_ms=(rt.tpot_s * 1e3 if rt.n_generated > 1
                         else float("nan")),
                e2e_ms=rt.latency_s * 1e3,
                queue_wait_ms=rt.queue_wait_s * 1e3,
                prefill_ms=(rt.first_token_t - rt.admit_t) * 1e3,
                decode_ms=(rt.finish_t - rt.first_token_t) * 1e3)
            self._judge_one(v, rt, events, origin)
            self.verdicts.append(v)
            if self._reg is not None:
                (self._c_met if v.met else self._c_missed).inc()
                if v.met:
                    self._c_good.inc(v.n_tokens)
        return self.verdicts

    def _judge_one(self, v: Verdict, rt, events, origin) -> None:
        lim = self.slo.limit("ttft")
        if lim is not None and v.ttft_ms > lim:
            v.misses["ttft"] = ("queue_wait"
                                if v.queue_wait_ms >= v.prefill_ms
                                else "prefill")
        lim = self.slo.limit("tpot")
        if lim is not None and v.n_tokens > 1 and v.tpot_ms > lim:
            # overshoot: decode wall time beyond what the deadline
            # allows for this many tokens; if concurrent prefill spans
            # cover it, the miss is interference, not decode speed
            overshoot_ms = v.decode_ms - lim * (v.n_tokens - 1)
            interference = _overlap_ms(
                events, PREFILL_SPANS,
                (rt.first_token_t - origin) * 1e6,
                (rt.finish_t - origin) * 1e6)
            v.misses["tpot"] = ("prefill"
                                if interference >= overshoot_ms > 0
                                else "decode_segment")
        lim = self.slo.limit("stall")
        if lim is not None and v.n_tokens > 1 and events:
            # the stalling span IS a prefill span, so a stall miss is
            # prefill interference by construction
            v.stall_ms = _max_span_ms(
                events, PREFILL_SPANS,
                (rt.first_token_t - origin) * 1e6,
                (rt.finish_t - origin) * 1e6)
            if v.stall_ms > lim:
                v.misses["stall"] = "prefill"
        lim = self.slo.limit("e2e")
        if lim is not None and v.e2e_ms > lim:
            phases = {"queue_wait": v.queue_wait_ms,
                      "prefill": v.prefill_ms,
                      "decode_segment": v.decode_ms}
            v.misses["e2e"] = max(phases, key=phases.get)
        v.met = not v.misses
        v.verdict = "met" if v.met else "miss"

    # -- aggregation ----------------------------------------------------

    def summary(self) -> Dict[str, float]:
        n = len(self.verdicts)
        met = sum(v.met for v in self.verdicts)
        shed = sum(v.verdict == "shed" for v in self.verdicts)
        tokens = sum(v.n_tokens for v in self.verdicts)
        good = sum(v.n_tokens for v in self.verdicts if v.met)
        dt = max(self._seconds, 1e-9)
        miss_by_dim = {d: sum(d in v.misses for v in self.verdicts)
                       for d in DEADLINES}
        miss_by_phase: Dict[str, int] = {}
        for v in self.verdicts:
            for phase in v.misses.values():
                miss_by_phase[phase] = miss_by_phase.get(phase, 0) + 1
        return {
            "requests": n, "met": met, "shed": shed,
            "attainment": met / n if n else float("nan"),
            "tokens": tokens, "goodput_tokens": good,
            "tok_per_s": tokens / dt,
            "goodput_tok_per_s": good / dt,
            "seconds": self._seconds,
            **{f"missed_{d}": c for d, c in miss_by_dim.items()},
            **{f"miss_phase_{p}": c for p, c in miss_by_phase.items()},
        }

    def format_summary(self) -> str:
        s = self.summary()
        lims = ", ".join(f"{d} {self.slo.limit(d):g}ms"
                         for d in DEADLINES
                         if self.slo.limit(d) is not None)
        line = (f"SLO [{lims}]: attainment {s['attainment']:.1%} "
                f"({s['met']}/{s['requests']}) | goodput "
                f"{s['goodput_tok_per_s']:.1f} tok/s "
                f"({s['goodput_tokens']}/{s['tokens']} tokens in SLO)")
        if s["shed"]:
            line += f" | shed {s['shed']}"
        misses = [f"{d} {s[f'missed_{d}']}" for d in DEADLINES
                  if s[f"missed_{d}"]]
        if misses:
            phases = ", ".join(
                f"{k[len('miss_phase_'):]} {v}" for k, v in s.items()
                if k.startswith("miss_phase_"))
            line += (f" | misses: {', '.join(misses)}"
                     f" (by phase: {phases})")
        return line
