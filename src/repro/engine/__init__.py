"""Continuous-batching inference engine (DESIGN.md §3).

Paged KV cache + task-centric scheduler + batched prefill / fused decode
on top of the GQSA-compressed model zoo::

    from repro.engine import InferenceEngine, EngineConfig, SamplingParams
    eng = InferenceEngine(cfg, params, EngineConfig(num_slots=4))
    eng.submit(prompt_tokens, max_new_tokens=32)
    results = eng.run()
"""
from repro.engine.engine import EngineConfig, InferenceEngine, plan_chunks
from repro.engine.kv_cache import PageAllocator, PagedKVCache
from repro.engine.loadgen import (SLO, SLOLedger, Workload, WorkloadSpec,
                                  generate, make_source)
from repro.engine.metrics import EngineMetrics
from repro.engine.prefix_cache import PrefixCache
from repro.engine.resilience import (ChaosConfig, OversizedRequest,
                                     RejectedRequest, ResilienceConfig)
from repro.engine.sampling import SamplingParams, sample, spec_verify
from repro.engine.scheduler import Request, Scheduler
from repro.engine.telemetry import (MetricsRegistry, SpanTracer,
                                    StreamingHistogram, Telemetry)

__all__ = ["EngineConfig", "InferenceEngine", "PageAllocator",
           "PagedKVCache", "EngineMetrics", "SamplingParams", "sample",
           "spec_verify", "Request", "Scheduler", "Telemetry",
           "MetricsRegistry", "SpanTracer", "StreamingHistogram",
           "WorkloadSpec", "Workload", "generate", "make_source", "SLO",
           "SLOLedger", "ResilienceConfig", "ChaosConfig",
           "RejectedRequest", "OversizedRequest", "PrefixCache",
           "plan_chunks"]
