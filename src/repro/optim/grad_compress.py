"""Int8 error-feedback gradient compression for the DP all-reduce.

Used by the shard_map DDP train step (launch/train.py --grad-compress):
per-device gradients are quantized to int8 with a pmax-shared per-tensor
scale, psum'd in int32 (exact integer sum), dequantized, and the local
quantization residual is carried as error feedback into the next step —
the standard EF-SGD construction, which keeps convergence unbiased in the
long run while cutting DP wire bytes 4x vs f32 (2x vs bf16).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.collectives import compressed_psum


def init_error_state(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def allreduce_mean(grads, axis: str):
    """Uncompressed baseline."""
    return jax.tree_util.tree_map(lambda g: jax.lax.pmean(g, axis), grads)


def allreduce_compressed(grads, err_state, axis: str):
    """Returns (mean grads, new error state)."""
    return compressed_psum(grads, err_state, axis)


def compression_wire_bytes(params) -> dict:
    """Static accounting: bytes on the wire per all-reduce, f32 vs int8."""
    n = sum(l.size for l in jax.tree_util.tree_leaves(params))
    return {"f32": 4 * n, "bf16": 2 * n, "int8_ef": n + 4 * len(
        jax.tree_util.tree_leaves(params))}
