"""LR schedules as pure step -> lr functions."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(base_lr: float, warmup_steps: int, total_steps: int,
                  min_ratio: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup_steps, 1)
        t = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        t = jnp.clip(t, 0.0, 1.0)
        cos = base_lr * (min_ratio + (1 - min_ratio)
                         * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr


def warmup_stable_decay(base_lr: float, warmup_steps: int, total_steps: int,
                        decay_frac: float = 0.2, min_ratio: float = 0.05):
    decay_start = int(total_steps * (1 - decay_frac))

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup_steps, 1)
        t = (step - decay_start) / jnp.maximum(total_steps - decay_start, 1)
        t = jnp.clip(t, 0.0, 1.0)
        dec = base_lr * (1 - (1 - min_ratio) * t)
        stable = jnp.where(step < decay_start, base_lr, dec)
        return jnp.where(step < warmup_steps, warm, stable)
    return lr
