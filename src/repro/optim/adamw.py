"""AdamW with decoupled weight decay + global-norm clipping (pure pytree,
no optax). Moments are stored f32 and inherit the parameter sharding (plus
FSDP) via dist.sharding rules — opt state is just another param-shaped tree.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init_state(params) -> dict:
    zeros = lambda t: jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), t)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def apply_updates(params, grads, state, cfg: AdamWConfig,
                  lr: Optional[jnp.ndarray] = None
                  ) -> Tuple[Any, dict, jnp.ndarray]:
    """Returns (new_params, new_state, pre-clip grad norm)."""
    lr = cfg.lr if lr is None else lr
    grads, norm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        pf = p.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        new_p = pf - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                           + cfg.weight_decay * pf)
        return new_p.astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_state = {"m": tdef.unflatten([o[1] for o in out]),
                 "v": tdef.unflatten([o[2] for o in out]),
                 "step": step}
    return new_params, new_state, norm
