"""Deterministic data pipeline.

Two sources:
  * ``SyntheticLM`` — a fixed-seed Zipfian n-gram-ish stream. Deterministic
    per (seed, step, host_shard): any host can regenerate any shard, which is
    what makes elastic restarts / failure recovery trivial (no data-state
    checkpoint beyond the step counter).
  * ``ByteCorpus`` — byte-level tokens from a local text file (vocab<=259:
    256 bytes + BOS/EOS/PAD) for the quality benchmarks.

Batches are delivered host-sharded: ``host_batch(step, host_id, n_hosts)``
returns this host's slice of the global batch; the launcher device_puts it
with the global-batch sharding (jax.make_array_from_process_local_data in a
real multi-host job).
"""
from __future__ import annotations

import dataclasses
import hashlib
from pathlib import Path
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


def _rng_for(seed: int, step: int, shard: int) -> np.random.Generator:
    h = hashlib.blake2b(f"{seed}:{step}:{shard}".encode(),
                        digest_size=8).digest()
    return np.random.default_rng(int.from_bytes(h, "little"))


@dataclasses.dataclass
class SyntheticLM:
    """Zipf unigram + periodic copy structure so models have signal to fit
    (loss decreases measurably within tens of steps on tiny models)."""
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3

    def host_batch(self, step: int, host_id: int = 0,
                   n_hosts: int = 1) -> Dict[str, np.ndarray]:
        b_local = self.global_batch // n_hosts
        rng = _rng_for(self.seed, step, host_id)
        z = rng.zipf(self.zipf_a, size=(b_local, self.seq_len + 1))
        toks = (z - 1) % self.vocab
        # copy structure: second half repeats first half for most rows —
        # a learnable induction task whose accuracy is precision-sensitive
        half = (self.seq_len + 1) // 2
        rows = rng.random(b_local) < 0.9
        toks[rows, half:2 * half] = toks[rows, :half]
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.host_batch(step)
            step += 1


@dataclasses.dataclass
class ByteCorpus:
    """Byte-level LM data from a file; deterministic window sampling."""
    path: str
    seq_len: int
    global_batch: int
    seed: int = 0
    vocab: int = 259       # 256 bytes + BOS(256)/EOS(257)/PAD(258)

    def __post_init__(self):
        self._data = np.frombuffer(Path(self.path).read_bytes(),
                                   dtype=np.uint8).astype(np.int32)
        if self._data.size < self.seq_len + 2:
            raise ValueError("corpus too small for seq_len")

    def host_batch(self, step: int, host_id: int = 0,
                   n_hosts: int = 1) -> Dict[str, np.ndarray]:
        b_local = self.global_batch // n_hosts
        rng = _rng_for(self.seed, step, host_id)
        starts = rng.integers(0, self._data.size - self.seq_len - 1,
                              size=b_local)
        toks = np.stack([self._data[s:s + self.seq_len + 1] for s in starts])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def eval_batches(self, n: int, seed: int = 10_000):
        for i in range(n):
            yield self.host_batch(seed + i)


def make_pipeline(kind: str, vocab: int, seq_len: int, global_batch: int,
                  seed: int = 0, path: Optional[str] = None):
    if kind == "synthetic":
        return SyntheticLM(vocab, seq_len, global_batch, seed)
    if kind == "bytes":
        assert path is not None
        return ByteCorpus(path, seq_len, global_batch, seed)
    raise ValueError(f"unknown data kind {kind}")
