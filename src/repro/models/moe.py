"""Mixture-of-Experts block (DeepSeek-style: shared + routed top-k).

Expert parallelism: expert-stacked weights are sharded on the `model` axis.
The block runs under shard_map — every model shard routes the (replicated)
tokens, dispatches the entries belonging to its *local* experts into a
contiguous [E_loc, capacity, d] buffer with a scatter (local, so no GSPMD
scatter hazards), runs the expert FFNs as batched matmuls, and the partial
outputs are psum-combined across the model axis. This is the
"replicated-dispatch + psum-combine" EP scheme; the all-to-all variant is a
§Perf iteration (see EXPERIMENTS.md).

The same dispatch code runs single-device (e_offset=0, E_loc=E) so smoke
tests and the distributed path share one implementation.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.gqs_layer import apply_linear
from repro.models.layers import linear_init, mlp_block, mlp_init


def moe_init(rng, cfg, dtype=jnp.float32) -> Dict:
    moe = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(rng, 5)
    scale = 1.0 / jnp.sqrt(d)

    def expert_stack(key, n_out, n_in):
        w = jax.random.normal(key, (moe.n_experts, n_out, n_in), dtype) * scale
        return {"w": w}

    p = {
        "router": linear_init(ks[0], moe.n_experts, d, dtype),
        "experts": {
            "wg": expert_stack(ks[1], moe.d_expert, d),
            "wu": expert_stack(ks[2], moe.d_expert, d),
            "wd": expert_stack(ks[3], d, moe.d_expert),
        },
    }
    if moe.n_shared:
        # shared experts fused into one wide SwiGLU (block-diagonal equiv.)
        p["shared"] = mlp_init(ks[4], d, moe.n_shared * moe.d_expert,
                               "swiglu", dtype)
    return p


def _route(router_p: Dict, x: jnp.ndarray, moe) -> Tuple[jnp.ndarray,
                                                         jnp.ndarray,
                                                         jnp.ndarray]:
    """x: [T, d] -> (gates [T, K], expert ids [T, K], aux loss scalar)."""
    logits = apply_linear(router_p, x.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                # [T, E]
    top_vals, top_idx = jax.lax.top_k(probs, moe.top_k)
    gates = top_vals / jnp.maximum(
        jnp.sum(top_vals, axis=-1, keepdims=True), 1e-9)
    # load-balancing aux: E * sum_e f_e * P_e
    e = moe.n_experts
    assign = jnp.zeros((x.shape[0], e), jnp.float32)
    assign = assign.at[jnp.arange(x.shape[0])[:, None], top_idx].set(1.0)
    f = jnp.mean(assign, axis=0)
    pbar = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f * pbar)
    return gates, top_idx, aux


def _expert_ffn(experts: Dict, x_buf: jnp.ndarray,
                use_pallas: bool = False, fsdp_axes=None) -> jnp.ndarray:
    """x_buf: [E_loc, C, d] -> [E_loc, C, d] via per-expert SwiGLU.

    When the expert weights are FSDP-sharded on d_ff (dist/sharding.py),
    each shard computes its d_ff slice and the wd contraction is a partial
    product psum'd over the FSDP axes — cheap activation traffic instead of
    weight all-gathers.
    """
    # NOTE: fsdp_axes is unused — with tokens data-sharded, FSDP'd expert
    # weights MUST be gathered per use (a d_ff-partial psum across data
    # shards would mix different tokens' partials). Kept in the signature to
    # document the rejected §Perf hypothesis.
    def one(pe, xe):
        g = apply_linear(pe["wg"], xe, use_pallas=use_pallas)
        u = apply_linear(pe["wu"], xe, use_pallas=use_pallas)
        return apply_linear(pe["wd"], jax.nn.silu(g) * u,
                            use_pallas=use_pallas)
    return jax.vmap(one)(experts, x_buf)


def _dispatch_compute(x: jnp.ndarray, gates: jnp.ndarray,
                      top_idx: jnp.ndarray, experts: Dict,
                      e_offset, e_local: int, capacity: int,
                      use_pallas: bool = False,
                      fsdp_axes=None) -> jnp.ndarray:
    """Scatter entries for local experts into buffers, compute, gather back.

    x: [T, d]; gates/top_idx: [T, K]. Returns partial y [T, d] covering only
    the local experts' contributions.
    """
    t, d = x.shape
    k = top_idx.shape[1]
    flat_eid = top_idx.reshape(-1)                         # [T*K] global ids
    lid = flat_eid - e_offset
    is_local = (lid >= 0) & (lid < e_local)
    lid_safe = jnp.where(is_local, lid, 0)

    # position of each entry within its expert's buffer
    onehot = (lid_safe[:, None] == jnp.arange(e_local)[None, :]) & \
        is_local[:, None]                                   # [T*K, E_loc]
    oh = onehot.astype(jnp.int32)
    pos = jnp.cumsum(oh, axis=0) - oh
    entry_pos = jnp.sum(pos * oh, axis=-1)                  # [T*K]
    keep = is_local & (entry_pos < capacity)
    entry_pos = jnp.where(keep, entry_pos, capacity - 1)

    token_id = jnp.arange(t * k) // k
    x_flat = x[token_id]                                    # [T*K, d]
    x_buf = jnp.zeros((e_local, capacity, d), x.dtype)
    x_buf = x_buf.at[lid_safe, entry_pos].add(
        jnp.where(keep[:, None], x_flat, 0))

    y_buf = _expert_ffn(experts, x_buf, use_pallas,
                        fsdp_axes=fsdp_axes)                 # [E_loc, C, d]

    y_flat = y_buf[lid_safe, entry_pos]                     # [T*K, d]
    y_flat = jnp.where(keep[:, None], y_flat, 0)
    gates_flat = gates.reshape(-1, 1).astype(y_flat.dtype)
    y = jnp.sum((y_flat * gates_flat).reshape(t, k, d), axis=1)
    return y


def moe_block(p: Dict, x: jnp.ndarray, cfg, dist=None,
              use_pallas: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, d] -> (y [B, S, d], aux loss). EP over `model` when dist."""
    moe = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    capacity = max(1, int(t * moe.top_k / moe.n_experts
                          * moe.capacity_factor))

    ep = (dist is not None and dist.mesh is not None
          and moe.n_experts % dist.axis_size(dist.model_axis) == 0)

    if not ep:
        gates, top_idx, aux = _route(p["router"], xf, moe)
        y = _dispatch_compute(xf, gates, top_idx, p["experts"], 0,
                              moe.n_experts, capacity, use_pallas)
    else:
        n_shards = dist.axis_size(dist.model_axis)
        e_local = moe.n_experts // n_shards
        maxis = dist.model_axis
        dp = dist.batch_axes

        fsdp_ax = dist.fsdp_axis if dist.fsdp else None

        def local(xl, router_p, experts_l):
            tl = xl.shape[0]
            cap_l = max(1, int(tl * moe.top_k / moe.n_experts
                               * moe.capacity_factor))
            gates, top_idx, aux_l = _route(router_p, xl, moe)
            e_off = jax.lax.axis_index(maxis) * e_local
            yl = _dispatch_compute(xl, gates, top_idx, experts_l, e_off,
                                   e_local, cap_l, use_pallas)
            yl = jax.lax.psum(yl, maxis)
            aux_l = jax.lax.pmean(aux_l, dp) if dp else aux_l
            return yl, aux_l

        # expert weights arrive GATHERED over the FSDP axis (ZeRO-3
        # semantics: shard for storage, gather for compute)
        expert_specs = jax.tree_util.tree_map(
            lambda l: P(maxis, *([None] * (l.ndim - 1))), p["experts"])
        router_specs = jax.tree_util.tree_map(
            lambda l: P(*([None] * l.ndim)), p["router"])
        y, aux = shard_map(
            local, mesh=dist.mesh,
            in_specs=(P(dp, None), router_specs, expert_specs),
            out_specs=(P(dp, None), P()),
            check_rep=False,
        )(xf.reshape(t, d), p["router"], p["experts"])

    if "shared" in p:
        y = y + mlp_block(p["shared"], xf, "swiglu", use_pallas)
    return y.reshape(b, s, d), aux
