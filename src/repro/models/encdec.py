"""Encoder-decoder backbone (Seamless-M4T-v2 style).

The speech frontend is a stub per the assignment: ``frames`` arrive as
precomputed [B, F, d_model] embeddings. Encoder = bidirectional attention +
GELU FFN; decoder = causal self-attention + cross-attention + FFN. Decode
caches self-attn KV plus the (computed-once) cross K/V.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.gqs_layer import apply_linear
from repro.models import layers as L


def _enc_layer_init(rng, cfg, dtype):
    ks = jax.random.split(rng, 2)
    return {"ln1": L.norm_init(cfg.d_model, dtype),
            "attn": L.attn_init(ks[0], cfg, dtype),
            "ln2": L.norm_init(cfg.d_model, dtype),
            "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_type,
                              dtype)}


def _dec_layer_init(rng, cfg, dtype):
    ks = jax.random.split(rng, 3)
    return {"ln1": L.norm_init(cfg.d_model, dtype),
            "self_attn": L.attn_init(ks[0], cfg, dtype),
            "ln2": L.norm_init(cfg.d_model, dtype),
            "cross": L.attn_init(ks[1], cfg, dtype),
            "ln3": L.norm_init(cfg.d_model, dtype),
            "mlp": L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_type,
                              dtype)}


def init_params(rng, cfg) -> Dict:
    dtype = cfg.params_dtype
    k_e, k_enc, k_dec, k_emb, k_head = jax.random.split(rng, 5)
    enc_keys = jax.random.split(k_enc, cfg.enc_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_layers)
    return {
        "embed": jax.random.normal(k_emb, (cfg.vocab, cfg.d_model),
                                   dtype) * 0.02,
        "enc_layers": jax.vmap(
            lambda k: _enc_layer_init(k, cfg, dtype))(enc_keys),
        "enc_norm": L.norm_init(cfg.d_model, dtype),
        "dec_layers": jax.vmap(
            lambda k: _dec_layer_init(k, cfg, dtype))(dec_keys),
        "final_norm": L.norm_init(cfg.d_model, dtype),
        "lm_head": L.linear_init(k_head, cfg.vocab, cfg.d_model, dtype,
                                 scale=0.02),
    }


def _cross_attend(p: Dict, x: jnp.ndarray, enc_k: jnp.ndarray,
                  enc_v: jnp.ndarray, cfg, use_pallas) -> jnp.ndarray:
    """x: [B, S, d]; enc_k/enc_v: [B, F, KH, D] (already projected)."""
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.hd
    q = apply_linear(p["wq"], x, use_pallas=use_pallas).reshape(b, s, h, hd)
    o = L.flash_attention(q, enc_k, enc_v, causal=False,
                          block_q=cfg.attn_block_q,
                          block_k=min(cfg.attn_block_k, enc_k.shape[1]),
                          unroll=cfg.analysis_unroll)
    return apply_linear(p["wo"], o.reshape(b, s, -1), use_pallas=use_pallas)


def _cross_kv(p: Dict, enc_out: jnp.ndarray, cfg, use_pallas):
    b, f, _ = enc_out.shape
    khn, hd = cfg.n_kv_heads, cfg.hd
    k = apply_linear(p["wk"], enc_out, use_pallas=use_pallas)
    v = apply_linear(p["wv"], enc_out, use_pallas=use_pallas)
    return k.reshape(b, f, khn, hd), v.reshape(b, f, khn, hd)


def encode(params: Dict, frames: jnp.ndarray, cfg, dist=None,
           use_pallas: bool = False) -> jnp.ndarray:
    """frames: [B, F, d] (stub embeddings) -> encoder states [B, F, d]."""
    h = frames.astype(cfg.compute_dtype)
    b, f, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(f)[None, :], (b, f))

    def body(hh, lp):
        a = L.attention_block(lp["attn"],
                              L.rmsnorm(hh, lp["ln1"], cfg.norm_eps),
                              positions, cfg, causal=False,
                              use_pallas=use_pallas)
        hh = hh + a
        m = L.mlp_block(lp["mlp"], L.rmsnorm(hh, lp["ln2"], cfg.norm_eps),
                        cfg.mlp_type, use_pallas)
        return hh + m, None

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(body, h, params["enc_layers"])
    return L.rmsnorm(h, params["enc_norm"], cfg.norm_eps)


def forward(params: Dict, tokens: jnp.ndarray, frames: jnp.ndarray, cfg,
            dist=None, use_pallas: bool = False, last_only: bool = False
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Teacher-forced training pass. Returns (logits [B, S, V], aux=0)."""
    enc_out = encode(params, frames, cfg, dist, use_pallas)
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    if dist is not None:
        h = dist.constrain(h, dist.batch_spec(3))

    def body(hh, lp):
        a = L.attention_block(lp["self_attn"],
                              L.rmsnorm(hh, lp["ln1"], cfg.norm_eps),
                              positions, cfg, use_pallas=use_pallas)
        hh = hh + a
        ek, ev = _cross_kv(lp["cross"], enc_out, cfg, use_pallas)
        c = _cross_attend(lp["cross"],
                          L.rmsnorm(hh, lp["ln2"], cfg.norm_eps),
                          ek, ev, cfg, use_pallas)
        hh = hh + c
        m = L.mlp_block(lp["mlp"], L.rmsnorm(hh, lp["ln3"], cfg.norm_eps),
                        cfg.mlp_type, use_pallas)
        return hh + m, None

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(body, h, params["dec_layers"])
    if last_only:
        h = h[:, -1:, :]
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = apply_linear(params["lm_head"], h)
    return logits, jnp.float32(0.0)


def init_cache(cfg, batch: int, max_seq: int, dtype=None) -> Dict:
    dtype = dtype or cfg.compute_dtype
    lyr, khn, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((lyr, batch, max_seq, khn, hd), dtype),
        "v": jnp.zeros((lyr, batch, max_seq, khn, hd), dtype),
        "cross_k": jnp.zeros((lyr, batch, cfg.n_frames, khn, hd), dtype),
        "cross_v": jnp.zeros((lyr, batch, cfg.n_frames, khn, hd), dtype),
    }


def prime_cross_cache(params: Dict, frames: jnp.ndarray, cache: Dict, cfg,
                      dist=None, use_pallas: bool = False) -> Dict:
    """Run the encoder once and fill the cross K/V cache."""
    enc_out = encode(params, frames, cfg, dist, use_pallas)

    def body(_, lp):
        ek, ev = _cross_kv(lp["cross"], enc_out, cfg, use_pallas)
        return 0, (ek, ev)

    _, (cks, cvs) = jax.lax.scan(body, 0, params["dec_layers"])
    return dict(cache, cross_k=cks.astype(cache["cross_k"].dtype),
                cross_v=cvs.astype(cache["cross_v"].dtype))


def decode_step(params: Dict, cache: Dict, tokens: jnp.ndarray,
                pos: jnp.ndarray, cfg, dist=None, use_pallas: bool = False
                ) -> Tuple[jnp.ndarray, Dict]:
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    b = h.shape[0]

    def body(hh, xs):
        lp, lc = xs
        hn = L.rmsnorm(hh, lp["ln1"], cfg.norm_eps)
        a, new_kv = L.attention_decode(lp["self_attn"], hn,
                                       {"k": lc["k"], "v": lc["v"]},
                                       pos, cfg, use_pallas)
        hh = hh + a
        hn = L.rmsnorm(hh, lp["ln2"], cfg.norm_eps)
        q = apply_linear(lp["cross"]["wq"], hn, use_pallas=use_pallas)
        q = q.reshape(b, 1, cfg.n_heads, cfg.hd)
        o = L.decode_attention(q, lc["cross_k"], lc["cross_v"],
                               jnp.int32(cfg.n_frames))
        c = apply_linear(lp["cross"]["wo"], o.reshape(b, 1, -1),
                         use_pallas=use_pallas)
        hh = hh + c
        m = L.mlp_block(lp["mlp"], L.rmsnorm(hh, lp["ln3"], cfg.norm_eps),
                        cfg.mlp_type, use_pallas)
        new_lc = {"k": new_kv["k"], "v": new_kv["v"],
                  "cross_k": lc["cross_k"], "cross_v": lc["cross_v"]}
        return hh + m, new_lc

    h, new_cache = jax.lax.scan(body, h, (params["dec_layers"], cache))
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = apply_linear(params["lm_head"], h)
    return logits, new_cache
