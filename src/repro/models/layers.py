"""Shared building blocks: norms, RoPE, attention (train flash + decode),
MLPs. All linears route through core.gqs_layer.apply_linear so every block
accepts FP, fake-quant, W4, or packed-GQSA parameters transparently.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gqs_layer import apply_linear


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def linear_init(rng, n_out: int, n_in: int, dtype=jnp.float32,
                scale: Optional[float] = None) -> Dict:
    scale = scale if scale is not None else (1.0 / jnp.sqrt(n_in))
    w = jax.random.normal(rng, (n_out, n_in), dtype) * scale
    return {"w": w}


def norm_init(dim: int, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.ones((dim,), dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(dt)


def layernorm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * w + b).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: [..., S, H, D]; positions: [..., S] (broadcastable)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                            # [D/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(ang)[..., None, :]                        # [..., S, 1, D/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (training/prefill): exact-FLOP blocked causal flash.
# Only lower-triangular (q-block, k-block) pairs are visited, so HLO FLOPs
# match S^2/2 and peak memory is O(block_q * block_k) per step.
# ---------------------------------------------------------------------------

def _gqa_scores(q, k):
    """q: [B, KH, R, T, D]; k: [B, KH, S, D] -> [B, KH, R, T, S]."""
    return jnp.einsum("bkrtd,bksd->bkrts", q, k)


def _causal_pairs(nq: int, nk: int, block_q: int, block_k: int,
                  causal: bool):
    pairs = [(i, j) for i in range(nq) for j in range(nk)
             if (not causal) or (j * block_k < (i + 1) * block_q)]
    return (jnp.asarray([p[0] for p in pairs], jnp.int32),
            jnp.asarray([p[1] for p in pairs], jnp.int32))


def _block_mask(qi, kj, block_q, block_k, sk, causal, q_off=0):
    kg = kj * block_k + jnp.arange(block_k)
    kv_valid = kg < sk                                 # mask padded keys
    if causal:
        qg = (jnp.asarray(q_off, jnp.float32)
              + qi * block_q + jnp.arange(block_q))
        return (qg[:, None] >= kg[None, :].astype(jnp.float32)) \
            & kv_valid[None, :]
    return jnp.broadcast_to(kv_valid[None, :], (block_q, block_k))


def _flash_fwd_impl(qb, kb, vb, q_off, causal, block_q, block_k, sk,
                    unroll=False, full_pairs=False):
    """qb: [B,KH,R,NQ,Tq,D]; kb/vb: [B,KH,NK,Tk,D*]. Returns (o, lse) with
    o: [B,KH,R,NQ,Tq,Dv], lse: [B,KH,R,NQ,Tq] (+inf on fully-masked rows)."""
    b, kh, r, nq, block_q_, d = qb.shape
    nk = kb.shape[2]
    dv = vb.shape[-1]
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    # sequence-parallel shards have a *traced* q offset: the causal pair
    # set cannot be enumerated statically, so visit all pairs and let the
    # mask cut (uniform SPMD program; ~2x attention FLOPs, traded for the
    # removal of per-block resharding collectives — see EXPERIMENTS §Perf)
    qi_arr, kj_arr = _causal_pairs(nq, nk, block_q, block_k,
                                   causal and not full_pairs)

    m0 = jnp.full((nq, b, kh, r, block_q), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((nq, b, kh, r, block_q), jnp.float32)
    o0 = jnp.zeros((nq, b, kh, r, block_q, dv), jnp.float32)

    def body(carry, idx):
        m, l, o = carry
        qi, kj = idx
        qblk = jax.lax.dynamic_index_in_dim(qb, qi, axis=3, keepdims=False)
        kblk = jax.lax.dynamic_index_in_dim(kb, kj, axis=2, keepdims=False)
        vblk = jax.lax.dynamic_index_in_dim(vb, kj, axis=2, keepdims=False)
        sco = _gqa_scores(qblk, kblk) * scale          # [B,KH,R,Tq,Tk]
        mask = _block_mask(qi, kj, block_q, block_k, sk, causal, q_off)
        sco = jnp.where(mask, sco, -jnp.inf)
        mi = jax.lax.dynamic_index_in_dim(m, qi, 0, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(l, qi, 0, keepdims=False)
        oi = jax.lax.dynamic_index_in_dim(o, qi, 0, keepdims=False)
        mnew = jnp.maximum(mi, jnp.max(sco, axis=-1))
        msafe = jnp.where(jnp.isinf(mnew), 0.0, mnew)  # -inf rows guard
        p = jnp.exp(sco - msafe[..., None])
        p = jnp.where(jnp.isinf(sco), 0.0, p)
        corr = jnp.exp(jnp.where(jnp.isinf(mi), -jnp.inf, mi) - msafe)
        corr = jnp.where(jnp.isinf(mi), 0.0, corr)
        lnew = li * corr + jnp.sum(p, axis=-1)
        onew = oi * corr[..., None] + jnp.einsum("bkrts,bksd->bkrtd", p, vblk)
        m = jax.lax.dynamic_update_index_in_dim(m, mnew, qi, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, lnew, qi, 0)
        o = jax.lax.dynamic_update_index_in_dim(o, onew, qi, 0)
        return (m, l, o), None

    (m, l, o), _ = jax.lax.scan(body, (m0, l0, o0), (qi_arr, kj_arr),
                                unroll=len(qi_arr) if unroll else 1)
    o = o / jnp.maximum(l[..., None], 1e-30)
    lse = jnp.where(l > 0, jnp.where(jnp.isinf(m), 0.0, m) + jnp.log(
        jnp.maximum(l, 1e-30)), jnp.inf)
    # -> [B,KH,R,NQ,Tq,(Dv)]
    return (o.transpose(1, 2, 3, 0, 4, 5), lse.transpose(1, 2, 3, 0, 4))


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9))
def _flash_core(qb, kb, vb, q_off, causal, block_q, block_k, sk,
                unroll=False, full_pairs=False):
    o, _ = _flash_fwd_impl(qb, kb, vb, q_off, causal, block_q, block_k, sk,
                           unroll, full_pairs)
    return o


def _flash_core_fwd(qb, kb, vb, q_off, causal, block_q, block_k, sk,
                    unroll=False, full_pairs=False):
    o, lse = _flash_fwd_impl(qb, kb, vb, q_off, causal, block_q, block_k,
                             sk, unroll, full_pairs)
    return o, (qb, kb, vb, q_off, o, lse)


def _flash_core_bwd(causal, block_q, block_k, sk, unroll, full_pairs,
                    res, do):
    """FlashAttention-style recompute backward: no per-step AD residuals."""
    qb, kb, vb, q_off, o, lse = res
    b, kh, r, nq, bq, d = qb.shape
    nk = kb.shape[2]
    dv = vb.shape[-1]
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    qi_arr, kj_arr = _causal_pairs(nq, nk, block_q, block_k,
                                   causal and not full_pairs)
    delta = jnp.sum(do * o, axis=-1)                   # [B,KH,R,NQ,Tq]

    dq0 = jnp.zeros_like(qb)
    dk0 = jnp.zeros((b, kh, nk, block_k, d), jnp.float32)
    dv0 = jnp.zeros((b, kh, nk, block_k, dv), jnp.float32)

    def body(carry, idx):
        dq, dk, dvv = carry
        qi, kj = idx
        qblk = jax.lax.dynamic_index_in_dim(qb, qi, axis=3, keepdims=False)
        kblk = jax.lax.dynamic_index_in_dim(kb, kj, axis=2, keepdims=False)
        vblk = jax.lax.dynamic_index_in_dim(vb, kj, axis=2, keepdims=False)
        do_i = jax.lax.dynamic_index_in_dim(do, qi, axis=3, keepdims=False)
        lse_i = jax.lax.dynamic_index_in_dim(lse, qi, axis=3, keepdims=False)
        dl_i = jax.lax.dynamic_index_in_dim(delta, qi, axis=3, keepdims=False)
        sco = _gqa_scores(qblk, kblk) * scale
        mask = _block_mask(qi, kj, block_q, block_k, sk, causal, q_off)
        lse_safe = jnp.where(jnp.isinf(lse_i), 0.0, lse_i)
        p = jnp.exp(sco - lse_safe[..., None])
        p = jnp.where(mask & ~jnp.isinf(lse_i)[..., None], p, 0.0)
        # dv_j += p^T do_i ; dp = do_i v_j^T ; ds = p (dp - delta_i) scale
        dv_j = jnp.einsum("bkrts,bkrtd->bksd", p, do_i)
        dp = jnp.einsum("bkrtd,bksd->bkrts", do_i, vblk)
        ds = p * (dp - dl_i[..., None]) * scale
        dq_i = jnp.einsum("bkrts,bksd->bkrtd", ds, kblk)
        dk_j = jnp.einsum("bkrts,bkrtd->bksd", ds, qblk)
        old_q = jax.lax.dynamic_index_in_dim(dq, qi, axis=3, keepdims=False)
        dq = jax.lax.dynamic_update_index_in_dim(dq, old_q + dq_i, qi, 3)
        old_k = jax.lax.dynamic_index_in_dim(dk, kj, axis=2, keepdims=False)
        dk = jax.lax.dynamic_update_index_in_dim(dk, old_k + dk_j, kj, 2)
        old_v = jax.lax.dynamic_index_in_dim(dvv, kj, axis=2, keepdims=False)
        dvv = jax.lax.dynamic_update_index_in_dim(dvv, old_v + dv_j, kj, 2)
        return (dq, dk, dvv), None

    (dq, dk, dvv), _ = jax.lax.scan(body, (dq0, dk0, dv0),
                                    (qi_arr, kj_arr),
                                    unroll=len(qi_arr) if unroll else 1)
    return dq, dk, dvv, jnp.zeros((), jnp.float32)


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    *, causal: bool = True, block_q: int = 512,
                    block_k: int = 512, unroll: bool = False,
                    q_offset=0) -> jnp.ndarray:
    """q: [B, Sq, H, D]; k, v: [B, Sk, KH, D(v)]; H % KH == 0.
    Returns [B, Sq, H, Dv].

    Blocked online-softmax over statically enumerated causal block pairs
    (exact FLOPs — upper-triangular blocks are never visited) with a
    FlashAttention-style custom VJP (recompute backward; O(block^2) AD
    memory instead of O(steps x S x D) scan residuals).
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    kh = k.shape[2]
    dv = v.shape[-1]
    r = h // kh
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pq = (-sq) % block_q
    pk = (-sk) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    s, skp = sq + pq, sk + pk
    nq, nk = s // block_q, skp // block_k

    qh = q.reshape(b, s, kh, r, d).transpose(0, 2, 3, 1, 4).astype(jnp.float32)
    qb = qh.reshape(b, kh, r, nq, block_q, d)
    kb = k.transpose(0, 2, 1, 3).astype(jnp.float32).reshape(
        b, kh, nk, block_k, d)
    vb = v.transpose(0, 2, 1, 3).astype(jnp.float32).reshape(
        b, kh, nk, block_k, dv)

    static_off = isinstance(q_offset, (int, np.integer))
    q_off = jnp.asarray(q_offset, jnp.float32)
    o = _flash_core(qb, kb, vb, q_off, causal, block_q, block_k, sk,
                    unroll, full_pairs=not static_off)
    # [B,KH,R,NQ,Tq,Dv] -> [B, S, H, Dv]
    o = o.transpose(0, 3, 4, 1, 2, 5).reshape(b, s, h, dv)
    return o[:, :sq].astype(q.dtype)


def quantize_kv(x: jnp.ndarray):
    """[B, 1, KH, D] -> (int8 codes, f32 scale [B, 1, KH]) per token+head."""
    amax = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1),
                       1e-6)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _query_lengths(length: jnp.ndarray, b: int, t: int) -> jnp.ndarray:
    """Broadcast a [] / [B] / [B, T] valid-prefix spec to [B, T]."""
    l = jnp.asarray(length)
    if l.ndim == 1:
        l = l[:, None]
    return jnp.broadcast_to(l, (b, t))


def staircase_mask(length: jnp.ndarray, b: int, t: int, s: int) -> jnp.ndarray:
    """[B, T, S] validity: cache position s is visible to query (b, t) iff
    s < lq[b, t]. The SINGLE definition of the multi-token staircase
    (T = K+1 speculative verify causality; T = 1 degenerates to a plain
    prefix mask) — shared by :func:`decode_attention`,
    :func:`decode_attention_int8` and the paged-attention kernel oracle
    (`kernels/ref.py:paged_attention_ref`)."""
    lq = _query_lengths(length, b, t)
    return jnp.arange(s)[None, None, :] < lq[..., None]


def ancestor_mask(length: jnp.ndarray, anc: Optional[jnp.ndarray],
                  base: Optional[jnp.ndarray], window: int,
                  b: int, t: int, s: int) -> jnp.ndarray:
    """[B, T, S] tree-attention validity — the token-tree generalization of
    :func:`staircase_mask` (which stays the chain special case).

    A speculative token *tree* is fed as one flat block of ``window``
    tokens written at cache positions ``base .. base + window - 1`` (BFS
    order). Query (b, t) sees cache position s iff s < length[b, t] AND,
    when s falls inside the fed window, bit ``s - base[b]`` of the
    query's ancestor bitmap ``anc[b, t]`` is set (the bitmap holds the
    query's root-to-self path, so siblings/uncles in the block stay
    invisible). ``anc is None`` degenerates to the staircase. Shared by
    both jnp decode attentions, the Pallas paged kernel's mask and its
    oracle (`kernels/ref.py:tree_attention_ref`)."""
    m = staircase_mask(length, b, t, s)
    if anc is None:
        return m
    fed = (jnp.arange(s, dtype=jnp.int32)[None, None, :]
           - base.astype(jnp.int32)[:, None, None])           # [B, 1, S]
    in_win = (fed >= 0) & (fed < window)
    bits = (anc.astype(jnp.int32)[:, :, None]
            >> jnp.clip(fed, 0, 31)) & 1                       # [B, T, S]
    return m & (~in_win | (bits == 1))


def decode_attention_int8(q: jnp.ndarray, k_cache: jnp.ndarray,
                          k_scale: jnp.ndarray, v_cache: jnp.ndarray,
                          v_scale: jnp.ndarray,
                          length: jnp.ndarray,
                          anc: Optional[jnp.ndarray] = None,
                          anc_base: Optional[jnp.ndarray] = None,
                          anc_window: int = 0) -> jnp.ndarray:
    """int8 KV-cache attention (beyond-paper GQSA extension: at 32k-context
    decode the cache, not the weights, dominates HBM traffic).

    q: [B, T, H, D] (T=1 decode; T=K+1 speculative verify); k/v_cache: int8
    [B, S, KH, D]; scales: f32 [B, S, KH]; length: [] / [B] / [B, T]
    per-query valid prefix (T > 1 is causal via a staircase length).
    ``anc``/``anc_base``/``anc_window``: optional tree-attention ancestor
    bitmaps (see :func:`ancestor_mask`) for token-tree verification.
    q is quantized per-head to int8 so the score contraction is an
    int8 x int8 -> int32 dot (half the cache read bytes of bf16); the
    softmax weights are likewise quantized so p @ v runs int8 x int8.
    """
    b, s, khn, d = k_cache.shape
    t, h = q.shape[1], q.shape[2]
    r = h // khn
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    qh = q.reshape(b, t, khn, r, d)
    q_i8, q_sc = quantize_kv(qh.reshape(b, t, khn * r, d))
    q_i8 = q_i8.reshape(b, t, khn, r, d)
    q_sc = q_sc.reshape(b, t, khn, r)
    sco_i = jnp.einsum("btkrd,bskd->bkrts", q_i8, k_cache,
                       preferred_element_type=jnp.int32)
    sco = (sco_i.astype(jnp.float32)
           * q_sc.transpose(0, 2, 3, 1)[..., None]
           * k_scale.transpose(0, 2, 1)[:, :, None, None, :]
           * scale)
    valid = ancestor_mask(length, anc, anc_base, anc_window,
                          b, t, s)                         # [B, T, S]
    sco = jnp.where(valid[:, None, None, :, :], sco, -jnp.inf)
    p = jax.nn.softmax(sco, axis=-1)                       # [B,KH,R,T,S]
    # fold the per-position value scale into p, then quantize p to int8
    p_scaled = p * v_scale.transpose(0, 2, 1)[:, :, None, None, :]
    p_amax = jnp.maximum(jnp.max(p_scaled, axis=-1), 1e-9)
    p_i8 = jnp.clip(jnp.round(p_scaled / p_amax[..., None] * 127.0),
                    -127, 127).astype(jnp.int8)
    o_i = jnp.einsum("bkrts,bskd->btkrd", p_i8, v_cache,
                     preferred_element_type=jnp.int32)
    o = o_i.astype(jnp.float32) * (p_amax.transpose(0, 3, 1, 2)[..., None]
                                   / 127.0)
    return o.reshape(b, t, h, d).astype(q.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, length: jnp.ndarray,
                     anc: Optional[jnp.ndarray] = None,
                     anc_base: Optional[jnp.ndarray] = None,
                     anc_window: int = 0) -> jnp.ndarray:
    """Short-query attention against a cache.

    q: [B, T, H, D] (T=1 plain decode; T=K+1 for the speculative verify
    step's short-prefill); caches: [B, S, KH, D]; length: [] / [B] / [B, T]
    valid prefix per query (a per-query staircase makes T > 1 causal);
    ``anc``/``anc_base``/``anc_window``: optional token-tree ancestor
    bitmaps (see :func:`ancestor_mask`).
    """
    b, s, khn, d = k_cache.shape
    dv = v_cache.shape[-1]
    t, h = q.shape[1], q.shape[2]
    r = h // khn
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    # keep caches in their storage dtype AND layout: no f32 copy, no
    # transpose of the whole KV history — contract in cache layout and
    # accumulate in f32 via the dot itself
    qh = q.reshape(b, t, khn, r, d).astype(k_cache.dtype)
    sco = jnp.einsum("btkrd,bskd->bkrts", qh, k_cache,
                     preferred_element_type=jnp.float32) * scale
    valid = ancestor_mask(length, anc, anc_base, anc_window,
                          b, t, s)                         # [B, T, S]
    sco = jnp.where(valid[:, None, None, :, :], sco, -jnp.inf)
    p = jax.nn.softmax(sco, axis=-1)                       # [B,KH,R,T,S]
    o = jnp.einsum("bkrts,bskd->btkrd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, t, h, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

def attn_init(rng, cfg, dtype=jnp.float32) -> Dict:
    d, h, khn, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(rng, 4)
    p = {"wq": linear_init(ks[0], h * hd, d, dtype),
         "wk": linear_init(ks[1], khn * hd, d, dtype),
         "wv": linear_init(ks[2], khn * hd, d, dtype),
         "wo": linear_init(ks[3], d, h * hd, dtype)}
    if cfg.qk_norm:
        p["q_norm"] = norm_init(hd, dtype)
        p["k_norm"] = norm_init(hd, dtype)
    return p


def attn_qkv(p: Dict, x: jnp.ndarray, positions: jnp.ndarray, cfg,
             use_pallas=False):
    b, s, d = x.shape
    h, khn, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = apply_linear(p["wq"], x, use_pallas=use_pallas).reshape(b, s, h, hd)
    k = apply_linear(p["wk"], x, use_pallas=use_pallas).reshape(b, s, khn, hd)
    v = apply_linear(p["wv"], x, use_pallas=use_pallas).reshape(b, s, khn, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_block(p: Dict, x: jnp.ndarray, positions: jnp.ndarray, cfg,
                    *, causal: bool = True, use_pallas=False,
                    dist=None) -> jnp.ndarray:
    """Full-sequence attention (train / prefill)."""
    if dist is not None and getattr(dist, "sp_attention", False) \
            and dist.mesh is not None \
            and x.shape[1] % dist.axis_size(dist.model_axis) == 0:
        return attention_block_sp(p, x, cfg, causal=causal,
                                  use_pallas=use_pallas, dist=dist)
    b, s, d = x.shape
    q, k, v = attn_qkv(p, x, positions, cfg, use_pallas)
    o = flash_attention(q, k, v, causal=causal,
                        block_q=cfg.attn_block_q, block_k=cfg.attn_block_k,
                        unroll=cfg.analysis_unroll)
    return apply_linear(p["wo"], o.reshape(b, s, -1), use_pallas=use_pallas)


def attention_block_sp(p: Dict, x: jnp.ndarray, cfg, *, causal=True,
                       use_pallas=False, dist=None) -> jnp.ndarray:
    """Sequence-parallel attention (shard_map over the model axis).

    Queries are sequence-sharded over `model`; the (small, GQA) K/V are
    all-gathered per shard. Head-count alignment with the TP degree becomes
    irrelevant — this removes the per-block resharding collectives GSPMD
    inserts when heads % tp != 0 (yi-34b: 56 heads, kv=8 on 16-way TP).
    Causality across shards is handled by a traced q_offset in the flash
    mask (uniform SPMD program; ~2x attention FLOPs upper bound).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    b, s, d = x.shape
    maxis = dist.model_axis
    nsh = dist.axis_size(maxis)
    s_loc = s // nsh
    dp = dist.batch_axes

    def local(xl, pp):
        i = jax.lax.axis_index(maxis)
        offset = (i * s_loc).astype(jnp.float32)
        positions = (offset + jnp.arange(s_loc)[None, :]
                     ).astype(jnp.float32) * jnp.ones((xl.shape[0], 1))
        q, k_loc, v_loc = attn_qkv(pp, xl, positions, cfg, use_pallas)
        k = jax.lax.all_gather(k_loc, maxis, axis=1, tiled=True)
        v = jax.lax.all_gather(v_loc, maxis, axis=1, tiled=True)
        o = flash_attention(q, k, v, causal=causal,
                            block_q=min(cfg.attn_block_q, s_loc),
                            block_k=cfg.attn_block_k,
                            unroll=cfg.analysis_unroll, q_offset=offset)
        yl = apply_linear(pp["wo"], o.reshape(xl.shape[0], s_loc, -1),
                          use_pallas=use_pallas)
        return yl

    pspec = jax.tree_util.tree_map(
        lambda l: P(*([None] * l.ndim)), p)
    return shard_map(local, mesh=dist.mesh,
                     in_specs=(P(dp, maxis, None), pspec),
                     out_specs=P(dp, maxis, None),
                     check_rep=False)(x, p)


def attention_decode(p: Dict, x: jnp.ndarray, cache: Dict, pos: jnp.ndarray,
                     cfg, use_pallas=False) -> Tuple[jnp.ndarray, Dict]:
    """x: [B, 1, d]; cache: {k: [B, S, KH, D], v: ...} (+k_scale/v_scale for
    the int8 cache); pos: [] shared step index or [B] per-slot positions
    (continuous batching: every slot decodes at its own depth)."""
    b = x.shape[0]
    h, khn, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    per_slot = jnp.ndim(pos) == 1
    if per_slot:
        positions = pos[:, None].astype(jnp.int32)
        slot = jnp.arange(b)

        def write3(buf, new):            # [B, S, ...] <- [B, 1, ...]
            return buf.at[slot, pos].set(new[:, 0].astype(buf.dtype))
    else:
        positions = jnp.full((b, 1), pos, jnp.int32)

        def write3(buf, new):
            start = (0, pos) + (0,) * (buf.ndim - 2)
            return jax.lax.dynamic_update_slice(
                buf, new.astype(buf.dtype), start)
    q, k, v = attn_qkv(p, x, positions, cfg, use_pallas)
    if "k_scale" in cache:   # int8 KV cache
        k_i8, k_sc = quantize_kv(k)
        v_i8, v_sc = quantize_kv(v)
        k_cache = write3(cache["k"], k_i8)
        v_cache = write3(cache["v"], v_i8)
        k_scale = write3(cache["k_scale"], k_sc)
        v_scale = write3(cache["v_scale"], v_sc)
        if use_pallas and not per_slot:
            from repro.kernels import ops as kops
            r = h // khn
            o = kops.kv_decode_attention(
                q.reshape(b, khn, r, hd), k_cache, k_scale,
                v_cache, v_scale, pos + 1)
            o = o.reshape(b, 1, h, hd).astype(x.dtype)
        else:
            o = decode_attention_int8(q, k_cache, k_scale, v_cache,
                                      v_scale, pos + 1)
        y = apply_linear(p["wo"], o.reshape(b, 1, -1), use_pallas=use_pallas)
        return y, {"k": k_cache, "v": v_cache, "k_scale": k_scale,
                   "v_scale": v_scale}
    k_cache = write3(cache["k"], k)
    v_cache = write3(cache["v"], v)
    o = decode_attention(q, k_cache, v_cache, pos + 1)
    y = apply_linear(p["wo"], o.reshape(b, 1, -1), use_pallas=use_pallas)
    return y, {"k": k_cache, "v": v_cache}


def paged_block_geometry(positions: jnp.ndarray, t: int,
                         tree: Optional[Dict]):
    """Position/mask plumbing shared by every paged decode block
    (:func:`attention_decode_paged` and `models/mla.py:mla_decode_paged`).

    ``positions`` [B] is the write position of each slot's FIRST fed
    token (token t lands at positions + t). Returns ``(pos_bt [B, T]
    write positions, rope_pos [B, T] RoPE positions, length [B, T]
    per-query valid prefix, base [B] | None, anc [B, T] | None,
    window int)`` — the chain staircase when ``tree`` is None, else the
    token-tree semantics of DESIGN.md §8 (RoPE at tree DEPTH, ancestor
    bitmaps over the fed window, storage still slot-sequential).
    """
    b = positions.shape[0]
    pos_bt = (positions[:, None].astype(jnp.int32)
              + jnp.arange(t, dtype=jnp.int32)[None, :])     # write slots
    if tree is not None:
        window = int(tree["window"])
        base = positions.astype(jnp.int32) - jnp.int32(tree["start"])
        rope_pos = base[:, None] + tree["depths"][None, :].astype(jnp.int32)
        length = jnp.broadcast_to((base + window)[:, None], (b, t))
        anc = jnp.broadcast_to(
            tree["anc"][None, :].astype(jnp.int32), (b, t))
    else:
        window = 0
        base = anc = None
        rope_pos = pos_bt
        length = pos_bt + 1                                  # [B, T]
    return pos_bt, rope_pos, length, base, anc, window


def attention_decode_paged(p: Dict, x: jnp.ndarray, cache: Dict,
                           block_tables: jnp.ndarray, positions: jnp.ndarray,
                           cfg, use_pallas=False, tree: Optional[Dict] = None,
                           feed_len: Optional[jnp.ndarray] = None
                           ) -> Tuple[jnp.ndarray, Dict]:
    """One decode step of T tokens against a *paged* KV cache (one layer's
    view). T=1 is plain continuous-batching decode; T=K+1 is the
    speculative-decoding verify step's per-slot short-prefill; a token
    TREE block (``tree`` set) is the tree-speculative draft/verify path.

    x: [B, T, d]; positions: [B] write position of each slot's FIRST
    token (token t lands at positions + t); block_tables: [B, MP] page ids
    (entries == n_pages are out-of-range sentinels: scatter-writes to
    them are dropped, gather-reads clip and get masked by the per-query
    length). cache: {"k_pages"/"v_pages": [P, ps, KH, D]}
    (+ "k_scale_pages"/"v_scale_pages" [P, ps, KH] for int8).

    Causality inside the T block comes from the per-query staircase
    length (query t sees cache positions < positions + t + 1); the K/V of
    all T tokens are scattered before the attention reads them, so later
    queries attend to earlier fed tokens exactly as a sequential decode
    would.

    ``tree`` switches the block to token-tree semantics
    (engine/spec/tree.py, DESIGN.md §8): the T fed tokens are a slice of
    a flat BFS tree block of ``tree["window"]`` tokens whose root sits at
    cache position ``positions - tree["start"]``. Storage stays
    slot-sequential (token t still writes at positions + t) but RoPE runs
    at the token's tree DEPTH (``tree["depths"]`` [T]) and the mask is
    the per-query ancestor bitmap ``tree["anc"]`` [T] over the window
    (:func:`ancestor_mask`) — so a node's K/V is rotated for the position
    it would hold in sequential decode, and the accepted path can be
    compacted by pure slot moves, no re-rotation.

    With ``use_pallas`` the attention runs the fused paged kernel
    (`kernels/paged_attention.py`): it streams each slot's live pages
    through VMEM directly — the dense `[B, MP*ps, ...]` page gather
    below exists only on the jnp reference path (GSPMD / dry-run), and
    even there the engine clamps ``block_tables`` to the batch's max
    *occupied* page count before calling in (``decode_step``'s
    ``max_live_pages``), so the reference never pays for unallocated
    pages either.
    """
    b, t, _ = x.shape
    kp = cache["k_pages"]
    page_size = kp.shape[1]
    pos_bt, rope_pos, length, base, anc, window = paged_block_geometry(
        positions, t, tree)
    q, k, v = attn_qkv(p, x, rope_pos, cfg, use_pallas)
    page = jnp.take_along_axis(block_tables, pos_bt // page_size,
                               axis=1)                       # [B, T]
    off = pos_bt % page_size
    if feed_len is not None:
        # ragged multi-token feed (prefix-cache tail prefill, DESIGN.md
        # §13): rows feed feed_len[i] <= T real tokens. Positions at or
        # past a row's feed_len remap to the out-of-range sentinel so
        # their K/V writes drop — the same convention batched prefill
        # uses for padding — instead of take_along_axis clipping them
        # onto the row's last live page and corrupting it.
        page = jnp.where(
            jnp.arange(t, dtype=jnp.int32)[None, :] < feed_len[:, None],
            page, kp.shape[0])

    def write(buf, new):                 # [P, ps, ...] <- [B, T, ...]
        return buf.at[page, off].set(new.astype(buf.dtype))

    def view(buf):                       # [P, ps, ...] -> [B, MP*ps, ...]
        g = buf[block_tables]            # OOB sentinel pages clip (masked)
        return g.reshape((b, -1) + buf.shape[2:])

    if "k_scale_pages" in cache:         # int8 paged cache
        k_i8, k_sc = quantize_kv(k)
        v_i8, v_sc = quantize_kv(v)
        new = {"k_pages": write(kp, k_i8),
               "v_pages": write(cache["v_pages"], v_i8),
               "k_scale_pages": write(cache["k_scale_pages"], k_sc),
               "v_scale_pages": write(cache["v_scale_pages"], v_sc)}
        if use_pallas:
            from repro.kernels import ops as kops
            o = kops.paged_decode_attention(
                q, new["k_pages"], new["v_pages"], length, block_tables,
                new["k_scale_pages"], new["v_scale_pages"],
                anc=anc, anc_base=base,
                anc_window=window).astype(q.dtype)
        else:
            o = decode_attention_int8(q, view(new["k_pages"]),
                                      view(new["k_scale_pages"]),
                                      view(new["v_pages"]),
                                      view(new["v_scale_pages"]), length,
                                      anc, base, window)
    else:
        new = {"k_pages": write(kp, k),
               "v_pages": write(cache["v_pages"], v)}
        if use_pallas:
            from repro.kernels import ops as kops
            o = kops.paged_decode_attention(
                q, new["k_pages"], new["v_pages"], length,
                block_tables, anc=anc, anc_base=base,
                anc_window=window).astype(q.dtype)
        else:
            o = decode_attention(q, view(new["k_pages"]),
                                 view(new["v_pages"]), length,
                                 anc, base, window)
    y = apply_linear(p["wo"], o.reshape(b, t, -1), use_pallas=use_pallas)
    return y, new


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(rng, d: int, d_ff: int, mlp_type: str, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(rng, 3)
    if mlp_type == "swiglu":
        return {"wg": linear_init(ks[0], d_ff, d, dtype),
                "wu": linear_init(ks[1], d_ff, d, dtype),
                "wd": linear_init(ks[2], d, d_ff, dtype)}
    return {"wu": linear_init(ks[0], d_ff, d, dtype),
            "wd": linear_init(ks[1], d, d_ff, dtype)}


def mlp_block(p: Dict, x: jnp.ndarray, mlp_type: str,
              use_pallas=False) -> jnp.ndarray:
    if mlp_type == "swiglu":
        g = apply_linear(p["wg"], x, use_pallas=use_pallas)
        u = apply_linear(p["wu"], x, use_pallas=use_pallas)
        return apply_linear(p["wd"], jax.nn.silu(g) * u,
                            use_pallas=use_pallas)
    u = apply_linear(p["wu"], x, use_pallas=use_pallas)
    return apply_linear(p["wd"], jax.nn.gelu(u), use_pallas=use_pallas)
