"""Pure Mamba2 LM (mamba2-130m): stacked SSM blocks, no attention."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.gqs_layer import apply_linear
from repro.models import layers as L
from repro.models import ssm as S


def init_params(rng, cfg) -> Dict:
    dtype = cfg.params_dtype
    k_emb, k_layers, k_head = jax.random.split(rng, 3)
    lkeys = jax.random.split(k_layers, cfg.n_layers)

    def one(k):
        kk = jax.random.split(k, 2)
        return {"ln": L.norm_init(cfg.d_model, dtype),
                "mamba": S.mamba_init(kk[0], cfg, dtype)}

    p = {"embed": jax.random.normal(k_emb, (cfg.vocab, cfg.d_model),
                                    dtype) * 0.02,
         "layers": jax.vmap(one)(lkeys),
         "final_norm": L.norm_init(cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = L.linear_init(k_head, cfg.vocab, cfg.d_model, dtype,
                                     scale=0.02)
    return p


def _unembed(params, h, cfg):
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype))
    return apply_linear(params["lm_head"], h)


def forward(params: Dict, tokens: jnp.ndarray, cfg, dist=None,
            use_pallas: bool = False,
            last_only: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    if dist is not None:
        h = dist.constrain(h, dist.batch_spec(3))

    def body(hh, lp):
        hn = L.rmsnorm(hh, lp["ln"], cfg.norm_eps)
        return hh + S.mamba_block(lp["mamba"], hn, cfg, use_pallas), None

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(body, h, params["layers"])
    if last_only:
        h = h[:, -1:, :]
    return _unembed(params, h, cfg), jnp.float32(0.0)


def init_cache(cfg, batch: int, max_seq: int, dtype=None) -> Dict:
    # SSM state is O(1) in sequence length: max_seq is irrelevant (that IS
    # the long-context win), kept in the signature for API uniformity.
    dtype = dtype or cfg.compute_dtype
    one = S.mamba_cache_init(cfg, batch, dtype)
    return jax.tree_util.tree_map(
        lambda l: jnp.zeros((cfg.n_layers,) + l.shape, l.dtype), one)


def decode_step(params: Dict, cache: Dict, tokens: jnp.ndarray,
                pos: jnp.ndarray, cfg, dist=None, use_pallas: bool = False
                ) -> Tuple[jnp.ndarray, Dict]:
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)

    def body(hh, xs):
        lp, lc = xs
        hn = L.rmsnorm(hh, lp["ln"], cfg.norm_eps)
        y, new_lc = S.mamba_decode(lp["mamba"], hn, lc, cfg, use_pallas)
        return hh + y, new_lc

    h, new_cache = jax.lax.scan(body, h, (params["layers"], cache))
    return _unembed(params, h, cfg), new_cache
