"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block
applied every ``shared_attn_every`` SSM layers (weights reused at every
invocation, as in Zamba/Zamba2).

Layer layout for n_layers=81, every=6: 13 groups of 6 mamba layers (outer
scan), each followed by the shared attention+MLP block; 3 tail mamba layers.
Decode keeps one KV cache per shared-block invocation (different network
depths attend over different histories) and per-layer SSM/conv states.

Long-context (500k) decode: when ``dist.seq_axis`` is set the shared-block
KV caches are sequence-sharded over the data axis and attention runs the
distributed flash-decoding combine (dist/collectives.py).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.gqs_layer import apply_linear
from repro.dist import collectives as C
from repro.models import layers as L
from repro.models import ssm as S


def _n_groups(cfg) -> Tuple[int, int]:
    every = cfg.shared_attn_every
    return cfg.n_layers // every, cfg.n_layers % every


def init_params(rng, cfg) -> Dict:
    dtype = cfg.params_dtype
    ng, rem = _n_groups(cfg)
    every = cfg.shared_attn_every
    k_emb, k_g, k_t, k_sh, k_head = jax.random.split(rng, 5)

    gkeys = jax.random.split(k_g, ng * every).reshape(ng, every, 2)
    grouped = jax.vmap(jax.vmap(lambda k: S.mamba_init(k, cfg, dtype)))(gkeys)
    p = {
        "embed": jax.random.normal(k_emb, (cfg.vocab, cfg.d_model),
                                   dtype) * 0.02,
        "groups": grouped,
        "shared": {
            "ln1": L.norm_init(cfg.d_model, dtype),
            "attn": L.attn_init(jax.random.fold_in(k_sh, 0), cfg, dtype),
            "ln2": L.norm_init(cfg.d_model, dtype),
            "mlp": L.mlp_init(jax.random.fold_in(k_sh, 1), cfg.d_model,
                              cfg.d_ff, cfg.mlp_type, dtype),
        },
        "final_norm": L.norm_init(cfg.d_model, dtype),
        "lm_head": L.linear_init(k_head, cfg.vocab, cfg.d_model, dtype,
                                 scale=0.02),
    }
    if rem:
        tkeys = jax.random.split(k_t, rem).reshape(rem, 2)
        p["tail"] = jax.vmap(lambda k: S.mamba_init(k, cfg, dtype))(tkeys)
    return p


def _shared_block(sp: Dict, h: jnp.ndarray, positions, cfg,
                  use_pallas) -> jnp.ndarray:
    a = L.attention_block(sp["attn"], L.rmsnorm(h, sp["ln1"], cfg.norm_eps),
                          positions, cfg, use_pallas=use_pallas)
    h = h + a
    m = L.mlp_block(sp["mlp"], L.rmsnorm(h, sp["ln2"], cfg.norm_eps),
                    cfg.mlp_type, use_pallas)
    return h + m


def forward(params: Dict, tokens: jnp.ndarray, cfg, dist=None,
            use_pallas: bool = False,
            last_only: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    if dist is not None:
        h = dist.constrain(h, dist.batch_spec(3))
    sp = params["shared"]

    def group_body(hh, gp):
        def inner(hh2, lp):
            return hh2 + S.mamba_block(lp, hh2, cfg, use_pallas), None
        hh, _ = jax.lax.scan(inner, hh, gp)
        hh = _shared_block(sp, hh, positions, cfg, use_pallas)
        if dist is not None:
            hh = dist.constrain(hh, dist.batch_spec(3))
        return hh, None

    if cfg.remat:
        group_body = jax.checkpoint(
            group_body, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(group_body, h, params["groups"])
    if "tail" in params:
        def inner(hh2, lp):
            return hh2 + S.mamba_block(lp, hh2, cfg, use_pallas), None
        h, _ = jax.lax.scan(inner, h, params["tail"])
    if last_only:
        h = h[:, -1:, :]
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = apply_linear(params["lm_head"], h)
    return logits, jnp.float32(0.0)


def init_cache(cfg, batch: int, max_seq: int, dtype=None) -> Dict:
    dtype = dtype or cfg.compute_dtype
    ng, rem = _n_groups(cfg)
    every = cfg.shared_attn_every
    one = S.mamba_cache_init(cfg, batch, dtype)
    stack = lambda tree, *dims: jax.tree_util.tree_map(
        lambda l: jnp.zeros(dims + l.shape, l.dtype), tree)
    cache = {
        "groups": stack(one, ng, every),
        "attn": {
            "k": jnp.zeros((ng, batch, max_seq, cfg.n_kv_heads, cfg.hd),
                           dtype),
            "v": jnp.zeros((ng, batch, max_seq, cfg.n_kv_heads, cfg.hd),
                           dtype),
        },
    }
    if rem:
        cache["tail"] = stack(one, rem)
    return cache


def _attn_decode_dist(sp, h, kv, pos, cfg, dist, use_pallas):
    """Shared-block decode attention; distributed flash-decoding when the
    KV cache is sequence-sharded (long-context, batch too small for DP)."""
    b = h.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = L.attn_qkv(sp["attn"], h, positions, cfg, use_pallas)
    if dist is not None and dist.seq_axis is not None:
        k_cache = C.update_sharded_cache(kv["k"], k, pos, dist.mesh,
                                         dist.seq_axis)
        v_cache = C.update_sharded_cache(kv["v"], v, pos, dist.mesh,
                                         dist.seq_axis)
        o = C.sharded_decode_attention(q, k_cache, v_cache, pos + 1,
                                       dist.mesh, dist.seq_axis)
    else:
        k_cache = jax.lax.dynamic_update_slice(
            kv["k"], k.astype(kv["k"].dtype), (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            kv["v"], v.astype(kv["v"].dtype), (0, pos, 0, 0))
        o = L.decode_attention(q, k_cache, v_cache, pos + 1)
    y = apply_linear(sp["attn"]["wo"], o.reshape(b, 1, -1),
                     use_pallas=use_pallas)
    return y, {"k": k_cache, "v": v_cache}


def decode_step(params: Dict, cache: Dict, tokens: jnp.ndarray,
                pos: jnp.ndarray, cfg, dist=None, use_pallas: bool = False
                ) -> Tuple[jnp.ndarray, Dict]:
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    sp = params["shared"]

    def group_body(hh, xs):
        gp, gc, kv = xs

        def inner(hh2, xs2):
            lp, lc = xs2
            y, new_lc = S.mamba_decode(lp, hh2, lc, cfg, use_pallas)
            return hh2 + y, new_lc

        hh, new_gc = jax.lax.scan(inner, hh, (gp, gc))
        hn = L.rmsnorm(hh, sp["ln1"], cfg.norm_eps)
        a, new_kv = _attn_decode_dist(sp, hn, kv, pos, cfg, dist, use_pallas)
        hh = hh + a
        m = L.mlp_block(sp["mlp"], L.rmsnorm(hh, sp["ln2"], cfg.norm_eps),
                        cfg.mlp_type, use_pallas)
        return hh + m, (new_gc, new_kv)

    h, (new_groups, new_attn) = jax.lax.scan(
        group_body, h, (params["groups"], cache["groups"], cache["attn"]))
    new_cache = {"groups": new_groups, "attn": new_attn}
    if "tail" in params:
        def inner(hh2, xs2):
            lp, lc = xs2
            y, new_lc = S.mamba_decode(lp, hh2, lc, cfg, use_pallas)
            return hh2 + y, new_lc
        h, new_tail = jax.lax.scan(inner, h, (params["tail"], cache["tail"]))
        new_cache["tail"] = new_tail
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = apply_linear(params["lm_head"], h)
    return logits, new_cache
