"""Mamba2 / SSD (state-space duality, arXiv:2405.21060).

Training/prefill uses the chunked SSD algorithm (quadratic within a chunk,
linear recurrence across chunks). Decode uses the O(1)-per-step recurrent
state update. The in/out projections are GQS-compressible linears; the conv
and SSD scan themselves carry no GEMV weight traffic (noted inapplicability
in DESIGN.md §6).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.gqs_layer import apply_linear
from repro.models.layers import linear_init, norm_init, rmsnorm


def _ssm_dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_ch


def mamba_init(rng, cfg, dtype=jnp.float32) -> Dict:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, n_heads, conv_ch = _ssm_dims(cfg)
    ks = jax.random.split(rng, 5)
    in_dim = 2 * d_inner + 2 * s.n_groups * s.d_state + n_heads
    return {
        "in_proj": linear_init(ks[0], in_dim, d, dtype),
        "conv_w": jax.random.normal(ks[1], (conv_ch, s.conv_width),
                                    dtype) * 0.2,
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads).astype(dtype)),
        "D": jnp.ones((n_heads,), dtype),
        "dt_bias": jnp.zeros((n_heads,), dtype),
        "norm": norm_init(d_inner, dtype),
        "out_proj": linear_init(ks[4], d, d_inner, dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray
                 ) -> jnp.ndarray:
    """Depthwise causal conv via shifted adds. x: [B, S, C]; w: [C, W]."""
    width = w.shape[1]
    w = w.astype(x.dtype)
    out = x * w[None, None, :, -1]
    for i in range(1, width):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i or None, :]
        shifted = shifted[:, :x.shape[1], :]
        out = out + shifted * w[None, None, :, -1 - i]
    return out + b[None, None, :].astype(x.dtype)


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """x: [..., L] -> [..., L, L]; out[i,j] = sum_{k in (j, i]} x[k] for
    i >= j, else -inf."""
    c = jnp.cumsum(x, axis=-1)
    d = c[..., :, None] - c[..., None, :]
    ll = x.shape[-1]
    mask = jnp.tril(jnp.ones((ll, ll), bool), k=0)
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x, dt, a, bmat, cmat, chunk: int,
                initial_state=None, unroll: bool = False):
    """Chunked SSD (port of mamba2's ssd_minimal_discrete, group-aware).

    x: [B, S, H, P]; dt: [B, S, H] (>0); a: [H] (<0); bmat/cmat: [B, S, G, N].
    Returns (y [B, S, H, P], final_state [B, H, P, N]).
    """
    b, s, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    rep = h // g
    nc = s // chunk

    xdt = (x * dt[..., None]).astype(jnp.float32)
    da = (dt * a[None, None, :]).astype(jnp.float32)        # [B, S, H]

    xc = xdt.reshape(b, nc, chunk, h, p)
    dac = da.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)  # [B, H, NC, L]
    bh = jnp.repeat(bmat.reshape(b, nc, chunk, g, n), rep, axis=3) \
        if rep > 1 else bmat.reshape(b, nc, chunk, g, n)
    ch = jnp.repeat(cmat.reshape(b, nc, chunk, g, n), rep, axis=3) \
        if rep > 1 else cmat.reshape(b, nc, chunk, g, n)
    bh = bh.astype(jnp.float32)
    ch = ch.astype(jnp.float32)

    # 1. intra-chunk (diagonal blocks)
    lmat = jnp.exp(_segsum(dac))                             # [B,H,NC,L,L]
    lmat = jnp.where(jnp.isfinite(lmat), lmat, 0.0)
    scores = jnp.einsum("bclhn,bcshn->bhcls", ch, bh)
    y_diag = jnp.einsum("bhcls,bcshp->bclhp", scores * lmat, xc)

    # 2. per-chunk states
    a_cs = jnp.cumsum(dac, axis=-1)                          # [B,H,NC,L]
    a_tot = a_cs[..., -1]                                    # [B,H,NC]
    decay_states = jnp.exp(a_tot[..., None] - a_cs)
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn", bh, decay_states, xc)

    # 3. inter-chunk recurrence (scan over chunks)
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), jnp.float32)

    def step(carry, inp):
        st_c, atot_c = inp                                   # [B,H,P,N],[B,H]
        prev = carry
        new = st_c + jnp.exp(atot_c)[..., None, None] * prev
        return new, prev                                     # emit incoming

    (final_state, prev_states) = jax.lax.scan(
        step, initial_state.astype(jnp.float32),
        (states.transpose(1, 0, 2, 3, 4), a_tot.transpose(2, 0, 1)),
        unroll=nc if unroll else 1)
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)       # [B,NC,H,P,N]

    # 4. inter-chunk contribution
    state_decay = jnp.exp(a_cs)                              # [B,H,NC,L]
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", ch, prev_states,
                       state_decay)
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y.astype(x.dtype), final_state


def ssd_decode_step(state, x, dt, a, bmat, cmat):
    """One recurrent step. state: [B,H,P,N]; x: [B,H,P]; dt: [B,H];
    bmat/cmat: [B,G,N]. Returns (y [B,H,P], new_state)."""
    b, h, p, n = state.shape
    g = bmat.shape[1]
    rep = h // g
    bh = jnp.repeat(bmat, rep, axis=1) if rep > 1 else bmat   # [B,H,N]
    chh = jnp.repeat(cmat, rep, axis=1) if rep > 1 else cmat
    da = jnp.exp(dt * a[None, :]).astype(jnp.float32)         # [B,H]
    xdt = (x * dt[..., None]).astype(jnp.float32)
    new_state = state * da[..., None, None] + \
        jnp.einsum("bhp,bhn->bhpn", xdt, bh.astype(jnp.float32))
    y = jnp.einsum("bhn,bhpn->bhp", chh.astype(jnp.float32), new_state)
    return y.astype(x.dtype), new_state


def _split_proj(zxbcdt, cfg):
    s = cfg.ssm
    d_inner, n_heads, _ = _ssm_dims(cfg)
    gn = s.n_groups * s.d_state
    z, xs, bmat, cmat, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + gn,
                 2 * d_inner + 2 * gn], axis=-1)
    return z, xs, bmat, cmat, dt


def mamba_block(p: Dict, x: jnp.ndarray, cfg,
                use_pallas: bool = False) -> jnp.ndarray:
    """Full-sequence Mamba2 block. x: [B, S, d] -> [B, S, d]."""
    s_cfg = cfg.ssm
    b, s, d = x.shape
    d_inner, n_heads, conv_ch = _ssm_dims(cfg)

    zxbcdt = apply_linear(p["in_proj"], x, use_pallas=use_pallas)
    z, xs, bmat, cmat, dt = _split_proj(zxbcdt, cfg)

    conv_in = jnp.concatenate([xs, bmat, cmat], axis=-1)     # [B,S,conv_ch]
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    xs, bmat, cmat = jnp.split(conv_out,
                               [d_inner, d_inner + s_cfg.n_groups *
                                s_cfg.d_state], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))   # [B,S,H]
    a = -jnp.exp(p["A_log"].astype(jnp.float32))             # [H]
    xh = xs.reshape(b, s, n_heads, s_cfg.head_dim)
    bm = bmat.reshape(b, s, s_cfg.n_groups, s_cfg.d_state)
    cm = cmat.reshape(b, s, s_cfg.n_groups, s_cfg.d_state)

    chunk = min(s_cfg.chunk, s)
    y, _ = ssd_chunked(xh, dt, a, bm, cm, chunk,
                       unroll=cfg.analysis_unroll)
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(b, s, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return apply_linear(p["out_proj"], y, use_pallas=use_pallas)


def mamba_cache_init(cfg, batch: int, dtype=jnp.float32) -> Dict:
    s = cfg.ssm
    d_inner, n_heads, conv_ch = _ssm_dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_ch), dtype),
        "state": jnp.zeros((batch, n_heads, s.head_dim, s.d_state),
                           jnp.float32),
    }


def mamba_decode(p: Dict, x: jnp.ndarray, cache: Dict, cfg,
                 use_pallas: bool = False) -> Tuple[jnp.ndarray, Dict]:
    """One-token step. x: [B, 1, d]."""
    s_cfg = cfg.ssm
    b = x.shape[0]
    d_inner, n_heads, conv_ch = _ssm_dims(cfg)

    zxbcdt = apply_linear(p["in_proj"], x[:, 0], use_pallas=use_pallas)
    z, xs, bmat, cmat, dt = _split_proj(zxbcdt, cfg)

    conv_in = jnp.concatenate([xs, bmat, cmat], axis=-1)     # [B, conv_ch]
    window = jnp.concatenate([cache["conv"],
                              conv_in[:, None, :]], axis=1)  # [B, W, C]
    conv_out = jnp.einsum("bwc,cw->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out).astype(x.dtype)
    xs, bmat, cmat = jnp.split(conv_out,
                               [d_inner, d_inner + s_cfg.n_groups *
                                s_cfg.d_state], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xs.reshape(b, n_heads, s_cfg.head_dim)
    bm = bmat.reshape(b, s_cfg.n_groups, s_cfg.d_state)
    cm = cmat.reshape(b, s_cfg.n_groups, s_cfg.d_state)

    y, new_state = ssd_decode_step(cache["state"], xh, dt, a, bm, cm)
    y = y + p["D"].astype(y.dtype)[None, :, None] * xh
    y = y.reshape(b, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    y = apply_linear(p["out_proj"], y, use_pallas=use_pallas)
    new_cache = {"conv": window[:, 1:], "state": new_state}
    return y[:, None, :], new_cache
