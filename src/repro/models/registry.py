"""Family dispatch: one API over all architectures.

    api = get_model(cfg)
    params = api.init_params(rng, cfg)
    logits, aux = api.forward(params, batch, cfg, dist)       # train/prefill
    cache = api.init_cache(cfg, batch_size, max_seq)
    logits, cache = api.decode_step(params, cache, tok, pos, cfg, dist)

``batch`` is a dict: tokens (all), labels (train), patch_embeds (vlm),
frames (encdec).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec, hybrid, ssm_lm, transformer


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    init_params: Callable
    forward: Callable        # (params, batch, cfg, dist, use_pallas)
    init_cache: Callable     # (cfg, batch_size, max_seq, dtype)
    decode_step: Callable    # (params, cache, tokens, pos, cfg, dist, ...)
    prime_cache: Optional[Callable] = None   # encdec cross-KV fill
    # continuous-batching engine hooks (paged KV cache; engine/)
    init_paged_cache: Optional[Callable] = None  # (cfg, n_pages, page_size)
    prefill: Optional[Callable] = None  # (params, cache, tokens, lengths,
    #                                      block_tables, cfg, dist, ...)

    @property
    def supports_paged_cache(self) -> bool:
        """Continuous-batching capability: the family provides BOTH the
        paged pool layout and the batched prefill (the engine needs the
        pair — `engine/kv_cache.py`, `launch/serve.py` and the engine
        constructor all gate on this flag and report
        :func:`paged_families` in their error)."""
        return self.init_paged_cache is not None and self.prefill is not None


def _tf_forward(params, batch, cfg, dist=None, use_pallas=False,
                last_only=False):
    return transformer.forward(params, batch["tokens"], cfg, dist,
                               use_pallas,
                               patch_embeds=batch.get("patch_embeds"),
                               last_only=last_only)


def _encdec_forward(params, batch, cfg, dist=None, use_pallas=False,
                    last_only=False):
    return encdec.forward(params, batch["tokens"], batch["frames"], cfg,
                          dist, use_pallas, last_only=last_only)


def _hybrid_forward(params, batch, cfg, dist=None, use_pallas=False,
                    last_only=False):
    return hybrid.forward(params, batch["tokens"], cfg, dist, use_pallas,
                          last_only=last_only)


def _ssm_forward(params, batch, cfg, dist=None, use_pallas=False,
                 last_only=False):
    return ssm_lm.forward(params, batch["tokens"], cfg, dist, use_pallas,
                          last_only=last_only)


_FAMILIES: Dict[str, ModelAPI] = {
    "dense": ModelAPI(transformer.init_params, _tf_forward,
                      transformer.init_cache, transformer.decode_step,
                      init_paged_cache=transformer.init_paged_cache,
                      prefill=transformer.prefill),
    "moe": ModelAPI(transformer.init_params, _tf_forward,
                    transformer.init_cache, transformer.decode_step,
                    init_paged_cache=transformer.init_paged_cache,
                    prefill=transformer.prefill),
    "mla_moe": ModelAPI(transformer.init_params, _tf_forward,
                        transformer.init_cache, transformer.decode_step,
                        init_paged_cache=transformer.init_paged_cache,
                        prefill=transformer.prefill),
    "vlm": ModelAPI(transformer.init_params, _tf_forward,
                    transformer.init_cache, transformer.decode_step,
                    init_paged_cache=transformer.init_paged_cache,
                    prefill=transformer.prefill),
    "encdec": ModelAPI(encdec.init_params, _encdec_forward,
                       encdec.init_cache, encdec.decode_step,
                       prime_cache=encdec.prime_cross_cache),
    "hybrid": ModelAPI(hybrid.init_params, _hybrid_forward,
                       hybrid.init_cache, hybrid.decode_step),
    "ssm": ModelAPI(ssm_lm.init_params, _ssm_forward,
                    ssm_lm.init_cache, ssm_lm.decode_step),
}


def paged_families() -> List[str]:
    """Families the continuous-batching engine can serve (paged cache +
    batched prefill) — the supported-family list quoted by every
    paged-cache capability error."""
    return sorted(f for f, api in _FAMILIES.items()
                  if api.supports_paged_cache)


def get_model(cfg) -> ModelAPI:
    try:
        return _FAMILIES[cfg.family]
    except KeyError:
        raise ValueError(f"unknown model family {cfg.family!r}; "
                         f"known: {sorted(_FAMILIES)}")


def lm_loss(logits: jnp.ndarray, labels: jnp.ndarray,
            mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Next-token CE. logits [B, S, V] (may be longer than labels when a
    modality prefix was prepended — align to the tail); labels [B, S]."""
    s = labels.shape[1]
    logits = logits[:, -s:, :].astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    # vocab-parallel-safe gold gather: masked reduction over the (possibly
    # model-sharded) vocab dim instead of take_along_axis (which would
    # all-gather the logits under GSPMD).
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    gold = jnp.sum(jnp.where(iota == labels[..., None], logits, 0.0),
                   axis=-1)
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
