"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV is compressed to a rank-`kv_lora_rank` latent + one shared RoPE key head;
the decode cache stores only [B, S, kv_lora_rank + qk_rope_dim] — the MLA
memory win. Decode uses the *absorbed* formulation: q_nope is projected
through W_UK once per step so scores contract directly against the latent
cache (no per-step K up-projection over the whole history).

GQSA note: w_qa/w_qb/w_kva/wo are GQS-compressible GEMVs; w_uk/w_uv are used
in per-head einsum form (absorbed path) and stay dense FP (~8M params each —
documented exclusion, DESIGN.md §6).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.gqs_layer import apply_linear
from repro.models.layers import (apply_rope, decode_attention,
                                 flash_attention, linear_init, norm_init,
                                 paged_block_geometry, rmsnorm)


def mla_init(rng, cfg, dtype=jnp.float32) -> Dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(rng, 7)
    return {
        "w_qa": linear_init(ks[0], m.q_lora_rank, d, dtype),
        "q_norm": norm_init(m.q_lora_rank, dtype),
        "w_qb": linear_init(ks[1], h * (m.qk_nope_dim + m.qk_rope_dim),
                            m.q_lora_rank, dtype),
        "w_kva": linear_init(ks[2], m.kv_lora_rank + m.qk_rope_dim, d, dtype),
        "kv_norm": norm_init(m.kv_lora_rank, dtype),
        "w_uk": jax.random.normal(ks[3], (h, m.qk_nope_dim, m.kv_lora_rank),
                                  dtype) / jnp.sqrt(m.kv_lora_rank),
        "w_uv": jax.random.normal(ks[4], (h, m.v_dim, m.kv_lora_rank),
                                  dtype) / jnp.sqrt(m.kv_lora_rank),
        "wo": linear_init(ks[5], d, h * m.v_dim, dtype),
    }


def _mla_q(p: Dict, x: jnp.ndarray, positions, cfg, use_pallas):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    cq = rmsnorm(apply_linear(p["w_qa"], x, use_pallas=use_pallas),
                 p["q_norm"], cfg.norm_eps)
    q = apply_linear(p["w_qb"], cq, use_pallas=use_pallas)
    q = q.reshape(b, s, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv_latent(p: Dict, x: jnp.ndarray, positions, cfg, use_pallas):
    m = cfg.mla
    ckv_full = apply_linear(p["w_kva"], x, use_pallas=use_pallas)
    c_kv, k_rope = jnp.split(ckv_full, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_prefill_paged(p: Dict, x: jnp.ndarray, positions: jnp.ndarray, cfg,
                      use_pallas: bool = False
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence MLA attention + the latent row each token pages.

    Returns (attn_out [B, S, d], latent [B, S, R + rope]). The latent
    row is EXACTLY what :func:`mla_decode`'s dense cache stores per
    position (post-norm ``c_kv`` ++ post-RoPE ``k_rope``), so a paged
    pool filled from it can be scored with the absorbed-W_UK decode path
    (:func:`mla_decode_paged`) and stays the dense path's parity twin.
    Attention itself is the unabsorbed flash form — prefill is
    compute-bound, so K/V are up-projected once for the whole sequence.
    """
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _mla_q(p, x, positions, cfg, use_pallas)
    c_kv, k_rope = _mla_kv_latent(p, x, positions, cfg, use_pallas)

    k_nope = jnp.einsum("bsr,hdr->bshd", c_kv,
                        p["w_uk"].astype(c_kv.dtype))
    v = jnp.einsum("bsr,hvr->bshv", c_kv, p["w_uv"].astype(c_kv.dtype))
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, s, h, m.qk_rope_dim))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    o = flash_attention(q, k, v, causal=True,
                        block_q=cfg.attn_block_q, block_k=cfg.attn_block_k,
                        unroll=cfg.analysis_unroll)
    out = apply_linear(p["wo"], o.reshape(b, s, -1), use_pallas=use_pallas)
    return out, jnp.concatenate([c_kv, k_rope], axis=-1)


def mla_block(p: Dict, x: jnp.ndarray, positions: jnp.ndarray, cfg,
              use_pallas: bool = False) -> jnp.ndarray:
    """Full-sequence MLA (train / prefill). x: [B, S, d]."""
    out, _ = mla_prefill_paged(p, x, positions, cfg, use_pallas)
    return out


def mla_cache_init(cfg, batch: int, max_seq: int, dtype) -> Dict:
    m = cfg.mla
    return {"c_kv": jnp.zeros((batch, max_seq, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_seq, m.qk_rope_dim), dtype)}


def _absorbed_q(p: Dict, q_nope: jnp.ndarray, q_rope: jnp.ndarray, cfg
                ) -> jnp.ndarray:
    """Absorb W_UK into q so scores contract against the latent directly:
    [B, T, H, nope/rope] -> pre-scaled [B, T, H, R + rope]. The score
    scale must match the UNABSORBED head dim, so q carries the
    sqrt(fake/true) correction (attention kernels divide by
    sqrt(R + rope))."""
    m = cfg.mla
    q_lat = jnp.einsum("bshd,hdr->bshr", q_nope,
                       p["w_uk"].astype(q_nope.dtype))       # [B,T,H,R]
    # treat latent + rope as a single KV head of dim R + rope
    q_cat = jnp.concatenate([q_lat, q_rope], axis=-1)        # [B,T,H,R+rope]
    true_dim = m.qk_nope_dim + m.qk_rope_dim
    fake_dim = m.kv_lora_rank + m.qk_rope_dim
    return q_cat * jnp.sqrt(fake_dim / true_dim).astype(q_cat.dtype)


def mla_decode(p: Dict, x: jnp.ndarray, cache: Dict, pos, cfg,
               use_pallas: bool = False) -> Tuple[jnp.ndarray, Dict]:
    """Absorbed single-step decode. x: [B, 1, d]."""
    m = cfg.mla
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(p, x, positions, cfg, use_pallas)
    c_kv_new, k_rope_new = _mla_kv_latent(p, x, positions, cfg, use_pallas)

    c_kv = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype),
        (0, pos, 0))

    q_scaled = _absorbed_q(p, q_nope, q_rope, cfg)
    k_cat = jnp.concatenate([c_kv, k_rope], axis=-1)[:, :, None, :]
    ctx = decode_attention(q_scaled, k_cat, c_kv[:, :, None, :], pos + 1)
    # ctx: [B,1,H,R] -> per-head value up-projection
    v = jnp.einsum("bshr,hvr->bshv", ctx, p["w_uv"].astype(ctx.dtype))
    return apply_linear(p["wo"], v.reshape(b, 1, -1), use_pallas=use_pallas)\
        , {"c_kv": c_kv, "k_rope": k_rope}


def mla_decode_paged(p: Dict, x: jnp.ndarray, cache: Dict,
                     block_tables: jnp.ndarray, positions: jnp.ndarray,
                     cfg, use_pallas: bool = False,
                     tree: Optional[Dict] = None,
                     feed_len: Optional[jnp.ndarray] = None
                     ) -> Tuple[jnp.ndarray, Dict]:
    """T-token absorbed MLA decode against the PAGED latent pool (one
    layer's view) — the mla_moe twin of
    `models/layers.py:attention_decode_paged` (DESIGN.md §9).

    x: [B, T, d]; positions: [B] write position of each slot's first
    token; block_tables: [B, MP] page ids (>= P entries are sentinels);
    cache: ``{"lat_pages": [P, ps, R + rope]}`` — ONE logical KV "head"
    per page pool holding post-norm ``c_kv`` ++ post-RoPE ``k_rope``.
    There is NO V pool: the value of a cached token is the leading R
    dims of the same latent row, up-projected through W_UV only AFTER
    attention — so paging the latent pays one pool instead of K + V.

    T=1 is plain continuous-batching decode, T=K+1 the speculative
    verify staircase, and ``tree`` the token-tree block (RoPE at tree
    depth, ancestor-bitmap masking — identical semantics to the GQA
    path, shared via :func:`layers.paged_block_geometry`).

    ``use_pallas`` routes the attention through the fused latent kernel
    (`kernels/ops.py:paged_latent_attention` — lane-dim-tiled scores for
    R + rope > 128); the jnp path gathers pages densely and reuses
    :func:`decode_attention`, the same op sequence as the dense
    :func:`mla_decode` oracle.
    """
    m = cfg.mla
    b, t, _ = x.shape
    lat = cache["lat_pages"]
    page_size = lat.shape[1]
    pos_bt, rope_pos, length, base, anc, window = paged_block_geometry(
        positions, t, tree)
    q_nope, q_rope = _mla_q(p, x, rope_pos, cfg, use_pallas)
    c_kv_new, k_rope_new = _mla_kv_latent(p, x, rope_pos, cfg, use_pallas)
    lat_new = jnp.concatenate([c_kv_new, k_rope_new], axis=-1)   # [B,T,R+r]

    page = jnp.take_along_axis(block_tables, pos_bt // page_size,
                               axis=1)                       # [B, T]
    off = pos_bt % page_size
    if feed_len is not None:
        # ragged feed (prefix-cache tail prefill): positions at or past a
        # row's feed_len write to the out-of-range sentinel, same masking
        # as layers.py:attention_decode_paged
        page = jnp.where(
            jnp.arange(t, dtype=jnp.int32)[None, :] < feed_len[:, None],
            page, lat.shape[0])
    new = {"lat_pages": lat.at[page, off].set(lat_new.astype(lat.dtype))}

    q_scaled = _absorbed_q(p, q_nope, q_rope, cfg)           # [B,T,H,R+r]
    if use_pallas:
        from repro.kernels import ops as kops
        ctx = kops.paged_latent_attention(
            q_scaled, new["lat_pages"], length, block_tables,
            v_rank=m.kv_lora_rank, anc=anc, anc_base=base,
            anc_window=window).astype(q_scaled.dtype)
    else:
        g = new["lat_pages"][block_tables]    # OOB sentinels clip (masked)
        g = g.reshape(b, -1, lat.shape[-1])
        ctx = decode_attention(q_scaled, g[:, :, None, :],
                               g[:, :, None, :m.kv_lora_rank], length,
                               anc, base, window)
    v = jnp.einsum("bshr,hvr->bshv", ctx, p["w_uv"].astype(ctx.dtype))
    return apply_linear(p["wo"], v.reshape(b, t, -1),
                        use_pallas=use_pallas), new
