"""Decoder-only LM: dense / MoE / MLA-MoE / VLM families.

Layers are weight-stacked and iterated with lax.scan (small HLO, fast
compiles at 60+ layers); the per-layer body is remat'd when cfg.remat.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.gqs_layer import apply_linear
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _layer_init(rng, cfg, dtype):
    ks = jax.random.split(rng, 4)
    p = {"ln1": L.norm_init(cfg.d_model, dtype),
         "ln2": L.norm_init(cfg.d_model, dtype)}
    if cfg.family == "mla_moe":
        p["attn"] = MLA.mla_init(ks[0], cfg, dtype)
    else:
        p["attn"] = L.attn_init(ks[0], cfg, dtype)
    if cfg.moe is not None:
        p["moe"] = MOE.moe_init(ks[1], cfg, dtype)
    else:
        p["mlp"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_type,
                              dtype)
    return p


def init_params(rng, cfg) -> Dict:
    dtype = cfg.params_dtype
    k_embed, k_layers, k_head = jax.random.split(rng, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    params = {
        "embed": jax.random.normal(k_embed, (cfg.vocab, cfg.d_model),
                                   dtype) * 0.02,
        "layers": jax.vmap(lambda k: _layer_init(k, cfg, dtype))(layer_keys),
        "final_norm": L.norm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.linear_init(k_head, cfg.vocab, cfg.d_model,
                                          dtype, scale=0.02)
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _block(lp: Dict, h: jnp.ndarray, positions: jnp.ndarray, cfg, dist,
           use_pallas) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if cfg.family == "mla_moe":
        a = MLA.mla_block(lp["attn"], L.rmsnorm(h, lp["ln1"], cfg.norm_eps),
                          positions, cfg, use_pallas)
    else:
        a = L.attention_block(lp["attn"],
                              L.rmsnorm(h, lp["ln1"], cfg.norm_eps),
                              positions, cfg, use_pallas=use_pallas,
                              dist=dist)
    h = h + a
    hn = L.rmsnorm(h, lp["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        m, aux = MOE.moe_block(lp["moe"], hn, cfg, dist, use_pallas)
    else:
        m, aux = L.mlp_block(lp["mlp"], hn, cfg.mlp_type, use_pallas), 0.0
    return h + m, jnp.asarray(aux, jnp.float32)


def embed_tokens(params: Dict, tokens: jnp.ndarray, cfg) -> jnp.ndarray:
    return jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)


def unembed(params: Dict, h: jnp.ndarray, cfg) -> jnp.ndarray:
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", h,
                          params["embed"].astype(h.dtype))
    return apply_linear(params["lm_head"], h)


def forward(params: Dict, tokens: jnp.ndarray, cfg, dist=None,
            use_pallas: bool = False,
            patch_embeds: Optional[jnp.ndarray] = None,
            last_only: bool = False
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens: [B, S_text]. Returns (logits [B, S, V], aux loss scalar).

    VLM: ``patch_embeds`` [B, P, d] are prepended to the token embeddings
    (the assignment's modality-frontend stub); S = P + S_text.
    """
    h = embed_tokens(params, tokens, cfg)
    if patch_embeds is not None:
        h = jnp.concatenate([patch_embeds.astype(h.dtype), h], axis=1)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    # sequence-parallel residual stream (Megatron-SP): keep h sharded on
    # (batch, seq@model); per-token ops run local, TP matmuls turn their
    # activation all-reduces into reduce-scatter/all-gather pairs (2x fewer
    # bytes). Enabled together with SP attention.
    if dist is not None and getattr(dist, "sp_attention", False) \
            and s % dist.axis_size(dist.model_axis) == 0:
        res_spec = __import__("jax").sharding.PartitionSpec(
            dist.batch_axes, dist.model_axis, None)
    elif dist is not None:
        res_spec = dist.batch_spec(3)
    else:
        res_spec = None
    if dist is not None:
        h = dist.constrain(h, res_spec)

    def body(carry, lp):
        hh, aux = carry
        hh, aux_l = _block(lp, hh, positions, cfg, dist, use_pallas)
        if dist is not None:
            hh = dist.constrain(hh, res_spec)
        return (hh, aux + aux_l), None

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    (h, aux), _ = jax.lax.scan(body, (h, jnp.float32(0.0)), params["layers"])
    if last_only:
        h = h[:, -1:, :]
    logits = unembed(params, h, cfg)
    return logits, aux / cfg.n_layers


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_seq: int, dtype=None) -> Dict:
    dtype = dtype or cfg.compute_dtype
    lyr = cfg.n_layers
    if cfg.family == "mla_moe":
        m = cfg.mla
        return {"c_kv": jnp.zeros((lyr, batch, max_seq, m.kv_lora_rank),
                                  dtype),
                "k_rope": jnp.zeros((lyr, batch, max_seq, m.qk_rope_dim),
                                    dtype)}
    if cfg.kv_cache_dtype == "int8":
        kh = cfg.n_kv_heads
        return {"k": jnp.zeros((lyr, batch, max_seq, kh, cfg.hd), jnp.int8),
                "v": jnp.zeros((lyr, batch, max_seq, kh, cfg.hd), jnp.int8),
                "k_scale": jnp.zeros((lyr, batch, max_seq, kh), jnp.float32),
                "v_scale": jnp.zeros((lyr, batch, max_seq, kh), jnp.float32)}
    return {"k": jnp.zeros((lyr, batch, max_seq, cfg.n_kv_heads, cfg.hd),
                           dtype),
            "v": jnp.zeros((lyr, batch, max_seq, cfg.n_kv_heads, cfg.hd),
                           dtype)}


def init_paged_cache(cfg, num_pages: int, page_size: int,
                     dtype=None) -> Dict:
    """Paged KV pool: fixed-size pages shared by all slots via per-request
    block tables (see DESIGN.md §3). Leaves are [L, P, ps, ...] so the
    decode scan hands each layer its [P, ps, ...] view.

    ``mla_moe`` pages the LATENT cache (DESIGN.md §9): one pool of
    [L, P, ps, kv_lora_rank + qk_rope_dim] rows — a single logical KV
    "head" per page, and NO V pool (values are up-projected from the
    latent through W_UV after attention). Latent pages stay in the
    compute dtype regardless of ``kv_cache_dtype`` (int8 latent pages
    are a recorded follow-on, ROADMAP)."""
    dtype = dtype or cfg.compute_dtype
    if cfg.family == "mla_moe":
        m = cfg.mla
        return {"lat_pages": jnp.zeros(
            (cfg.n_layers, num_pages, page_size,
             m.kv_lora_rank + m.qk_rope_dim), dtype)}
    lyr, kh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    if cfg.kv_cache_dtype == "int8":
        return {"k_pages": jnp.zeros((lyr, num_pages, page_size, kh, hd),
                                     jnp.int8),
                "v_pages": jnp.zeros((lyr, num_pages, page_size, kh, hd),
                                     jnp.int8),
                "k_scale_pages": jnp.zeros((lyr, num_pages, page_size, kh),
                                           jnp.float32),
                "v_scale_pages": jnp.zeros((lyr, num_pages, page_size, kh),
                                           jnp.float32)}
    return {"k_pages": jnp.zeros((lyr, num_pages, page_size, kh, hd), dtype),
            "v_pages": jnp.zeros((lyr, num_pages, page_size, kh, hd), dtype)}


def prefill(params: Dict, cache: Dict, tokens: jnp.ndarray,
            lengths: jnp.ndarray, block_tables: jnp.ndarray, cfg,
            dist=None, use_pallas: bool = False
            ) -> Tuple[jnp.ndarray, Dict]:
    """True batched prefill: run the full (padded) prompts through flash
    attention ONCE and scatter every layer's K/V into the paged cache.

    tokens: [B, S] right-padded prompts; lengths: [B] valid prefix;
    block_tables: [B, MP] page ids. Padding positions map to the
    out-of-range page sentinel, so their K/V scatter-writes are dropped;
    causality keeps valid tokens from attending to the (trailing) padding.
    Returns (last-valid-token logits [B, 1, V], filled cache).
    """
    b, s = tokens.shape
    leaf = jax.tree_util.tree_leaves(cache)[0]
    num_pages, page_size = leaf.shape[1], leaf.shape[2]
    h = embed_tokens(params, tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    # (b, s) -> flat page/offset; invalid (padding) positions -> OOB page
    page = jnp.take_along_axis(
        block_tables, positions // page_size, axis=1)       # [B, S]
    page = jnp.where(positions < lengths[:, None], page, num_pages)
    off = positions % page_size
    mla = cfg.family == "mla_moe"
    int8 = "k_scale_pages" in cache

    def body(carry, xs):
        hh = carry
        lp, lc = xs
        hn = L.rmsnorm(hh, lp["ln1"], cfg.norm_eps)
        if mla:
            # full-seq latent attention; the latent row (post-norm c_kv
            # ++ post-RoPE k_rope) pages as ONE pool — no V scatter
            a, latent = MLA.mla_prefill_paged(lp["attn"], hn, positions,
                                              cfg, use_pallas)
            new_c = {"lat_pages": lc["lat_pages"].at[page, off].set(
                latent.astype(lc["lat_pages"].dtype))}
        else:
            q, k, v = L.attn_qkv(lp["attn"], hn, positions, cfg, use_pallas)
            o = L.flash_attention(q, k, v, causal=True,
                                  block_q=cfg.attn_block_q,
                                  block_k=cfg.attn_block_k,
                                  unroll=cfg.analysis_unroll)
            a = apply_linear(lp["attn"]["wo"], o.reshape(b, s, -1),
                             use_pallas=use_pallas)
            if int8:
                k_i8, k_sc = L.quantize_kv(k)
                v_i8, v_sc = L.quantize_kv(v)
                new_c = {
                    "k_pages": lc["k_pages"].at[page, off].set(k_i8),
                    "v_pages": lc["v_pages"].at[page, off].set(v_i8),
                    "k_scale_pages":
                        lc["k_scale_pages"].at[page, off].set(k_sc),
                    "v_scale_pages":
                        lc["v_scale_pages"].at[page, off].set(v_sc)}
            else:
                new_c = {
                    "k_pages": lc["k_pages"].at[page, off].set(
                        k.astype(lc["k_pages"].dtype)),
                    "v_pages": lc["v_pages"].at[page, off].set(
                        v.astype(lc["v_pages"].dtype))}
        hh = hh + a
        hn = L.rmsnorm(hh, lp["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            m, _ = MOE.moe_block(lp["moe"], hn, cfg, dist, use_pallas)
        else:
            m = L.mlp_block(lp["mlp"], hn, cfg.mlp_type, use_pallas)
        return hh + m, new_c

    h, new_cache = jax.lax.scan(body, h, (params["layers"], cache))
    # logits only at each row's last valid token (cheap unembed: [B, 1, V])
    h_last = jnp.take_along_axis(
        h, jnp.maximum(lengths - 1, 0)[:, None, None], axis=1)
    logits = unembed(params, h_last, cfg)
    return logits, new_cache


def decode_step(params: Dict, cache: Dict, tokens: jnp.ndarray,
                pos: jnp.ndarray, cfg, dist=None, use_pallas: bool = False,
                block_tables=None, max_live_pages: Optional[int] = None,
                tree: Optional[Dict] = None,
                feed_len: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, Dict]:
    """tokens: [B, T]; pos: scalar shared step index OR [B] per-slot
    positions. ``cache`` is either the contiguous cache from
    :func:`init_cache` (T must be 1) or the paged view from
    :func:`init_paged_cache` (then ``block_tables`` [B, MP] is required
    and T may exceed 1: token t is written/attended at pos + t — the
    speculative-decoding verify step's per-slot short-prefill).
    ``mla_moe`` paged caches route through the absorbed latent path
    (`models/mla.py:mla_decode_paged`); everything below — staircase,
    tree, clamp — applies unchanged.

    ``tree`` (paged cache only) switches the T fed tokens to token-tree
    semantics: ``{"depths": [T], "anc": [T], "window": int, "start":
    int}`` — RoPE at tree depth, per-query ancestor-bitmap masking over
    the fed window (`models/layers.py:attention_decode_paged`,
    DESIGN.md §8).

    ``feed_len`` [B] (paged cache only) makes the T-token feed ragged:
    row i's tokens at t >= feed_len[i] are padding — their K/V writes
    are dropped (sentinel-masked) and their logits are garbage to be
    discarded by the caller. This is the prefix-cache tail prefill
    (DESIGN.md §13): slots prefill unshared tails of different lengths
    padded to one T.

    ``max_live_pages`` (static) clamps the block tables to the batch's
    max *occupied* page count: every slot's allocation (prompt + budget
    + lookahead) fits in the leading entries, so the trailing all-
    sentinel columns carry no information — dropping them shrinks the
    jnp reference's dense page gather and the Pallas kernel's grid from
    O(max_pages) to O(occupied pages). The engine buckets the value
    (pow2) so retraces stay bounded. Returns (logits [B, T, V], cache).
    """
    paged = isinstance(cache, dict) and ("k_pages" in cache
                                         or "lat_pages" in cache)
    if paged and block_tables is None:
        raise ValueError("paged cache decode requires block_tables")
    if tree is not None and not paged:
        raise ValueError("token-tree decode requires the paged cache")
    if feed_len is not None and not paged:
        raise ValueError("ragged feed_len requires the paged cache")
    if paged and max_live_pages is not None:
        block_tables = block_tables[
            :, :max(1, min(max_live_pages, block_tables.shape[1]))]
    h = embed_tokens(params, tokens, cfg)

    def body(hh, xs):
        lp, lc = xs
        hn = L.rmsnorm(hh, lp["ln1"], cfg.norm_eps)
        if paged and cfg.family == "mla_moe":
            a, new_c = MLA.mla_decode_paged(lp["attn"], hn, lc,
                                            block_tables, pos, cfg,
                                            use_pallas, tree=tree,
                                            feed_len=feed_len)
        elif paged:
            a, new_c = L.attention_decode_paged(lp["attn"], hn, lc,
                                                block_tables, pos, cfg,
                                                use_pallas, tree=tree,
                                                feed_len=feed_len)
        elif cfg.family == "mla_moe":
            a, new_c = MLA.mla_decode(lp["attn"], hn, lc, pos, cfg,
                                      use_pallas)
        else:
            a, new_c = L.attention_decode(lp["attn"], hn, lc, pos, cfg,
                                          use_pallas)
        hh = hh + a
        hn = L.rmsnorm(hh, lp["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            m, _ = MOE.moe_block(lp["moe"], hn, cfg, dist, use_pallas)
        else:
            m = L.mlp_block(lp["mlp"], hn, cfg.mlp_type, use_pallas)
        return hh + m, new_c

    h, new_cache = jax.lax.scan(body, h, (params["layers"], cache))
    logits = unembed(params, h, cfg)
    return logits, new_cache
