"""deepseek-moe-16b [arXiv:2401.06066]: fine-grained MoE, 2 shared + 64
routed top-6 experts."""
from repro.configs.base import ModelConfig, MoECfg


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b", family="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab=102400,
        moe=MoECfg(n_experts=64, top_k=6, n_shared=2, d_expert=1408),
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b-reduced", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=96, vocab=256,
        moe=MoECfg(n_experts=8, top_k=2, n_shared=2, d_expert=96),
        dtype="float32", attn_block_q=32, attn_block_k=32,
    )
