"""mamba2-130m [arXiv:2405.21060]: pure SSD stack, attention-free,
tied embeddings. Sub-quadratic => runs long_500k."""
from repro.configs.base import ModelConfig, SSMCfg


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m", family="ssm",
        n_layers=24, d_model=768, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab=50280,
        ssm=SSMCfg(d_state=128, head_dim=64, expand=2, conv_width=4,
                   chunk=256),
        tie_embeddings=True,
        supports_long_context=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m-reduced", family="ssm",
        n_layers=2, d_model=64, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab=256,
        ssm=SSMCfg(d_state=16, head_dim=16, expand=2, conv_width=4,
                   chunk=16),
        tie_embeddings=True,
        supports_long_context=True,
        dtype="float32",
    )
