"""llava-next-mistral-7b [hf:llava-hf/llava-v1.6-mistral-7b-hf]: Mistral-7B
backbone; anyres vision tiling is a stub (576 precomputed patch embeds)."""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b", family="vlm",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=32000,
        n_patches=576, rope_theta=1e6,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b-reduced", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256,
        n_patches=8, dtype="float32", attn_block_q=32, attn_block_k=32,
    )
