"""Architecture registry + input_specs for every (arch x shape) cell."""
from __future__ import annotations

import importlib
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCfg, SHAPES

ARCH_IDS = [
    "deepseek_moe_16b",
    "deepseek_v2_236b",
    "llava_next_mistral_7b",
    "seamless_m4t_large_v2",
    "yi_34b",
    "starcoder2_3b",
    "qwen3_14b",
    "mistral_nemo_12b",
    "zamba2_7b",
    "mamba2_130m",
    # the paper's own benchmark model (extra, not an assigned cell)
    "llama2_7b",
]

# assignment ids use dashes
_ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}


def _module(name: str):
    name = _ALIASES.get(name, name)
    return importlib.import_module(f"repro.configs.{name}")


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    mod = _module(name)
    return mod.reduced() if reduced else mod.full()


def list_archs(include_extra: bool = False) -> List[str]:
    return ARCH_IDS if include_extra else ARCH_IDS[:-1]


def list_draft_profiles() -> List[str]:
    """Draft compression profiles for speculative decoding (the serving
    CLIs' --draft-profile choices). Lazy import: configs stay importable
    without the compression stack."""
    from repro.core.model_compress import DRAFT_PROFILES
    return sorted(DRAFT_PROFILES)


def supported_shapes(cfg: ModelConfig) -> List[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long_context:
        out.append("long_500k")
    return out


def input_specs(cfg: ModelConfig, shape: ShapeCfg) -> Dict:
    """ShapeDtypeStruct stand-ins for a forward/train call (no allocation).

    For decode shapes these are the *per-step* token inputs; the cache specs
    come from jax.eval_shape(api.init_cache, ...) in the launcher.
    """
    sds = jax.ShapeDtypeStruct
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = cfg.compute_dtype

    if shape.kind == "decode":
        return {"tokens": sds((b, 1), i32)}

    batch: Dict = {}
    if cfg.family == "vlm":
        s_text = s - cfg.n_patches
        batch["tokens"] = sds((b, s_text), i32)
        batch["patch_embeds"] = sds((b, cfg.n_patches, cfg.d_model), dt)
        if shape.kind == "train":
            batch["labels"] = sds((b, s_text), i32)
        return batch
    if cfg.family == "encdec":
        batch["tokens"] = sds((b, s), i32)
        batch["frames"] = sds((b, cfg.n_frames, cfg.d_model), dt)
        if shape.kind == "train":
            batch["labels"] = sds((b, s), i32)
        return batch
    batch["tokens"] = sds((b, s), i32)
    if shape.kind == "train":
        batch["labels"] = sds((b, s), i32)
    return batch


def all_cells(include_extra: bool = False):
    """Every assigned (arch, shape) pair, with skips annotated."""
    cells = []
    for arch in list_archs(include_extra):
        cfg = get_config(arch)
        for sname, shp in SHAPES.items():
            runnable = sname in supported_shapes(cfg)
            cells.append((arch, sname, runnable))
    return cells
