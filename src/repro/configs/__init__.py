from repro.configs.base import ModelConfig, MoECfg, MLACfg, SSMCfg, SHAPES
from repro.configs.registry import (get_config, input_specs, list_archs,
                                    supported_shapes, all_cells, ARCH_IDS)
