"""llama-2-7b [arXiv:2307.09288]: the paper's own benchmark model (GQSA
Tables 1-4). Extra config, not one of the 10 assigned cells."""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="llama2-7b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
        d_ff=11008, vocab=32000,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama2-7b-reduced", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256,
        dtype="float32", attn_block_q=32, attn_block_k=32,
    )
