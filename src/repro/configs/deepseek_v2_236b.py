"""deepseek-v2-236b [arXiv:2405.04434]: MLA (kv_lora=512) + 2 shared +
160 routed top-6 experts. FSDP on, largest assigned model."""
from repro.configs.base import MLACfg, ModelConfig, MoECfg


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", family="mla_moe",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
        d_ff=1536, vocab=102400,
        moe=MoECfg(n_experts=160, top_k=6, n_shared=2, d_expert=1536),
        mla=MLACfg(kv_lora_rank=512, q_lora_rank=1536,
                   qk_nope_dim=128, qk_rope_dim=64, v_dim=128),
        fsdp=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b-reduced", family="mla_moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=96, vocab=256,
        moe=MoECfg(n_experts=8, top_k=2, n_shared=2, d_expert=96),
        mla=MLACfg(kv_lora_rank=32, q_lora_rank=48,
                   qk_nope_dim=16, qk_rope_dim=8, v_dim=16),
        dtype="float32", attn_block_q=32, attn_block_k=32,
    )
