"""Unified model / run configuration schema for the architecture zoo."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int            # routed experts
    top_k: int
    n_shared: int = 0         # always-on shared experts
    d_expert: int = 0         # per-expert FFN hidden size
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-2


@dataclasses.dataclass(frozen=True)
class MLACfg:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128          # SSD chunk length
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | mla_moe | vlm | encdec | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    ssm: Optional[SSMCfg] = None
    # architecture knobs
    mlp_type: str = "swiglu"          # swiglu | gelu
    qk_norm: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # hybrid (zamba2): shared attention block applied every k SSM layers
    shared_attn_every: int = 0
    # enc-dec (seamless): encoder depth (decoder = n_layers), stub frames
    enc_layers: int = 0
    n_frames: int = 960
    # vlm (llava): patch-embedding stub length
    n_patches: int = 0
    # numerics / execution
    kv_cache_dtype: str = "bf16"      # "int8": quantized decode KV cache
    analysis_unroll: bool = False     # unroll inner scans (cost analysis)
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    attn_block_q: int = 512
    attn_block_k: int = 512
    # distribution
    fsdp: bool = False                # shard params over the data axis too
    grad_compress: bool = False       # int8 error-feedback DP all-reduce
    # which shapes are supported (long_500k only for sub-quadratic mixers)
    supports_long_context: bool = False

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.n_heads

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def params_dtype(self):
        return jnp.dtype(self.param_dtype)

    def n_params(self) -> int:
        """Approximate parameter count (embedding + blocks), for roofline
        MODEL_FLOPS."""
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "hybrid"):
            hd = self.hd
            qkv = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads
            o = hd * self.n_heads * d
            attn = qkv + o
        if self.family in ("dense", "vlm"):
            mlp = (3 if self.mlp_type == "swiglu" else 2) * d * self.d_ff
            per_layer = attn + mlp
        elif self.family == "moe":
            moe = self.moe
            expert = 3 * d * moe.d_expert
            per_layer = attn + (moe.n_experts + moe.n_shared) * expert \
                + d * moe.n_experts
        elif self.family == "mla_moe":
            m, moe = self.mla, self.moe
            h = self.n_heads
            attn = (d * m.q_lora_rank
                    + m.q_lora_rank * h * (m.qk_nope_dim + m.qk_rope_dim)
                    + d * (m.kv_lora_rank + m.qk_rope_dim)
                    + m.kv_lora_rank * h * (m.qk_nope_dim + m.v_dim)
                    + h * m.v_dim * d)
            expert = 3 * d * moe.d_expert
            per_layer = attn + (moe.n_experts + moe.n_shared) * expert \
                + d * moe.n_experts
        elif self.family == "ssm":
            s = self.ssm
            d_in = s.expand * d
            nh = d_in // s.head_dim
            per_layer = (d * (2 * d_in + 2 * s.n_groups * s.d_state + nh)
                         + d_in * d)
        elif self.family == "hybrid":
            s = self.ssm
            d_in = s.expand * d
            nh = d_in // s.head_dim
            ssm_l = (d * (2 * d_in + 2 * s.n_groups * s.d_state + nh)
                     + d_in * d)
            shared = attn + 3 * d * self.d_ff
            return emb + self.n_layers * ssm_l + shared
        elif self.family == "encdec":
            hd = self.hd
            attn = 2 * d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads
            mlp = 2 * d * self.d_ff
            dec_layer = 2 * attn + mlp      # self + cross attention
            enc_layer = attn + mlp
            return emb + self.enc_layers * enc_layer + self.n_layers * dec_layer
        return emb + self.n_layers * per_layer

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.n_params()
        full = self.n_params()
        expert = 3 * self.d_model * self.moe.d_expert
        inactive = (self.moe.n_experts - self.moe.top_k) * expert
        return full - self.n_layers * inactive


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    """One assigned input-shape cell."""
    name: str                  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}
