"""qwen3-14b [hf:Qwen/Qwen3]: GQA + qk_norm."""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b", family="dense",
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=17408, vocab=151936,
        head_dim=128, qk_norm=True, rope_theta=1e6,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b-reduced", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256,
        head_dim=16, qk_norm=True, dtype="float32",
        attn_block_q=32, attn_block_k=32,
    )
