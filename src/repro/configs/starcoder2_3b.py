"""starcoder2-3b [arXiv:2402.19173]: GQA (kv=2), RoPE, non-gated GELU MLP."""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b", family="dense",
        n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
        d_ff=12288, vocab=49152,
        mlp_type="gelu", rope_theta=1e5,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b-reduced", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256,
        mlp_type="gelu", dtype="float32",
        attn_block_q=32, attn_block_k=32,
    )
