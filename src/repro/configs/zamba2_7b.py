"""zamba2-7b [arXiv:2411.15242]: Mamba2 backbone + shared attention block
every 6 SSM layers. Sub-quadratic mixer => runs long_500k."""
from repro.configs.base import ModelConfig, SSMCfg


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
        d_ff=14336, vocab=32000,
        ssm=SSMCfg(d_state=64, head_dim=64, expand=2, conv_width=4,
                   chunk=128),
        shared_attn_every=6,
        supports_long_context=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b-reduced", family="hybrid",
        n_layers=7, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256,
        ssm=SSMCfg(d_state=16, head_dim=16, expand=2, conv_width=4,
                   chunk=16),
        shared_attn_every=3,
        supports_long_context=True,
        dtype="float32", attn_block_q=32, attn_block_k=32,
    )
