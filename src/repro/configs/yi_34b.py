"""yi-34b [arXiv:2403.04652]: llama-arch GQA dense. FSDP on."""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="yi-34b", family="dense",
        n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
        d_ff=20480, vocab=64000,
        rope_theta=5e6, fsdp=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="yi-34b-reduced", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256,
        dtype="float32", attn_block_q=32, attn_block_k=32,
    )
