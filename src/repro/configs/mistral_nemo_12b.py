"""mistral-nemo-12b [hf:mistralai/Mistral-Nemo-Base-2407]: 128k-context GQA
dense (head_dim 128 != d_model/n_heads)."""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b", family="dense",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=131072,
        head_dim=128, rope_theta=1e6,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b-reduced", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256,
        head_dim=16, dtype="float32", attn_block_q=32, attn_block_k=32,
    )
