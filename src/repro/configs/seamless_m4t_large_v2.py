"""seamless-m4t-large-v2 [arXiv:2308.11596]: enc-dec backbone; the speech
frontend is a stub (1024 precomputed frame embeddings)."""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2", family="encdec",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=8192, vocab=256206,
        enc_layers=24, n_frames=1024, mlp_type="gelu",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2-reduced", family="encdec",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256,
        enc_layers=2, n_frames=16, mlp_type="gelu",
        dtype="float32", attn_block_q=32, attn_block_k=32,
    )
