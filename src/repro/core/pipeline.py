"""The full GQSA compression pipeline (paper Figure 2):

    FP model --calibrate--> masks --BQPO--> fake-quant weights
             --freeze INT--> frozen codes --E2E-OQP--> tuned (s, z)
             --pack--> BSR serving params

One call: ``gqsa_compress(params, batches, cfg, gqsa)``. Dense family gets
exact per-linear Hessian calibration; packing preserves the E2E-tuned
scale/zero bit-exactly (verified by tests/test_gqsa_pipeline.py).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bqpo import BQPOConfig, bqpo
from repro.core.bsr import pack_quantized
from repro.core.e2e_oqp import E2EConfig, e2e_oqp, freeze_int
from repro.core.gqs_layer import GQSAConfig


def pack_frozen(params_frozen: Dict) -> Dict:
    """frozen-int tree -> packed-BSR serving tree."""
    def walk(node):
        if isinstance(node, dict) and "q" in node and "gmask" in node:
            q = np.asarray(node["q"])
            gm = np.asarray(node["gmask"])
            sc = np.asarray(node["scale"])
            zr = np.asarray(node["zero"])
            lead = q.shape[:-2]
            n, k = q.shape[-2:]
            g = k // sc.shape[-1]
            qf = q.reshape((-1, n, k))
            gmf = gm.reshape((-1,) + gm.shape[-2:])
            scf = sc.reshape((-1,) + sc.shape[-2:])
            zrf = zr.reshape((-1,) + zr.shape[-2:])
            packed = [pack_quantized(jnp.asarray(qf[i]), gmf[i],
                                     jnp.asarray(scf[i]), jnp.asarray(zrf[i]),
                                     group_size=g)
                      for i in range(qf.shape[0])]
            if not lead:
                return {"bsr": packed[0]}
            stack = lambda *xs: jnp.stack(xs).reshape(lead + xs[0].shape)
            return {"bsr": jax.tree_util.tree_map(stack, *packed)}
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        return node
    return walk(params_frozen)


def gqsa_compress(params: Dict, token_batches: List[Dict], cfg,
                  gqsa: Optional[GQSAConfig] = None,
                  bqpo_cfg: Optional[BQPOConfig] = None,
                  e2e_cfg: Optional[E2EConfig] = None,
                  verbose: bool = False) -> Tuple[Dict, Dict]:
    """Returns (packed serving params, report)."""
    gqsa = gqsa or GQSAConfig()
    report = {}

    # stage 1: block-wise (calibration + masks happen inside, per block)
    toks = [b["tokens"] for b in token_batches]
    params_fq, block_losses = bqpo(params, toks, cfg, gqsa, bqpo_cfg,
                                   verbose=verbose)
    report["bqpo_block_mse"] = block_losses

    # freeze to INT codes
    params_frozen = freeze_int(params_fq, gqsa)

    # stage 2: end-to-end (s, z) fine-tune
    params_frozen, e2e_losses = e2e_oqp(params_frozen, token_batches, cfg,
                                        e2e_cfg, verbose=verbose)
    report["e2e_loss"] = e2e_losses

    packed = pack_frozen(params_frozen)
    return packed, report


def stage1_only(params: Dict, token_batches: List[Dict], cfg,
                gqsa: Optional[GQSAConfig] = None,
                bqpo_cfg: Optional[BQPOConfig] = None) -> Dict:
    """BQPO-only packed model (the paper's Table 6 ablation arm)."""
    gqsa = gqsa or GQSAConfig()
    toks = [b["tokens"] for b in token_batches]
    params_fq, _ = bqpo(params, toks, cfg, gqsa, bqpo_cfg)
    return pack_frozen(freeze_int(params_fq, gqsa))


def oneshot(params: Dict, token_batches: List[Dict], cfg,
            gqsa: Optional[GQSAConfig] = None) -> Dict:
    """No optimization at all: calibrate -> prune -> quantize -> pack
    (the 'naive GQSA' baseline)."""
    from repro.core.bqpo import (block_to_fake_quant, calibrate_block_stats,
                                 capture_block_io)
    gqsa = gqsa or GQSAConfig()
    ins = [capture_block_io(params, b["tokens"], cfg)[0]
           for b in token_batches]
    new_layers = []
    for l in range(cfg.n_layers):
        lp = jax.tree_util.tree_map(lambda a: a[l], params["layers"])
        stats = calibrate_block_stats(lp, [hi[l] for hi in ins], cfg)
        new_layers.append(block_to_fake_quant(lp, stats, gqsa))
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *new_layers)
    out = dict(params)
    out["layers"] = stacked
    return pack_frozen(freeze_int(out, gqsa))
