"""Hessian-based weight saliency (paper §3.1, eq. 4) and calibration stats.

For a linear layer y = x @ W.T with W[out, in], the layer Hessian wrt W rows
is H = 2 * E[x x^T]  (same for every output row). The paper scores
``s_i = w_i^2 / [H^-1]_ii^2`` and averages within each 1xG group.

Two estimators:
  * diagonal (default, CPU-friendly): [H^-1]_ii ~= 1 / H_ii  =>
    s_i = w_i^2 * H_ii^2  (monotone-equivalent to Wanda's |w|*||x||).
  * exact: damped Cholesky inverse of the full KxK Hessian (GPTQ-style);
    feasible for the small-K models we calibrate on CPU.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class HessianStats:
    """Accumulated calibration statistics for one linear layer."""
    xtx: jnp.ndarray      # [K, K] sum of x x^T  (or None-like zeros if diag_only)
    diag: jnp.ndarray     # [K]   sum of x_i^2
    count: int            # number of rows (tokens) accumulated
    diag_only: bool = False

    @staticmethod
    def init(k: int, diag_only: bool = False) -> "HessianStats":
        xtx = jnp.zeros((1, 1), jnp.float32) if diag_only else jnp.zeros(
            (k, k), jnp.float32)
        return HessianStats(xtx=xtx, diag=jnp.zeros((k,), jnp.float32),
                            count=0, diag_only=diag_only)

    def update(self, x: jnp.ndarray) -> "HessianStats":
        """x: [..., K] activations entering the layer."""
        xf = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        diag = self.diag + jnp.sum(xf * xf, axis=0)
        xtx = self.xtx if self.diag_only else self.xtx + xf.T @ xf
        return HessianStats(xtx=xtx, diag=diag,
                            count=self.count + xf.shape[0],
                            diag_only=self.diag_only)


def hessian_diag(stats: HessianStats, damp: float = 1e-2) -> jnp.ndarray:
    """H_ii = 2/n * sum x_i^2, damped by mean."""
    h = 2.0 * stats.diag / max(stats.count, 1)
    return h + damp * jnp.mean(h)


def inv_hessian_diag(stats: HessianStats, damp: float = 1e-2) -> jnp.ndarray:
    """[H^-1]_ii. Exact (Cholesky) when full XtX available, else 1/H_ii."""
    if stats.diag_only:
        return 1.0 / hessian_diag(stats, damp)
    h = 2.0 * stats.xtx / max(stats.count, 1)
    h = h + damp * jnp.mean(jnp.diag(h)) * jnp.eye(h.shape[0], dtype=h.dtype)
    hinv = jnp.linalg.inv(h)
    return jnp.diag(hinv)


def weight_saliency(w: jnp.ndarray, stats: HessianStats,
                    damp: float = 1e-2, exact: bool = False) -> jnp.ndarray:
    """Per-element saliency s_i = w_i^2 / [H^-1]_ii^2  (eq. 4). Shape of w.

    w: [out, in]. The Hessian factor is shared across output rows.
    """
    if exact and not stats.diag_only:
        hinv_ii = inv_hessian_diag(stats, damp)          # [K]
        denom = jnp.maximum(hinv_ii * hinv_ii, 1e-20)
        return (w.astype(jnp.float32) ** 2) / denom[None, :]
    h_ii = hessian_diag(stats, damp)                     # [K]
    return (w.astype(jnp.float32) ** 2) * (h_ii * h_ii)[None, :]


def group_saliency(elem_saliency: jnp.ndarray, group_size: int) -> jnp.ndarray:
    """Average per-element saliency within each 1xG group.

    [out, in] -> [out, in/G].
    """
    n, k = elem_saliency.shape
    if k % group_size != 0:
        raise ValueError(f"in dim {k} not divisible by group {group_size}")
    return elem_saliency.reshape(n, k // group_size, group_size).mean(axis=-1)


def collect_layer_stats(
    apply_fn, params, batches, layer_taps: Dict[str, callable],
    diag_only: bool = True,
) -> Dict[str, HessianStats]:
    """Run calibration batches through ``apply_fn`` capturing inputs of the
    tapped layers.

    ``layer_taps`` maps layer-name -> fn(params, batch) -> activations [.., K]
    (each tap recomputes the prefix of the network up to that layer's input;
    fine for the small calibration models this runs on).
    """
    stats: Dict[str, HessianStats] = {}
    for name, tap in layer_taps.items():
        k = None
        for b in batches:
            x = tap(params, b)
            if k is None:
                k = x.shape[-1]
                stats[name] = HessianStats.init(k, diag_only=diag_only)
            stats[name] = stats[name].update(x)
    return stats


def saliency_by_mode(w: jnp.ndarray, stats: Optional["HessianStats"],
                     mode: str = "hessian", damp: float = 1e-2,
                     exact: bool = False) -> jnp.ndarray:
    """Dispatch: hessian (paper eq. 4) | wanda | magnitude."""
    if mode == "magnitude" or stats is None:
        return jnp.square(w.astype(jnp.float32))
    if mode == "wanda":
        h_ii = hessian_diag(stats, damp)
        return jnp.abs(w.astype(jnp.float32)) * jnp.sqrt(h_ii)[None, :]
    return weight_saliency(w, stats, damp=damp, exact=exact)
