"""BQPO — Block Quantization-Pruning Optimization (paper §3.3, stage 1).

Per transformer block: freeze the pruning mask, run the block under
fake-quant (STE), and optimize the *surviving weights* to match the FP
block's outputs on calibration activations. One block in memory at a time —
the paper's training-cost argument (Appendix A) carries over directly.

Exact per-linear calibration (Hessian-diag from the true layer inputs) is
implemented for the dense family (the paper's LLaMA models); other families
fall back to magnitude saliency (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gqs_layer import GQSAConfig, apply_linear
from repro.core.pruning import group_mask
from repro.core.quant import group_minmax_params
from repro.core.saliency import HessianStats, group_saliency, weight_saliency
from repro.models import layers as L
from repro.models import transformer as TF
from repro.optim import adamw


@dataclasses.dataclass
class BQPOConfig:
    steps: int = 50            # optimizer steps per block ("epochs" over the
    lr: float = 1e-5           # calibration set in the paper; steps here)
    b1: float = 0.9
    b2: float = 0.999


# ---------------------------------------------------------------------------
# calibration capture (dense family): exact inputs of every linear
# ---------------------------------------------------------------------------

def capture_block_io(params: Dict, tokens: jnp.ndarray, cfg):
    """Run the FP model, returning (h_in[l], h_out[l]) for every layer.
    h: [L, B, S, d]."""
    h = TF.embed_tokens(params, tokens, cfg)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(hh, lp):
        out, _ = TF._block(lp, hh, positions, cfg, None, False)
        return out, hh                     # ys = layer input

    h_last, h_ins = jax.lax.scan(body, h, params["layers"])
    h_outs = jnp.concatenate([h_ins[1:], h_last[None]], axis=0)
    return h_ins, h_outs


def linear_input_taps(lp: Dict, h: jnp.ndarray, positions, cfg) -> Dict:
    """Exact inputs of each linear in a dense block (for Hessian stats)."""
    taps = {}
    hn = L.rmsnorm(h, lp["ln1"], cfg.norm_eps)
    taps["wq"] = hn
    taps["wk"] = hn
    taps["wv"] = hn
    b, s, _ = h.shape
    q, k, v = L.attn_qkv(lp["attn"], hn, positions, cfg, False)
    o = L.flash_attention(q, k, v, causal=True,
                          block_q=cfg.attn_block_q,
                          block_k=cfg.attn_block_k)
    taps["wo"] = o.reshape(b, s, -1)
    a = apply_linear(lp["attn"]["wo"], taps["wo"])
    h2 = h + a
    hn2 = L.rmsnorm(h2, lp["ln2"], cfg.norm_eps)
    taps["wg"] = hn2
    taps["wu"] = hn2
    if cfg.mlp_type == "swiglu":
        g = apply_linear(lp["mlp"]["wg"], hn2)
        u = apply_linear(lp["mlp"]["wu"], hn2)
        taps["wd"] = jax.nn.silu(g) * u
    else:
        u = apply_linear(lp["mlp"]["wu"], hn2)
        taps["wd"] = jax.nn.gelu(u)
    return taps


def calibrate_block_stats(lp: Dict, h_batches: List[jnp.ndarray], cfg
                          ) -> Dict[str, HessianStats]:
    stats: Dict[str, HessianStats] = {}
    for h in h_batches:
        b, s, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        taps = linear_input_taps(lp, h, positions, cfg)
        for name, x in taps.items():
            if name not in stats:
                stats[name] = HessianStats.init(x.shape[-1], diag_only=True)
            stats[name] = stats[name].update(x)
    return stats


# ---------------------------------------------------------------------------
# masks + fake-quant conversion for one block
# ---------------------------------------------------------------------------

_LINEAR_OF = {"wq": ("attn", "wq"), "wk": ("attn", "wk"),
              "wv": ("attn", "wv"), "wo": ("attn", "wo"),
              "wg": ("mlp", "wg"), "wu": ("mlp", "wu"),
              "wd": ("mlp", "wd")}


def block_to_fake_quant(lp: Dict, stats: Optional[Dict[str, HessianStats]],
                        gqsa: GQSAConfig, with_qparams: bool = False) -> Dict:
    """Dense block params -> fake-quant block params (masks from saliency)."""
    out = jax.tree_util.tree_map(lambda x: x, lp)  # shallow-ish copy
    for name, path in _LINEAR_OF.items():
        if cfgless_missing(lp, path):
            continue
        node = lp[path[0]][path[1]]
        w = node["w"]
        from repro.core.saliency import saliency_by_mode
        sal = saliency_by_mode(w, (stats or {}).get(name),
                               mode=gqsa.saliency, exact=gqsa.exact_hessian)
        gsal = group_saliency(sal, gqsa.prune.group_size)
        gm = group_mask(gsal, gqsa.prune)
        new = {"w": w, "gmask": gm}
        if with_qparams:
            s, z = group_minmax_params(w, gqsa.quant)
            new["scale"], new["zero"] = s, z
        out[path[0]] = dict(out[path[0]])
        out[path[0]][path[1]] = new
    return out


def cfgless_missing(lp, path):
    node = lp
    for k in path:
        if not isinstance(node, dict) or k not in node:
            return True
        node = node[k]
    return False


# ---------------------------------------------------------------------------
# the block-wise optimization loop
# ---------------------------------------------------------------------------

def bqpo_block(lp_fq: Dict, h_ins: List[jnp.ndarray],
               h_outs: List[jnp.ndarray], cfg, gqsa: GQSAConfig,
               bcfg: BQPOConfig) -> Dict:
    """Optimize one fake-quant block to match FP outputs. Returns params."""
    from repro.core.partition import merge, partition
    opt_cfg = adamw.AdamWConfig(lr=bcfg.lr, b1=bcfg.b1, b2=bcfg.b2,
                                weight_decay=0.0, grad_clip=1e9)
    train, frozen = partition(lp_fq, r"\.w$|^w$")
    state = adamw.init_state(train)

    def loss_fn(tr, h, target):
        lp = merge(tr, frozen)
        b, s, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        out, _ = TF._block(lp, h, positions, cfg, None, False)
        return jnp.mean(jnp.square(out.astype(jnp.float32)
                                   - target.astype(jnp.float32)))

    @jax.jit
    def step(tr, st, h, target):
        loss, grads = jax.value_and_grad(loss_fn)(tr, h, target)
        tr, st, _ = adamw.apply_updates(tr, grads, st, opt_cfg)
        return tr, st, loss

    n = len(h_ins)
    last = None
    for i in range(bcfg.steps):
        h = h_ins[i % n]
        t = h_outs[i % n]
        train, state, last = step(train, state, h, t)
    return merge(train, frozen), float(last)


def bqpo(params: Dict, token_batches: List[jnp.ndarray], cfg,
         gqsa: GQSAConfig, bcfg: Optional[BQPOConfig] = None,
         verbose: bool = False):
    """Stage 1 over the whole (dense-family) model.

    Returns params with every block converted to fake-quant and optimized.
    Embeddings / lm_head stay FP (deployment convention, DESIGN.md §6).
    """
    bcfg = bcfg or BQPOConfig()
    n_layers = cfg.n_layers
    # FP targets for every layer; inputs are then propagated through the
    # already-compressed prefix (cascade calibration) so each block learns
    # to undo the accumulated quantization error of its predecessors —
    # without this, per-block MSE optimization compounds across depth.
    outs = [capture_block_io(params, toks, cfg)[1] for toks in token_batches]
    h_cur = []
    for toks in token_batches:
        h = TF.embed_tokens(params, toks, cfg)
        h_cur.append(h)

    @jax.jit
    def fq_forward(lp, h):
        b, s, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        out, _ = TF._block(lp, h, positions, cfg, None, False)
        return out

    new_layers = []
    losses = []
    for l in range(n_layers):
        lp = jax.tree_util.tree_map(lambda a: a[l], params["layers"])
        t_l = [ho[l] for ho in outs]
        stats = calibrate_block_stats(lp, h_cur, cfg)
        lp_fq = block_to_fake_quant(lp, stats, gqsa)
        lp_fq, loss = bqpo_block(lp_fq, h_cur, t_l, cfg, gqsa, bcfg)
        losses.append(loss)
        if verbose:
            print(f"[bqpo] block {l}: mse={loss:.3e}")
        new_layers.append(lp_fq)
        h_cur = [fq_forward(lp_fq, h) for h in h_cur]

    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *new_layers)
    out = dict(params)
    out["layers"] = stacked
    return out, losses
