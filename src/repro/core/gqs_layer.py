"""GQS layer (paper §3.2): the drop-in replacement for Linear.

A linear layer's parameters take one of four *representations*; the model
code calls :func:`apply_linear` and dispatches on which leaves are present,
so the same model definition runs FP training, fake-quant optimization
(BQPO / E2E-OQP), and packed-BSR serving.

    fp          {"w": [N,K] (, "b")}
    fake_quant  {"w", "gmask" [N,K/G] bool (, "scale","zero" [N,K/G])}
    w4          {"qw" packed u8 [N,K/2], "scale","zero" [N,K/G]}   dense quant
    gqsa        {"bsr": BSRMatrix}                                  quant+sparse
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import pruning
from repro.core.bsr import BSRMatrix, pack_dense
from repro.core.quant import (QuantConfig, fake_quant, group_minmax_params,
                              pack_int4, quantize)
from repro.core.pruning import PruneConfig, expand_mask, group_mask
from repro.core.saliency import (HessianStats, group_saliency,
                                 weight_saliency)
from repro.kernels import ops as kops
from repro.kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class GQSAConfig:
    """End-to-end compression configuration (paper W4 S{20..50} G16).

    ``saliency``: "hessian" (paper eq. 4, diag approx), "wanda"
    (|w|*sqrt(E x^2)) or "magnitude" (w^2). On our from-scratch benchmark
    models the shared per-input-dim Hessian factor correlates row masks
    (prunes whole input dims) and magnitude wins one-shot; with the full
    two-stage pipeline all three converge (see benchmarks/fig_saliency).
    """
    quant: QuantConfig = QuantConfig(bits=4, group_size=16)
    prune: PruneConfig = PruneConfig(sparsity=0.5, group_size=16,
                                     row_balanced=True)
    exact_hessian: bool = False
    saliency: str = "hessian"

    def __post_init__(self):
        if self.quant.group_size != self.prune.group_size:
            raise ValueError("quant and prune group sizes must match: the "
                             "group is both the quant and the prune unit")


def apply_linear(p: Dict, x: jnp.ndarray, *, qcfg: Optional[QuantConfig] = None,
                 use_pallas: bool = False) -> jnp.ndarray:
    """x: [..., K] -> [..., N]; dispatch on the parameter representation."""
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    if isinstance(p, dict) and "bsr" in p:
        bsr = p["bsr"]
        y = kops.gqsa_gemv(x2, bsr, use_pallas=use_pallas)
        y = y.astype(x.dtype)
    elif isinstance(p, dict) and "qw" in p:
        g = k // p["scale"].shape[-1]
        y = kops.w4_matmul(x2, p["qw"], p["scale"], p["zero"],
                           group_size=g,
                           use_pallas=use_pallas).astype(x.dtype)
    elif isinstance(p, dict) and "q" in p:
        # E2E-OQP: frozen INT codes, trainable (scale, zero) — dequant is
        # linear in (s, z) so gradients flow to them with no STE
        from repro.core.quant import dequantize
        k2 = p["q"].shape[-1]
        g = k2 // p["scale"].shape[-1]
        w = dequantize(jax.lax.stop_gradient(p["q"]), p["scale"], p["zero"],
                       QuantConfig(group_size=g))
        mask = expand_mask(jax.lax.stop_gradient(p["gmask"]),
                           g).astype(w.dtype)
        y = x2 @ (w * mask).astype(x.dtype).T
    elif isinstance(p, dict) and "gmask" in p:
        if qcfg is None:
            # group structure is encoded in the mask; bits default to the
            # paper's W4
            g = p["w"].shape[-1] // p["gmask"].shape[-1]
            qcfg = QuantConfig(bits=4, group_size=g)
        w = fake_quant(p["w"], qcfg, p.get("scale"), p.get("zero"))
        mask = expand_mask(jax.lax.stop_gradient(p["gmask"]),
                           qcfg.group_size).astype(w.dtype)
        y = x2 @ (w * mask).astype(x.dtype).T
    else:
        # params may be stored f32; compute in the activation dtype
        y = x2 @ p["w"].astype(x.dtype).T
    if isinstance(p, dict) and "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y.reshape(*lead, -1)


# ---------------------------------------------------------------------------
# Representation conversions (the offline compression steps).
# ---------------------------------------------------------------------------

def make_fake_quant(w: jnp.ndarray, stats: HessianStats,
                    cfg: GQSAConfig, with_qparams: bool = False) -> Dict:
    """FP weight + calibration stats -> fake-quant params (stage-1 input)."""
    sal = weight_saliency(w, stats, exact=cfg.exact_hessian)
    gsal = group_saliency(sal, cfg.prune.group_size)
    gmask = group_mask(gsal, cfg.prune)
    p = {"w": w, "gmask": gmask}
    if with_qparams:
        s, z = group_minmax_params(w, cfg.quant)
        p["scale"], p["zero"] = s, z
    return p


def pack_gqsa(p_fake: Dict, cfg: GQSAConfig) -> Dict:
    """fake-quant params -> packed BSR serving params."""
    return {"bsr": pack_dense(p_fake["w"], p_fake["gmask"], cfg.quant)}


def pack_w4(w: jnp.ndarray, qcfg: QuantConfig) -> Dict:
    """FP weight -> dense W<=4 serving params (quantization-only baseline).
    Nibble packing only holds codes < 16; wider bit-widths use the
    fake-quant (dense FP) representation instead."""
    if qcfg.bits > 4:
        raise ValueError("pack_w4 packs two codes per byte: bits must be "
                         "<= 4 (use fake_quant for W8)")
    s, z = group_minmax_params(w, qcfg)
    q = quantize(w, s, z, qcfg)
    return {"qw": pack_int4(q), "scale": s, "zero": z}


def compress_linear(w: jnp.ndarray, stats: HessianStats,
                    cfg: GQSAConfig) -> Dict:
    """One-shot (no BQPO) FP -> packed GQSA params."""
    return pack_gqsa(make_fake_quant(w, stats, cfg), cfg)


# ---------------------------------------------------------------------------
# Shape-only construction for the dry-run (no allocation, no numpy loops).
# ---------------------------------------------------------------------------

def packed_linear_shapes(n: int, k: int, cfg: GQSAConfig) -> Dict:
    """ShapeDtypeStructs of the packed representation for (n, k)."""
    g = cfg.prune.group_size
    m = pruning.groups_kept_per_row(k, cfg.prune)
    sds = jax.ShapeDtypeStruct
    bsr = BSRMatrix(
        idx=sds((n, m), jnp.int32),
        vals=sds((n, m, g // 2), jnp.uint8),
        scale=sds((n, m), jnp.float32),
        zero=sds((n, m), jnp.float32),
        shape=(n, k), group_size=g, bits=cfg.quant.bits)
    return {"bsr": bsr}


def dequant_dense(p: Dict, qcfg: Optional[QuantConfig] = None) -> jnp.ndarray:
    """Any representation -> dense FP weight (for tests / analysis)."""
    from repro.core.bsr import to_dense
    from repro.core.quant import dequantize, unpack_int4
    if "bsr" in p:
        return to_dense(p["bsr"])
    if "qw" in p:
        k2 = p["qw"].shape[1] * 2
        g = k2 // p["scale"].shape[-1]
        q = unpack_int4(p["qw"])
        return dequantize(q, p["scale"], p["zero"],
                          QuantConfig(group_size=g))
    if "gmask" in p:
        assert qcfg is not None
        w = fake_quant(p["w"], qcfg, p.get("scale"), p.get("zero"))
        return w * expand_mask(p["gmask"], qcfg.group_size).astype(w.dtype)
    return p["w"]
