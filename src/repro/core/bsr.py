"""Block-Sparse-Row storage for GQS layers (paper §3.2 + Figure 3).

Canonical paper form (exact, ragged):
    rowIndex[N+1]  -- prefix offsets; rowIndex[i+1]-rowIndex[i] = groups in row i
    groups[nnz]    -- column index (in group units) of each surviving group
    values[nnz, G] -- INT4 codes (packed two-per-byte -> [nnz, G/2] uint8)
    scale/zero[nnz]

TPU padded tensor form (what the models & kernels consume):
    idx   [N, M] int32   -- kept group columns, sorted; -1 padding on ragged rows
    vals  [N, M, G/2] u8 -- packed nibbles; padding rows are zero
    scale [N, M] f32     -- 0 on padding (=> dequant contributes nothing)
    zero  [N, M] f32
M = max groups per row (== exact count in row_balanced mode).

Compression accounting matches the paper: positions stored per *group*, not
per element, so metadata amortizes over G elements.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np
import jax.numpy as jnp

from repro.core.quant import (QuantConfig, group_minmax_params, quantize,
                              pack_int4, unpack_int4)


@dataclasses.dataclass
class BSRMatrix:
    """Padded tensor form. All leaves are jnp arrays (a pytree)."""
    idx: jnp.ndarray        # [N, M] int32 (-1 = padding)
    vals: jnp.ndarray       # [N, M, G/2] uint8
    scale: jnp.ndarray      # [N, M] float32
    zero: jnp.ndarray       # [N, M] float32
    shape: Tuple[int, int]  # dense (N, K)
    group_size: int
    bits: int = 4

    def tree_flatten(self):
        return ((self.idx, self.vals, self.scale, self.zero),
                (self.shape, self.group_size, self.bits))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        idx, vals, scale, zero = leaves
        shape, group_size, bits = aux
        return cls(idx=idx, vals=vals, scale=scale, zero=zero, shape=shape,
                   group_size=group_size, bits=bits)

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_cols(self) -> int:
        return self.shape[1]

    @property
    def m_groups(self) -> int:
        return self.idx.shape[1]

    def nbytes_packed(self) -> int:
        """Actual storage bytes of the compressed representation
        (idx int32 could be int16 on K<=1M; we count what we store)."""
        return int(self.idx.nbytes + self.vals.nbytes + self.scale.nbytes
                   + self.zero.nbytes)

    def dense_nbytes_fp16(self) -> int:
        return 2 * self.shape[0] * self.shape[1]

    def compression_ratio(self) -> float:
        return self.dense_nbytes_fp16() / self.nbytes_packed()


import jax.tree_util
jax.tree_util.register_pytree_node(
    BSRMatrix, BSRMatrix.tree_flatten, BSRMatrix.tree_unflatten)


def pack_dense(w: jnp.ndarray, gmask: jnp.ndarray,
               qcfg: QuantConfig) -> BSRMatrix:
    """Dense W [N, K] + group mask [N, K/G] -> padded BSR with per-group
    INT4 quantization of the surviving groups."""
    n, k = w.shape
    g = qcfg.group_size
    ngroups = k // g
    gm = np.asarray(gmask)
    counts = gm.sum(axis=1)
    m = int(counts.max()) if counts.size else 0
    m = max(m, 1)

    if counts.size and counts.max() and counts.min() == counts.max():
        # row-balanced fast path: nonzero() is row-major => already sorted
        idx = np.nonzero(gm)[1].reshape(n, m).astype(np.int32)
    else:
        idx = np.full((n, m), -1, dtype=np.int32)
        for i in range(n):
            cols = np.nonzero(gm[i])[0]
            idx[i, :cols.shape[0]] = np.sort(cols)
    idx_j = jnp.asarray(idx)

    # Gather surviving groups: [N, M, G] (padding rows gather group 0, then
    # get zeroed via scale=0).
    wg = w.reshape(n, ngroups, g)
    safe_idx = jnp.maximum(idx_j, 0)
    taken = jnp.take_along_axis(wg, safe_idx[..., None], axis=1)  # [N, M, G]

    scale, zero = group_minmax_params(taken.reshape(n, m * g),
                                      QuantConfig(bits=qcfg.bits, group_size=g))
    scale = scale.reshape(n, m)
    zero = zero.reshape(n, m)
    q = quantize(taken.reshape(n, m * g), scale.reshape(n, m),
                 zero.reshape(n, m),
                 QuantConfig(bits=qcfg.bits, group_size=g)).reshape(n, m, g)

    pad = (idx_j < 0)
    scale = jnp.where(pad, 0.0, scale)
    zero = jnp.where(pad, 0.0, zero)
    q = jnp.where(pad[..., None], 0, q)
    vals = pack_int4(q)
    return BSRMatrix(idx=idx_j, vals=vals, scale=scale.astype(jnp.float32),
                     zero=zero.astype(jnp.float32), shape=(n, k),
                     group_size=g, bits=qcfg.bits)


def pack_quantized(q_codes: jnp.ndarray, gmask: jnp.ndarray,
                   scale: jnp.ndarray, zero: jnp.ndarray,
                   group_size: int, bits: int = 4) -> BSRMatrix:
    """Pack *already-quantized* codes with their (trained) scale/zero —
    the E2E-OQP output path, preserving the fine-tuned quant params exactly.

    q_codes: [N, K] uint8; gmask/scale/zero: [N, K/G].
    """
    n, k = q_codes.shape
    g = group_size
    ngroups = k // g
    gm = np.asarray(gmask)
    counts = gm.sum(axis=1)
    m = max(int(counts.max()) if counts.size else 0, 1)
    if counts.size and counts.max() and counts.min() == counts.max():
        idx = np.nonzero(gm)[1].reshape(n, m).astype(np.int32)
    else:
        idx = np.full((n, m), -1, dtype=np.int32)
        for i in range(n):
            cols = np.nonzero(gm[i])[0]
            idx[i, :cols.shape[0]] = np.sort(cols)
    idx_j = jnp.asarray(idx)
    safe = jnp.maximum(idx_j, 0)
    qg = q_codes.reshape(n, ngroups, g)
    taken = jnp.take_along_axis(qg, safe[..., None], axis=1)   # [N, M, G]
    sc = jnp.take_along_axis(scale, safe, axis=1)
    zc = jnp.take_along_axis(zero, safe, axis=1)
    pad = idx_j < 0
    sc = jnp.where(pad, 0.0, sc)
    zc = jnp.where(pad, 0.0, zc)
    taken = jnp.where(pad[..., None], 0, taken)
    return BSRMatrix(idx=idx_j, vals=pack_int4(taken),
                     scale=sc.astype(jnp.float32),
                     zero=zc.astype(jnp.float32), shape=(n, k),
                     group_size=g, bits=bits)


def to_dense(bsr: BSRMatrix, dtype=jnp.float32) -> jnp.ndarray:
    """Decompress to dense [N, K] (pruned groups = 0)."""
    n, k = bsr.shape
    g = bsr.group_size
    ngroups = k // g
    q = unpack_int4(bsr.vals).astype(jnp.float32)          # [N, M, G]
    deq = (q - bsr.zero[..., None]) * bsr.scale[..., None]  # [N, M, G]
    out = jnp.zeros((n, ngroups, g), jnp.float32)
    safe_idx = jnp.maximum(bsr.idx, 0)
    # scatter-add; padding slots have scale 0 => contribute 0 to group 0
    out = out.at[jnp.arange(n)[:, None], safe_idx].add(deq)
    return out.reshape(n, k).astype(dtype)


def to_paper_bsr(bsr: BSRMatrix):
    """Padded form -> the paper's exact (rowIndex, groups, values) arrays
    (numpy; used for storage accounting and format tests)."""
    idx = np.asarray(bsr.idx)
    vals = np.asarray(bsr.vals)
    scale = np.asarray(bsr.scale)
    zero = np.asarray(bsr.zero)
    n, m = idx.shape
    keep = idx >= 0                                     # [N, M] bool
    # rowIndex = exclusive prefix sum of per-row kept-group counts
    row_index = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(keep.sum(axis=1), out=row_index[1:])
    # padded slots are right-aligned after the sorted kept columns, so a
    # row-major boolean gather preserves (row, sorted-col) order exactly
    groups = idx[keep].astype(np.int32)
    values = vals[keep]
    if values.size == 0:
        values = np.zeros((0, bsr.group_size // 2), np.uint8)
    return (row_index, groups, values,
            scale[keep].astype(np.float32), zero[keep].astype(np.float32))


def paper_bsr_nbytes(row_index, groups, values, scales, zeros,
                     bits: int = 4) -> int:
    """Exact ragged-format byte count (int16 group cols suffice for K/G<2^15,
    fp16 scale + u8 zero as deployed)."""
    return int(row_index.shape[0] * 4 + groups.shape[0] * 2
               + values.size + scales.shape[0] * 2 + zeros.shape[0] * 1)


# ---------------------------------------------------------------------------
# Task-centric work list (paper §3.5, Stream-K adapted to the TPU grid).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class WorkList:
    """Flattened, equal-size work items for the sparse kernel.

    Each item = (row_block r, slot range [chunk*BM, chunk*BM+BM) of the padded
    group slots of that row block). Ragged rows make the number of *useful*
    chunks vary per row block; flattening them into one 1-D grid equalizes
    per-step latency (the TPU analogue of Stream-K's work-centric
    decomposition). ``first`` marks items that initialize their output tile.
    """
    row_block: jnp.ndarray   # [W] int32
    chunk: jnp.ndarray       # [W] int32
    first: jnp.ndarray       # [W] int32 (1 = first visit of this row block)
    n_items: int


def build_work_list(idx: jnp.ndarray, block_n: int, block_m: int) -> WorkList:
    """idx: [N, M] padded kept-group columns (-1 pad). Static (numpy) build --
    runs offline at pack time, like the paper's pre-processing."""
    idx_np = np.asarray(idx)
    n, m = idx_np.shape
    nrb = (n + block_n - 1) // block_n
    rows, chunks, firsts = [], [], []
    for r in range(nrb):
        blk = idx_np[r * block_n:(r + 1) * block_n]
        useful = int((blk >= 0).sum(axis=1).max()) if blk.size else 0
        nch = max(1, (useful + block_m - 1) // block_m)
        for c in range(nch):
            rows.append(r)
            chunks.append(c)
            firsts.append(1 if c == 0 else 0)
    return WorkList(row_block=jnp.asarray(rows, jnp.int32),
                    chunk=jnp.asarray(chunks, jnp.int32),
                    first=jnp.asarray(firsts, jnp.int32),
                    n_items=len(rows))
