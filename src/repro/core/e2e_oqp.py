"""E2E-OQP — End-to-End Optimized Quantization-Pruning (paper §3.4, stage 2).

The backbone INT codes are frozen (quantized once from the BQPO weights);
only the per-group quantization parameters (scale, zero) are trained against
the full-network LM objective. Dequant ``(q - z) * s`` is linear in (s, z),
so no STE is involved; pruned groups are excluded by the (frozen) mask —
exactly the paper's "no sparse masks needed at fine-tune time" property once
packed, which we verify by asserting packed == frozen-int forward.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.gqs_layer import GQSAConfig
from repro.core.partition import merge, partition
from repro.core.quant import group_minmax_params, quantize
from repro.models.registry import get_model, lm_loss
from repro.optim import adamw


@dataclasses.dataclass
class E2EConfig:
    steps: int = 100
    lr: float = 1e-5


def freeze_int(params_fq: Dict, gqsa: GQSAConfig) -> Dict:
    """fake-quant tree (w + gmask [+ scale/zero]) -> frozen-int tree
    (q codes + gmask + scale + zero), leaving non-GQS leaves untouched."""
    def walk(node):
        if isinstance(node, dict) and "gmask" in node and "w" in node:
            w = node["w"]
            lead = w.shape[:-2]
            n, k = w.shape[-2:]
            wf = w.reshape((-1, n, k))
            qs, ss, zs = [], [], []
            for i in range(wf.shape[0]):
                s, z = group_minmax_params(wf[i], gqsa.quant)
                qs.append(quantize(wf[i], s, z, gqsa.quant))
                ss.append(s)
                zs.append(z)
            q = jnp.stack(qs).reshape(lead + (n, k))
            s = jnp.stack(ss).reshape(lead + ss[0].shape)
            z = jnp.stack(zs).reshape(lead + zs[0].shape)
            return {"q": q, "gmask": node["gmask"],
                    "scale": s, "zero": z}
        if isinstance(node, dict):
            return {k2: walk(v) for k2, v in node.items()}
        return node
    return walk(params_fq)


def e2e_oqp(params_frozen: Dict, token_batches: List[Dict], cfg,
            ecfg: Optional[E2EConfig] = None, verbose: bool = False):
    """Train only scale/zero leaves of frozen-int GQS layers, end to end."""
    ecfg = ecfg or E2EConfig()
    api = get_model(cfg)
    # scale/zero that live next to a "q" sibling are the trainables
    train, frozen = partition(params_frozen, r"\.(scale|zero)$")
    opt_cfg = adamw.AdamWConfig(lr=ecfg.lr, weight_decay=0.0, grad_clip=1.0)
    state = adamw.init_state(train)

    def loss_fn(tr, batch):
        p = merge(tr, frozen)
        logits, aux = api.forward(p, batch, cfg)
        return lm_loss(logits, batch["labels"]) + 1e-2 * aux

    @jax.jit
    def step(tr, st, batch):
        loss, grads = jax.value_and_grad(loss_fn)(tr, batch)
        tr, st, _ = adamw.apply_updates(tr, grads, st, opt_cfg)
        return tr, st, loss

    n = len(token_batches)
    losses = []
    for i in range(ecfg.steps):
        batch = token_batches[i % n]
        train, state, loss = step(train, state, batch)
        losses.append(float(loss))
        if verbose and i % 10 == 0:
            print(f"[e2e-oqp] step {i}: loss={losses[-1]:.4f}")
    return merge(train, frozen), losses
