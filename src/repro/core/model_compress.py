"""Whole-model GQSA compression: walk a parameter tree and replace every
eligible linear's {"w"} with the packed-BSR serving representation.

Eligible = the decode-path GEMV weights (attention projections, MLP /
expert FFNs, SSM in/out projections, MLA low-rank projections). Excluded =
embeddings, lm_head (kept FP16 as deployed engines do), norms, MLA w_uk/w_uv
(einsum-form, DESIGN.md §6), conv/ssm scalars, routers.

Handles weight stacking: leaves may be [L, N, K] (scan layers) or
[L, E, N, K] (scan layers x experts) — each 2-D slice is packed and the BSR
leaves are re-stacked, so the scan-based model code slices them layer by
layer exactly like dense weights.
"""
from __future__ import annotations

import re
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bsr import BSRMatrix, pack_dense
from repro.core.gqs_layer import GQSAConfig, packed_linear_shapes, pack_w4
from repro.core.pruning import PruneConfig, group_mask
from repro.core.quant import QuantConfig, group_minmax_params, quantize, \
    pack_int4
from repro.core.saliency import HessianStats, group_saliency, weight_saliency

COMPRESSIBLE = re.compile(
    r"(wq|wk|wv|wo|wg|wu|wd|w_qa|w_qb|w_kva|in_proj|out_proj)$")
EXCLUDED = re.compile(r"(router|shared_?$)")  # routers stay FP


def _path_str(path) -> str:
    parts = []
    for e in path:
        parts.append(str(getattr(e, "key", getattr(e, "idx", e))))
    return ".".join(parts)


def _is_compressible(pstr: str) -> bool:
    return bool(COMPRESSIBLE.search(pstr)) and not EXCLUDED.search(pstr)


def _walk(node, path, fn):
    """Replace {"w": leaf} dicts at compressible paths via fn(pstr, leaf)."""
    if isinstance(node, dict):
        if set(node.keys()) >= {"w"} and len(node) <= 2 and \
                _is_compressible(path):
            return fn(path, node)
        return {k: _walk(v, f"{path}.{k}" if path else k, fn)
                for k, v in node.items()}
    return node


def _pack_stacked(w: np.ndarray, cfg: GQSAConfig,
                  sal_fn: Optional[Callable] = None) -> BSRMatrix:
    """w: [..., N, K] -> BSRMatrix with leading dims stacked on each leaf."""
    lead = w.shape[:-2]
    n, k = w.shape[-2:]
    flat = w.reshape(-1, n, k)
    packed = []
    for i in range(flat.shape[0]):
        wi = jnp.asarray(flat[i])
        sal = sal_fn(wi) if sal_fn is not None else _magnitude_sal(wi)
        gsal = group_saliency(sal, cfg.prune.group_size)
        gm = group_mask(gsal, cfg.prune)
        packed.append(pack_dense(wi, gm, cfg.quant))
    if not lead:
        return packed[0]
    stack = lambda *xs: jnp.stack(xs).reshape(lead + xs[0].shape)
    return jax.tree_util.tree_map(stack, *packed)


# HessianStats has no _replace_uniform; provide magnitude fallback directly
def _magnitude_sal(w: jnp.ndarray) -> jnp.ndarray:
    return jnp.square(w.astype(jnp.float32))


def compress_params(params, cfg, gqsa: GQSAConfig,
                    stats: Optional[Dict[str, HessianStats]] = None):
    """FP param tree -> serving tree with packed GQS layers.

    ``stats`` maps path-string -> HessianStats (exact calibration). Layers
    without stats fall back to magnitude saliency (documented approximation
    for stacked/looped layers; the BQPO/E2E stages recover the gap).
    """
    def fn(pstr, node):
        w = node["w"]
        st = (stats or {}).get(pstr)
        if st is not None:
            sal_fn = lambda wi: weight_saliency(wi, st)
        else:
            sal_fn = _magnitude_sal
        return {"bsr": _pack_stacked(np.asarray(w), gqsa, sal_fn)}

    return _walk(params, "", fn)


def compress_params_w4(params, cfg, qcfg: QuantConfig):
    """Quantization-only baseline (dense W4, no pruning)."""
    def fn(pstr, node):
        w = node["w"]
        lead = w.shape[:-2]
        n, k = w.shape[-2:]
        flat = jnp.reshape(w, (-1, n, k))
        packs = [pack_w4(flat[i], qcfg) for i in range(flat.shape[0])]
        if not lead:
            return packs[0]
        stack = lambda *xs: jnp.stack(xs).reshape(lead + xs[0].shape)
        return jax.tree_util.tree_map(stack, *packs)
    return _walk(params, "", fn)


# ---------------------------------------------------------------------------
# Draft profiles (speculative decoding, DESIGN.md §4): one FP checkpoint
# yields BOTH the deployed target compression and a more aggressive draft
# compression. Quality collapse at draft-level settings is fine — the
# engine's verify step makes the served distribution exactly the target's,
# so the draft profile only trades acceptance rate against draft cost.
# ---------------------------------------------------------------------------

DRAFT_PROFILES: Dict[str, Dict] = {
    # dense 4-bit (no pruning): near-target quality, highest acceptance
    "w4": dict(bits=4, sparsity=0.0),
    # the paper's deployed setting — as a draft it accepts ~everything
    "w4s50": dict(bits=4, sparsity=0.5),
    # aggressive: settings the paper shows degrade too much to SERVE,
    # which is exactly what a drafter is allowed to be
    "w4s75": dict(bits=4, sparsity=0.75),
    "w2s50": dict(bits=2, sparsity=0.5),
    "w2s75": dict(bits=2, sparsity=0.75),
    # depth-pruned (LayerSkip-style self-speculation): keep the first
    # 12.5% / 25% / 50% of layers — sparsity at LAYER granularity, the
    # knob that makes a draft step structurally cheaper in every cost
    # regime (the shallow exit shares the final norm + unembedding)
    "w4l12": dict(bits=4, sparsity=0.0, depth=0.125),
    "w4l25": dict(bits=4, sparsity=0.0, depth=0.25),
    "w4l50": dict(bits=4, sparsity=0.0, depth=0.5),
    "w4s50l50": dict(bits=4, sparsity=0.5, depth=0.5),
}


def draft_layers(cfg, profile: str) -> int:
    """Effective drafter depth for a profile (>= 1, full when no depth)."""
    try:
        spec = DRAFT_PROFILES[profile]
    except KeyError:
        raise ValueError(f"unknown draft profile {profile!r}; "
                         f"known: {sorted(DRAFT_PROFILES)}")
    depth = spec.get("depth", 1.0)
    return max(1, int(round(cfg.n_layers * depth)))


def compress_draft(params, cfg, profile: str = "w4s75",
                   group_size: int = 16,
                   stats: Optional[Dict[str, HessianStats]] = None):
    """FP param tree -> the draft-profile parameter set.

    ``params`` is the SAME checkpoint the target compression starts
    from. Depth profiles first truncate the stacked layer leaves to the
    profile's layer count (embed / final norm / lm_head stay shared);
    then sparsity 0 routes to the dense W4 packer, anything else to the
    full GQSA packer at the profile's (bits, sparsity). A depth-pruned
    draft must be RUN at ``draft_layers(cfg, profile)`` layers
    (the engine's ``EngineConfig.spec_draft_layers``).
    """
    dl = draft_layers(cfg, profile)          # validates the profile name
    spec = DRAFT_PROFILES[profile]
    if dl < cfg.n_layers:
        params = dict(params, layers=jax.tree_util.tree_map(
            lambda l: l[:dl], params["layers"]))
    if spec["sparsity"] <= 0.0:
        return compress_params_w4(params, cfg, QuantConfig(
            bits=spec["bits"], group_size=group_size))
    gqsa = GQSAConfig(
        quant=QuantConfig(bits=spec["bits"], group_size=group_size),
        prune=PruneConfig(sparsity=spec["sparsity"], group_size=group_size))
    return compress_params(params, cfg, gqsa, stats=stats)


def compress_params_shapes(params_template, cfg, gqsa: GQSAConfig):
    """ShapeDtypeStruct version for the dry-run (no data, no loops)."""
    def fn(pstr, node):
        w = node["w"]
        lead = w.shape[:-2]
        n, k = w.shape[-2:]
        base = packed_linear_shapes(n, k, gqsa)["bsr"]

        def lift(l):
            return jax.ShapeDtypeStruct(lead + l.shape, l.dtype)
        leaves, treedef = jax.tree_util.tree_flatten(base)
        return {"bsr": treedef.unflatten([lift(l) for l in leaves])}

    return _walk(params_template, "", fn)


def compression_report(fp_params, packed_params) -> dict:
    def nbytes(t):
        return sum(np.prod(l.shape) * jnp.dtype(l.dtype).itemsize
                   for l in jax.tree_util.tree_leaves(t))
    fp = float(nbytes(fp_params))
    pk = float(nbytes(packed_params))
    return {"fp32_bytes": fp, "fp16_bytes": fp / 2, "packed_bytes": pk,
            "ratio_vs_fp16": (fp / 2) / pk}
