"""Per-group asymmetric uniform quantization (paper §3.1, eqs. 1-3).

Weights W[out, in] are grouped along the *input* (last) dimension in
contiguous groups of ``group_size`` (the paper's "1xN" mode). Each group gets
its own (scale, zero). Quantized codes live in [0, 2^bits - 1].

Three faces of the same math:
  * ``quantize`` / ``dequantize``     -- integer codes (storage / serving)
  * ``fake_quant``                    -- STE quant-dequant (training / BQPO)
  * ``pack_int4`` / ``unpack_int4``   -- two codes per uint8 byte
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    bits: int = 4
    group_size: int = 16
    # Clip optimization range-shrink factor bounds used by BQPO/E2E-OQP.
    min_scale: float = 1e-8

    @property
    def levels(self) -> int:
        return (1 << self.bits) - 1


def _group(w: jnp.ndarray, group_size: int) -> jnp.ndarray:
    """[..., K] -> [..., K/G, G]."""
    if w.shape[-1] % group_size != 0:
        raise ValueError(
            f"last dim {w.shape[-1]} not divisible by group_size {group_size}")
    return w.reshape(*w.shape[:-1], w.shape[-1] // group_size, group_size)


def _ungroup(w: jnp.ndarray) -> jnp.ndarray:
    """[..., K/G, G] -> [..., K]."""
    return w.reshape(*w.shape[:-2], w.shape[-2] * w.shape[-1])


def group_minmax_params(
    w: jnp.ndarray, cfg: QuantConfig
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scale/zero from per-group min/max (eq. 1). Returns (scale, zero),
    each shaped [..., K/G]."""
    g = _group(w.astype(jnp.float32), cfg.group_size)
    wmax = jnp.max(g, axis=-1)
    wmin = jnp.min(g, axis=-1)
    scale = jnp.maximum((wmax - wmin) / cfg.levels, cfg.min_scale)
    zero = jnp.round(-wmin / scale)
    return scale, zero


def quantize(
    w: jnp.ndarray, scale: jnp.ndarray, zero: jnp.ndarray, cfg: QuantConfig
) -> jnp.ndarray:
    """eq. 2: codes in [0, 2^bits - 1], shaped like w, dtype uint8."""
    g = _group(w.astype(jnp.float32), cfg.group_size)
    q = jnp.clip(jnp.round(g / scale[..., None]) + zero[..., None],
                 0, cfg.levels)
    return _ungroup(q).astype(jnp.uint8)


def dequantize(
    q: jnp.ndarray, scale: jnp.ndarray, zero: jnp.ndarray, cfg: QuantConfig,
    dtype=jnp.float32,
) -> jnp.ndarray:
    """eq. 3: (q - z) * s."""
    g = _group(q.astype(jnp.float32), cfg.group_size)
    w = (g - zero[..., None]) * scale[..., None]
    return _ungroup(w).astype(dtype)


def fake_quant(
    w: jnp.ndarray,
    cfg: QuantConfig,
    scale: jnp.ndarray | None = None,
    zero: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Quant-dequant with a straight-through estimator.

    If scale/zero are given they are *trainable leaves* (E2E-OQP); gradients
    flow to them through the dequant expression while the rounding is STE'd.
    """
    if scale is None or zero is None:
        s, z = group_minmax_params(w, cfg)
        # min/max params depend on w only through (max, min); STE the whole
        # round-trip wrt w.
        s, z = jax.lax.stop_gradient(s), jax.lax.stop_gradient(z)
    else:
        s = jnp.maximum(scale, cfg.min_scale)
        z = zero
    g = _group(w.astype(jnp.float32), cfg.group_size)
    inv = 1.0 / s[..., None]
    q_soft = g * inv + z[..., None]
    q_hard = jnp.clip(jnp.round(q_soft), 0, cfg.levels)
    # STE: forward uses q_hard, backward sees q_soft (identity through round,
    # zero through the clip boundary).
    q = q_soft + jax.lax.stop_gradient(q_hard - q_soft)
    wq = (q - z[..., None]) * s[..., None]
    return _ungroup(wq).astype(w.dtype)


# ---------------------------------------------------------------------------
# int4 <-> uint8 nibble packing (little-endian within the byte: element 2i in
# the low nibble, 2i+1 in the high nibble).
# ---------------------------------------------------------------------------

def pack_int4(q: jnp.ndarray) -> jnp.ndarray:
    """uint8 codes in [0,15], last dim even -> packed uint8, last dim K/2."""
    if q.shape[-1] % 2 != 0:
        raise ValueError("last dim must be even to pack nibbles")
    lo = q[..., 0::2].astype(jnp.uint8)
    hi = q[..., 1::2].astype(jnp.uint8)
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4(p: jnp.ndarray) -> jnp.ndarray:
    """packed uint8 -> uint8 codes, last dim doubled."""
    lo = p & 0xF
    hi = (p >> 4) & 0xF
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*p.shape[:-1], p.shape[-1] * 2)


def quant_error_bound(scale: jnp.ndarray) -> jnp.ndarray:
    """Worst-case |w - deq(quant(w))| for in-range w: s/2."""
    return scale / 2.0


def int8_symmetric_quant(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor symmetric int8 (activations / gradient compression)."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_symmetric_dequant(q: jnp.ndarray, scale: jnp.ndarray,
                           dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)
