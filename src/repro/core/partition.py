"""Split a param tree into (trainable, frozen) halves by leaf path — used by
BQPO (train only surviving weights) and E2E-OQP (train only scale/zero)."""
from __future__ import annotations

import re
from typing import Callable, Tuple

import jax


def _path_str(path) -> str:
    return ".".join(str(getattr(e, "key", getattr(e, "idx", e)))
                    for e in path)


def partition(tree, pattern: str) -> Tuple:
    """Returns (trainable, frozen): same treedef, None at the other side."""
    pat = re.compile(pattern)

    def pick(path, leaf):
        return leaf if pat.search(_path_str(path)) else None

    def drop(path, leaf):
        return None if pat.search(_path_str(path)) else leaf

    train = jax.tree_util.tree_map_with_path(pick, tree)
    frozen = jax.tree_util.tree_map_with_path(drop, tree)
    return train, frozen


def merge(a, b):
    """Recombine two partition() halves (None marks the absent side).

    Manual recursion: None is an *empty pytree node* to jax, so the two
    halves have different treedefs and tree_map cannot zip them.
    """
    if a is None:
        return b
    if b is None:
        return a
    if isinstance(a, dict):
        return {k: merge(a[k], b[k]) for k in a}
    if isinstance(a, (list, tuple)):
        return type(a)(merge(x, y) for x, y in zip(a, b))
    return a
