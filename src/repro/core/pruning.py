"""Group pruning: which 1xG groups survive (paper §3.2).

Modes:
  * row_balanced (TPU default, beyond-paper): every output row keeps exactly
    its top-M groups by saliency. Rectangular storage, perfectly balanced
    compute -> no stragglers by construction.
  * global threshold (paper-faithful): keep the globally most salient groups
    at the target sparsity; rows end up ragged -> exercised by the
    task-centric kernel work list.
  * two_four: classic 2:4 semi-structured baseline (for comparisons).
  * magnitude: |w| instead of Hessian saliency (ablation baseline).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PruneConfig:
    sparsity: float = 0.5          # fraction of groups removed
    group_size: int = 16
    row_balanced: bool = True


def groups_kept_per_row(k: int, cfg: PruneConfig) -> int:
    """M = ceil(K/G * (1 - sparsity)), >= 1."""
    ngroups = k // cfg.group_size
    return max(1, int(round(ngroups * (1.0 - cfg.sparsity))))


def row_balanced_mask(gsal: jnp.ndarray, cfg: PruneConfig) -> jnp.ndarray:
    """Per-row top-M group mask. gsal: [N, K/G] -> bool [N, K/G]."""
    n, ngroups = gsal.shape
    m = groups_kept_per_row(ngroups * cfg.group_size, cfg)
    idx = jnp.argsort(gsal, axis=-1, descending=True)[:, :m]
    mask = jnp.zeros_like(gsal, dtype=bool)
    mask = mask.at[jnp.arange(n)[:, None], idx].set(True)
    return mask


def global_threshold_mask(gsal: jnp.ndarray, cfg: PruneConfig) -> jnp.ndarray:
    """Keep globally top (1-s) fraction of groups. Ragged rows."""
    flat = gsal.reshape(-1)
    keep = max(1, int(round(flat.shape[0] * (1.0 - cfg.sparsity))))
    thresh = jnp.sort(flat, descending=True)[keep - 1]
    return gsal >= thresh


def group_mask(gsal: jnp.ndarray, cfg: PruneConfig) -> jnp.ndarray:
    if cfg.row_balanced:
        return row_balanced_mask(gsal, cfg)
    return global_threshold_mask(gsal, cfg)


def expand_mask(gmask: jnp.ndarray, group_size: int) -> jnp.ndarray:
    """[N, K/G] bool -> [N, K] bool (broadcast within groups)."""
    return jnp.repeat(gmask, group_size, axis=-1)


def two_four_mask(sal: jnp.ndarray) -> jnp.ndarray:
    """2:4 semi-structured: keep top-2 of every 4 consecutive elements.

    sal: per-element saliency [N, K] (K % 4 == 0) -> bool [N, K].
    """
    n, k = sal.shape
    s4 = sal.reshape(n, k // 4, 4)
    idx = jnp.argsort(s4, axis=-1, descending=True)[..., :2]
    mask = jnp.zeros_like(s4, dtype=bool)
    mask = mask.at[jnp.arange(n)[:, None, None],
                   jnp.arange(k // 4)[None, :, None], idx].set(True)
    return mask.reshape(n, k)


def magnitude_saliency(w: jnp.ndarray) -> jnp.ndarray:
    return jnp.abs(w.astype(jnp.float32))


def mask_sparsity(mask: jnp.ndarray) -> float:
    return float(1.0 - jnp.mean(mask.astype(jnp.float32)))


def kept_indices_row_balanced(
    gsal: jnp.ndarray, cfg: PruneConfig
) -> Tuple[jnp.ndarray, int]:
    """Sorted kept-group column indices per row: [N, M] int32, plus M.

    Sorting the kept indices keeps the BSR column stream monotone per row,
    which the kernels rely on for coalesced activation tiles.
    """
    n, ngroups = gsal.shape
    m = groups_kept_per_row(ngroups * cfg.group_size, cfg)
    top = jnp.argsort(gsal, axis=-1, descending=True)[:, :m]
    return jnp.sort(top, axis=-1).astype(jnp.int32), m
