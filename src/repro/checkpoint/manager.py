"""Fault-tolerant checkpointing.

Design (scales to multi-host: every host writes only its own shards):
  * one ``.npz`` per leaf-group + a JSON manifest with the tree structure,
    logical shapes and step;
  * writes go to ``step_XXXX.tmp/`` then a single atomic ``os.rename`` —
    a crash mid-write can never corrupt the latest checkpoint;
  * optional async writer thread (the train loop donates a host copy and
    keeps stepping — checkpoint I/O overlaps compute);
  * ``restore(..., mesh=...)`` re-device_puts with *any* target sharding:
    elastic restarts onto a different mesh shape need no conversion step;
  * ``keep`` old checkpoints are retained for rollback after bad steps.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._q: "queue.Queue" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        if async_save:
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    # -- public ------------------------------------------------------------

    def save(self, step: int, tree: Any, blocking: Optional[bool] = None):
        """Snapshot ``tree`` (params / opt state / metadata pytree)."""
        if self._error:
            raise RuntimeError("previous async save failed") from self._error
        leaves, treedef = _flatten(tree)
        host_leaves = []
        for l in leaves:                 # device -> host copy
            a = np.asarray(l)
            if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
                a = np.asarray(l, dtype=np.float32)  # lossless widen
            host_leaves.append(a)
        treedef_repr = jax.tree_util.tree_structure(tree)
        blocking = (not self.async_save) if blocking is None else blocking
        if blocking:
            self._write(step, host_leaves, str(treedef_repr))
        else:
            self._q.put((step, host_leaves, str(treedef_repr)))

    def wait(self):
        if self.async_save:
            self._q.join()
        if self._error:
            raise RuntimeError("async save failed") from self._error

    def latest_step(self) -> Optional[int]:
        steps = sorted(int(p.name.split("_")[1]) for p in self.dir.iterdir()
                       if p.is_dir() and p.name.startswith("step_")
                       and not p.name.endswith(".tmp"))
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Rebuild ``template``-structured tree. ``shardings`` (optional tree
        of NamedShardings) lets a checkpoint land on a *different* mesh than
        it was saved from (elastic restart)."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        leaves, treedef = _flatten(template)
        assert manifest["n_leaves"] == len(leaves), \
            f"leaf count mismatch: ckpt {manifest['n_leaves']} vs {len(leaves)}"
        data = np.load(d / "leaves.npz")
        out = []
        shard_leaves = (treedef.flatten_up_to(shardings)
                        if shardings is not None else [None] * len(leaves))
        for i, (tmpl, shd) in enumerate(zip(leaves, shard_leaves)):
            arr = data[f"leaf_{i}"]
            if tuple(arr.shape) != tuple(tmpl.shape):
                raise ValueError(f"shape mismatch for leaf {i}: "
                                 f"{arr.shape} vs {tmpl.shape}")
            if shd is not None:
                out.append(jax.device_put(arr.astype(tmpl.dtype), shd))
            else:
                out.append(jax.numpy.asarray(arr.astype(tmpl.dtype)))
        return treedef.unflatten(out)

    # -- internals ----------------------------------------------------------

    def _drain(self):
        while True:
            item = self._q.get()
            try:
                self._write(*item)
            except BaseException as e:   # surfaced on next save()/wait()
                self._error = e
            finally:
                self._q.task_done()

    def _write(self, step: int, host_leaves, treedef_repr: str):
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "leaves.npz",
                 **{f"leaf_{i}": l for i, l in enumerate(host_leaves)})
        (tmp / "manifest.json").write_text(json.dumps({
            "step": step, "n_leaves": len(host_leaves),
            "treedef": treedef_repr,
            "shapes": [list(l.shape) for l in host_leaves],
            "dtypes": [str(l.dtype) for l in host_leaves]}))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)            # atomic publish
        self._gc()

    def _gc(self):
        steps = sorted(p for p in self.dir.iterdir()
                       if p.is_dir() and p.name.startswith("step_")
                       and not p.name.endswith(".tmp"))
        for p in steps[:-self.keep]:
            shutil.rmtree(p, ignore_errors=True)
