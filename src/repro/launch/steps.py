"""Step builders: train_step / prefill_step / serve_step with shardings.

These are the jit roots used by both the real launchers (train.py, serve.py)
and the multi-pod dry-run (dryrun.py lowers them against ShapeDtypeStructs).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCfg
from repro.core.gqs_layer import GQSAConfig
from repro.core.model_compress import compress_params_shapes
from repro.dist.sharding import DistContext, param_shardings
from repro.models.registry import get_model, lm_loss
from repro.optim import adamw
from repro.optim.schedule import warmup_cosine


def make_dist(cfg: ModelConfig, mesh=None, multi_pod: bool = False,
              shape: Optional[ShapeCfg] = None,
              sp_attention: bool = False) -> DistContext:
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    seq_axis = None
    if (shape is not None and shape.kind == "decode" and mesh is not None):
        dp = int(np.prod([mesh.shape[a] for a in batch_axes]))
        if shape.global_batch < dp and cfg.family in ("hybrid",):
            seq_axis = "data"   # sequence-sharded KV (distributed decode)
    return DistContext(mesh=mesh, batch_axes=batch_axes,
                       fsdp=cfg.fsdp, seq_axis=seq_axis,
                       sp_attention=sp_attention)


def batch_shardings(batch_tmpl: Dict, dist: DistContext):
    if dist.mesh is None:
        return None

    def one(leaf):
        b = leaf.shape[0]
        dp = int(np.prod([dist.axis_size(a) for a in dist.batch_axes]))
        spec = [None] * len(leaf.shape)
        if b % dp == 0 and b >= dp:
            spec[0] = dist.batch_axes
        return NamedSharding(dist.mesh, P(*spec))
    return jax.tree_util.tree_map(one, batch_tmpl)


def cache_shardings(cache_tmpl, batch: int, seq: int, dist: DistContext):
    """Cache leaves are [L(, G), B, S, inner...].

    * batch dim -> DP axes (when divisible);
    * ALSO one inner dim (KV heads / head_dim / MLA latent rank) -> model
      axis — without this the KV cache is the decode memory hog (e.g.
      yi-34b decode_32k: 1TB global / 16 DP shards = 64GB/dev; sharding
      head_dim over the 16-way model axis brings it to 4GB/dev);
    * batch too small to shard (long-context) -> sequence dim on 'data'.
    """
    if dist.mesh is None:
        return None
    dp = int(np.prod([dist.axis_size(a) for a in dist.batch_axes]))
    mp = dist.axis_size(dist.model_axis)

    def one(leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        b_idx = None
        if batch % dp == 0 and batch >= dp:
            for i, s in enumerate(shape):
                if s == batch:
                    spec[i] = dist.batch_axes
                    b_idx = i
                    break
        elif dist.seq_axis is not None:
            for i, s in enumerate(shape):
                if s == seq:
                    spec[i] = dist.seq_axis
                    b_idx = i
                    break
        if b_idx is not None:
            # inner dims live after the sequence dim: prefer the last dims
            # (KV-heads / head_dim / latent rank), skipping the seq dim
            for i in range(len(shape) - 1, b_idx + 1, -1):
                if shape[i] != seq and shape[i] % mp == 0 and shape[i] >= mp:
                    spec[i] = dist.model_axis
                    break
        return NamedSharding(dist.mesh, P(*spec))
    return jax.tree_util.tree_map(one, cache_tmpl)


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, dist: DistContext,
                     opt_cfg: adamw.AdamWConfig,
                     lr_fn=None, aux_weight: float = 1e-2,
                     accum_steps: int = 1, use_pallas: bool = False):
    api = get_model(cfg)
    lr_fn = lr_fn or (lambda step: opt_cfg.lr)

    def loss_fn(params, batch):
        logits, aux = api.forward(params, batch, cfg, dist, use_pallas)
        return lm_loss(logits, batch["labels"]) + aux_weight * aux

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def micro(carry, mb):
                acc_loss, acc_g = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (acc_loss + l,
                        jax.tree_util.tree_map(jnp.add, acc_g, g)), None
            micro_batch = jax.tree_util.tree_map(
                lambda x: x.reshape((accum_steps, -1) + x.shape[1:]), batch)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.float32(0.0), zeros), micro_batch)
            loss = loss / accum_steps
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, grads)
        lr = lr_fn(opt_state["step"])
        params, opt_state, gnorm = adamw.apply_updates(
            params, grads, opt_state, opt_cfg, lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return params, opt_state, metrics

    return train_step


def train_templates(cfg: ModelConfig, shape: ShapeCfg, dist: DistContext):
    """(params_sds, opt_sds, batch_sds, in_shardings) — no allocation."""
    from repro.configs.registry import input_specs
    api = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    params_sds = jax.eval_shape(functools.partial(api.init_params, cfg=cfg),
                                rng)
    opt_sds = jax.eval_shape(adamw.init_state, params_sds)
    batch_sds = input_specs(cfg, shape)
    p_sh = param_shardings(params_sds, dist)
    o_sh = {"m": p_sh, "v": p_sh,
            "step": NamedSharding(dist.mesh, P()) if dist.mesh else None}
    b_sh = batch_shardings(batch_sds, dist)
    return params_sds, opt_sds, batch_sds, (p_sh, o_sh, b_sh)


def build_train_step_ddp(cfg: ModelConfig, dist: DistContext,
                         opt_cfg: adamw.AdamWConfig, lr_fn=None,
                         aux_weight: float = 1e-2, compress: bool = True):
    """shard_map DDP train step with int8 error-feedback gradient all-reduce
    (params replicated; for models that fit per-device — the paper's own
    llama-2-7b class). State gains an ``err`` tree (error feedback)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.optim import grad_compress as GC
    api = get_model(cfg)
    lr_fn = lr_fn or (lambda step: opt_cfg.lr)
    axes = dist.batch_axes

    def local_step(params, opt_state, err, batch):
        def loss_fn(p):
            logits, aux = api.forward(p, batch, cfg, None)
            return lm_loss(logits, batch["labels"]) + aux_weight * aux
        loss, grads = jax.value_and_grad(loss_fn)(params)
        loss = jax.lax.pmean(loss, axes)
        if compress:
            grads, err = GC.allreduce_compressed(grads, err, axes)
        else:
            grads = GC.allreduce_mean(grads, axes)
        lr = lr_fn(opt_state["step"])
        params, opt_state, gnorm = adamw.apply_updates(
            params, grads, opt_state, opt_cfg, lr)
        return params, opt_state, err, {"loss": loss, "grad_norm": gnorm,
                                        "lr": lr}

    if dist.mesh is None:
        # single-device fallback: no collective, no compression effect
        def step1(params, opt_state, err, batch):
            def loss_fn(p):
                logits, aux = api.forward(p, batch, cfg, None)
                return lm_loss(logits, batch["labels"]) + aux_weight * aux
            loss, grads = jax.value_and_grad(loss_fn)(params)
            lr = lr_fn(opt_state["step"])
            params, opt_state, gnorm = adamw.apply_updates(
                params, grads, opt_state, opt_cfg, lr)
            return params, opt_state, err, {"loss": loss,
                                            "grad_norm": gnorm, "lr": lr}
        return step1

    rep = lambda t: jax.tree_util.tree_map(
        lambda l: P(*([None] * getattr(l, "ndim", 0))), t)

    def step(params, opt_state, err, batch):
        p_spec = rep(params)
        o_spec = rep(opt_state)
        e_spec = rep(err)
        b_spec = jax.tree_util.tree_map(
            lambda l: P(axes, *([None] * (l.ndim - 1))), batch)
        m_spec = {"loss": P(), "grad_norm": P(), "lr": P()}
        return shard_map(local_step, mesh=dist.mesh,
                         in_specs=(p_spec, o_spec, e_spec, b_spec),
                         out_specs=(p_spec, o_spec, e_spec, m_spec),
                         check_rep=False)(params, opt_state, err, batch)

    return step


# ---------------------------------------------------------------------------
# prefill / serve
# ---------------------------------------------------------------------------

def build_prefill_step(cfg: ModelConfig, dist: DistContext,
                       use_pallas: bool = False):
    api = get_model(cfg)

    def prefill_step(params, batch):
        logits, _ = api.forward(params, batch, cfg, dist, use_pallas,
                                last_only=True)
        return jnp.argmax(logits[:, -1, :], axis=-1)

    return prefill_step


def build_serve_step(cfg: ModelConfig, dist: DistContext,
                     use_pallas: bool = False):
    api = get_model(cfg)

    def serve_step(params, cache, tokens, pos):
        logits, new_cache = api.decode_step(params, cache, tokens, pos, cfg,
                                            dist, use_pallas)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1, keepdims=True)
        return next_tok.astype(jnp.int32), new_cache

    return serve_step


def serve_templates(cfg: ModelConfig, shape: ShapeCfg, dist: DistContext,
                    gqsa: Optional[GQSAConfig]):
    """(packed_params_sds, cache_sds, tokens_sds, in_shardings)."""
    api = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    params_sds = jax.eval_shape(functools.partial(api.init_params, cfg=cfg),
                                rng)
    if gqsa is not None:
        params_sds = compress_params_shapes(params_sds, cfg, gqsa)
    b = shape.global_batch
    cache_sds = jax.eval_shape(
        functools.partial(api.init_cache, cfg, b, shape.seq_len))
    tokens_sds = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    p_sh = param_shardings(params_sds, dist)
    c_sh = cache_shardings(cache_sds, b, shape.seq_len, dist)
    t_sh = batch_shardings({"t": tokens_sds}, dist)
    t_sh = t_sh["t"] if t_sh else None
    pos_sh = NamedSharding(dist.mesh, P()) if dist.mesh else None
    return (params_sds, cache_sds, tokens_sds, pos_sds,
            (p_sh, c_sh, t_sh, pos_sh))
