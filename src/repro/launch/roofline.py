"""Aggregate dry-run artifacts into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.launch.roofline [--dir experiments/dryrun]
        [--write experiments/roofline.md]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs.base import SHAPES
from repro.configs.registry import get_config, list_archs, supported_shapes


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def fmt_b(x):
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.2f}{unit}"
    return f"{x:.0f}B"


def load(dir_: Path, variant=None):
    recs = {}
    for f in sorted(dir_.glob("*.json")):
        rec = json.loads(f.read_text())
        v = rec.get("variant", "baseline")
        if variant is not None and v != variant:
            continue
        key = (rec["arch"], rec["shape"], rec["mesh"], v)
        recs[key] = rec
    return recs


def dryrun_table(recs) -> str:
    """§Dry-run: compile status + memory per cell (both meshes)."""
    lines = ["| arch | shape | mesh | status | HBM/dev | args/dev | "
             "compile | collective bytes/dev/step |",
             "|---|---|---|---|---|---|---|---|"]
    for arch in list_archs():
        cfg = get_config(arch)
        for shape in SHAPES:
            runnable = shape in supported_shapes(cfg)
            for mesh in ("16x16", "2x16x16"):
                if not runnable:
                    if mesh == "16x16":
                        lines.append(f"| {arch} | {shape} | - | SKIP "
                                     f"(full attention at 512k; DESIGN.md "
                                     f"§6) | - | - | - | - |")
                    continue
                rec = recs.get((arch, shape, mesh, "baseline"))
                if rec is None:
                    lines.append(f"| {arch} | {shape} | {mesh} | MISSING "
                                 f"| - | - | - | - |")
                    continue
                if rec["status"] != "ok":
                    lines.append(f"| {arch} | {shape} | {mesh} | FAIL | - "
                                 f"| - | - | - |")
                    continue
                mem = rec["memory_analysis"]
                tot = mem.get("total_hbm_bytes")
                args = mem.get("argument_size_in_bytes")
                coll = rec["collective_bytes"]["total"]
                lines.append(
                    f"| {arch} | {shape} | {mesh} | ok | "
                    f"{fmt_b(tot) if tot else '-'} | "
                    f"{fmt_b(args) if args else '-'} | "
                    f"{rec['compile_s']:.0f}s | {fmt_b(coll)} |")
    return "\n".join(lines)


def roofline_table(recs) -> str:
    """§Roofline: three terms per (arch x shape), single-pod mesh."""
    lines = ["| arch | shape | compute | memory | collective | dominant | "
             "MODEL_FLOPS | useful ratio |",
             "|---|---|---|---|---|---|---|---|"]
    for arch in list_archs():
        cfg = get_config(arch)
        for shape in supported_shapes(cfg):
            rec = recs.get((arch, shape, "16x16", "baseline"))
            if rec is None or rec.get("status") != "ok" or \
                    "roofline" not in rec:
                lines.append(f"| {arch} | {shape} | - | - | - | - | - | - |")
                continue
            r = rec["roofline"]
            lines.append(
                f"| {arch} | {shape} | {fmt_s(r['compute_s'])} | "
                f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
                f"**{r['dominant']}** | {r['model_flops']:.2e} | "
                f"{(r.get('useful_ratio') or 0):.3f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--write", default=None)
    args = ap.parse_args()
    recs = load(Path(args.dir))
    out = ("## Dry-run\n\n" + dryrun_table(recs)
           + "\n\n## Roofline (single-pod 16x16, baseline)\n\n"
           + roofline_table(recs) + "\n")
    if args.write:
        Path(args.write).write_text(out)
        print(f"wrote {args.write}")
    else:
        print(out)


if __name__ == "__main__":
    main()
