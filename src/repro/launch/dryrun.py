import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes and extract memory / cost / collective artifacts for the roofline.

The two lines above MUST stay first: jax locks the device count at first
init, and only the dry-run wants 512 host devices.

Usage:
    python -m repro.launch.dryrun --arch qwen3_14b --shape train_4k \
        --mesh single --out experiments/dryrun
    python -m repro.launch.dryrun --all [--mesh both] [--jobs-file f.json]

--all runs each cell in a fresh subprocess (XLA compile state does not
accumulate; one bad cell cannot kill the sweep) and aggregates a summary.
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES
from repro.configs.registry import (get_config, input_specs, list_archs,
                                    supported_shapes)
from repro.core.gqs_layer import GQSAConfig
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (batch_shardings, build_prefill_step,
                                build_serve_step, build_train_step,
                                make_dist, serve_templates, train_templates)
from repro.optim import adamw


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             gqsa_sparsity: float = 0.5, accum_steps: int = 0,
             variant: str = "baseline") -> dict:
    cfg = get_config(arch)
    import dataclasses
    if "bf16p" in variant:
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    if "kv8" in variant:
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    dist = make_dist(cfg, mesh, multi_pod, shape,
                     sp_attention=("spattn" in variant))
    if accum_steps == 0:
        # baseline default: microbatch of ~4 sequences per data shard
        # (1 per shard for FSDP giants — memory first, then hillclimb)
        dp = 32 if multi_pod else 16
        per = 1 if cfg.fsdp else 4
        accum_steps = max(1, shape.global_batch // dp // per) \
            if shape.kind == "train" else 1
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            step = build_train_step(cfg, dist, adamw.AdamWConfig(),
                                    accum_steps=accum_steps)
            p_sds, o_sds, b_sds, in_sh = train_templates(cfg, shape, dist)
            jitted = jax.jit(step, in_shardings=in_sh,
                             donate_argnums=(0, 1))
            lowered = jitted.lower(p_sds, o_sds, b_sds)
        elif shape.kind == "prefill":
            step = build_prefill_step(cfg, dist)
            p_sds, _, b_sds, (p_sh, _, b_sh) = train_templates(
                cfg, shape, dist)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(p_sds, b_sds)
        else:  # decode
            gqsa = GQSAConfig()
            if gqsa_sparsity != 0.5:
                from repro.core.pruning import PruneConfig
                gqsa = GQSAConfig(prune=PruneConfig(sparsity=gqsa_sparsity))
            step = build_serve_step(cfg, dist)
            p_sds, c_sds, t_sds, pos_sds, in_sh = serve_templates(
                cfg, shape, dist, gqsa)
            jitted = jax.jit(step, in_shardings=in_sh, donate_argnums=(1,))
            lowered = jitted.lower(p_sds, c_sds, t_sds, pos_sds)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    mem = H.memory_analysis_dict(compiled)
    hlo = compiled.as_text()
    coll = H.collective_bytes_from_hlo(hlo)
    mf = H.model_flops_estimate(cfg, shape)
    roof = H.roofline_terms(cost, coll, chips, model_flops=mf)

    print(f"[dryrun] memory_analysis: {json.dumps(mem)}")
    print(f"[dryrun] cost_analysis: flops/dev={cost.get('flops', 0):.3e} "
          f"bytes/dev={cost.get('bytes accessed', 0):.3e}")
    print(f"[dryrun] collectives/dev: {json.dumps(coll)}")

    # component-wise analysis: exact FLOPs/bytes/collectives (scan bodies
    # are undercounted by HloCostAnalysis — see component_analysis.py)
    from repro.launch.component_analysis import analyze_cell
    gqsa_obj = None
    if shape.kind == "decode":
        gqsa_obj = GQSAConfig()
        if gqsa_sparsity != 0.5:
            from repro.core.pruning import PruneConfig
            gqsa_obj = GQSAConfig(prune=PruneConfig(sparsity=gqsa_sparsity))
    try:
        comp = analyze_cell(cfg, shape, mesh, multi_pod, gqsa=gqsa_obj,
                            accum=accum_steps,
                            sp_attention=("spattn" in variant))
    except Exception as e:
        comp = {"error": f"{type(e).__name__}: {e}"}
    if "roofline" in comp:
        print(f"[dryrun] component roofline: "
              f"{json.dumps(comp['roofline'])}")

    return {
        "arch": arch, "shape": shape_name, "variant": variant,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips, "kind": shape.kind,
        "accum_steps": accum_steps,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "memory_analysis": mem,
        "collective_bytes": coll,
        "roofline_wholeprog": roof.as_dict(),
        "component_analysis": comp,
        "roofline": comp.get("roofline", roof.as_dict()),
        "status": "ok",
    }


def _cell_filename(arch, shape_name, mesh_tag, variant):
    v = "" if variant == "baseline" else f"__{variant}"
    return f"{arch}__{shape_name}__{mesh_tag}{v}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--accum-steps", type=int, default=0)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    if args.all:
        failures = 0
        for arch in list_archs():
            cfg = get_config(arch)
            shapes = supported_shapes(cfg)
            for shape_name in shapes:
                for mesh_tag in (["16x16", "2x16x16"]
                                 if args.mesh == "both" else
                                 ["2x16x16" if args.mesh == "multi"
                                  else "16x16"]):
                    fn = out_dir / _cell_filename(arch, shape_name, mesh_tag,
                                                  args.variant)
                    if args.skip_existing and fn.exists():
                        ok = json.loads(fn.read_text()).get("status") == "ok"
                        if ok:
                            print(f"skip {fn.name}")
                            continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape_name,
                           "--mesh",
                           "multi" if mesh_tag == "2x16x16" else "single",
                           "--out", str(out_dir),
                           "--variant", args.variant]
                    print(f"=== {arch} x {shape_name} x {mesh_tag} ===",
                          flush=True)
                    r = subprocess.run(cmd)
                    if r.returncode != 0:
                        failures += 1
        print(f"dry-run sweep complete, failures={failures}")
        sys.exit(1 if failures else 0)

    assert args.arch and args.shape, "--arch/--shape or --all"
    multi = args.mesh == "multi"
    mesh_tag = "2x16x16" if multi else "16x16"
    fn = out_dir / _cell_filename(args.arch, args.shape, mesh_tag,
                                  args.variant)
    try:
        rec = run_cell(args.arch, args.shape, multi,
                       gqsa_sparsity=args.sparsity,
                       accum_steps=args.accum_steps, variant=args.variant)
    except Exception as e:
        rec = {"arch": args.arch, "shape": args.shape, "mesh": mesh_tag,
               "variant": args.variant, "status": "fail",
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()}
        fn.write_text(json.dumps(rec, indent=1))
        print(rec["error"])
        sys.exit(1)
    fn.write_text(json.dumps(rec, indent=1))
    print(f"wrote {fn}")


if __name__ == "__main__":
    main()
