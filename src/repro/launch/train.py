"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama2_7b --reduced \
        --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Production posture: sharded jit step (TP/FSDP/EP via dist rules) or
--ddp [--grad-compress] shard_map data parallelism; async atomic
checkpoints; straggler watchdog; retrying step wrapper; elastic restart —
on relaunch it restores the latest checkpoint onto whatever mesh the
surviving devices support (dist/elastic.py) and the deterministic data
pipeline resumes from the step counter alone.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ShapeCfg
from repro.configs.registry import get_config
from repro.data.pipeline import make_pipeline
from repro.dist.elastic import build_mesh, plan_mesh
from repro.dist.fault import StepWatchdog, TrainerHealth, retrying
from repro.dist.sharding import param_shardings
from repro.launch.steps import (batch_shardings, build_train_step,
                                build_train_step_ddp, make_dist)
from repro.models.registry import get_model
from repro.optim import adamw
from repro.optim.grad_compress import init_error_state
from repro.optim.schedule import warmup_cosine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2_7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--data", default="synthetic",
                    choices=["synthetic", "bytes"])
    ap.add_argument("--data-path", default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ddp", action="store_true")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    api = get_model(cfg)
    n_dev = len(jax.devices())

    mesh = None
    if n_dev > 1:
        plan = plan_mesh(n_dev, model_parallel=args.model_parallel)
        mesh = build_mesh(plan)
        print(f"mesh: {dict(zip(plan.axes, plan.shape))}")
    dist = make_dist(cfg, mesh, multi_pod=False)

    data = make_pipeline(args.data, cfg.vocab, args.seq, args.batch,
                         seed=args.seed, path=args.data_path)
    rng = jax.random.PRNGKey(args.seed)
    params = api.init_params(rng, cfg)
    opt_state = adamw.init_state(params)
    err = init_error_state(params) if args.grad_compress else None
    opt_cfg = adamw.AdamWConfig(lr=args.lr)
    lr_fn = warmup_cosine(args.lr, args.warmup, args.steps)

    start_step = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir)
        latest = ckpt.latest_step()
        if latest is not None:
            shardings = None
            if mesh is not None:
                from jax.sharding import NamedSharding, PartitionSpec as P
                p_sh = param_shardings(params, dist)
                rep = NamedSharding(mesh, P())
                shardings = {"params": p_sh,
                             "opt": {"m": p_sh, "v": p_sh, "step": rep}}
            state = ckpt.restore({"params": params, "opt": opt_state},
                                 latest, shardings=shardings)
            params, opt_state = state["params"], state["opt"]
            start_step = latest
            print(f"restored checkpoint at step {latest}")

    if mesh is not None and not args.ddp:
        p_sh = param_shardings(params, dist)
        params = jax.device_put(params, p_sh)

    if args.ddp:
        step_fn = build_train_step_ddp(cfg, dist, opt_cfg, lr_fn,
                                       compress=args.grad_compress)
    else:
        step_fn = build_train_step(cfg, dist, opt_cfg, lr_fn,
                                   accum_steps=args.accum)
    step_fn = retrying(jax.jit(step_fn, donate_argnums=(0, 1))
                       if not args.ddp else step_fn)

    watchdog = StepWatchdog()
    health = TrainerHealth(watchdog)
    metrics_log = []
    t_train0 = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v)
                 for k, v in data.host_batch(step).items()}
        t0 = time.time()
        if args.ddp:
            params, opt_state, err, metrics = step_fn(params, opt_state,
                                                      err or params, batch)
        else:
            params, opt_state, metrics = step_fn(params, opt_state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        dt = time.time() - t0
        watchdog.observe(dt)
        if step % args.log_every == 0 or step == args.steps - 1:
            toks = args.batch * args.seq / dt
            print(f"step {step:5d} loss {metrics['loss']:.4f} "
                  f"gnorm {metrics['grad_norm']:.2f} "
                  f"lr {metrics['lr']:.2e} {dt*1e3:.0f}ms "
                  f"({toks:.0f} tok/s) health={health.as_dict()}")
            metrics_log.append(dict(metrics, step=step, dt=dt))
        if ckpt and step > start_step and step % args.save_every == 0:
            ckpt.save(step, {"params": params, "opt": opt_state})
    if ckpt:
        ckpt.save(args.steps, {"params": params, "opt": opt_state},
                  blocking=True)
        ckpt.wait()
    print(f"done in {time.time()-t_train0:.1f}s; "
          f"final loss {metrics_log[-1]['loss']:.4f}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(metrics_log, f)
    return metrics_log


if __name__ == "__main__":
    main()
