"""Roofline-term extraction from compiled XLA artifacts.

compute    = HLO_FLOPs / (chips * peak_FLOPs)
memory     = HLO_bytes / (chips * HBM_bw)
collective = collective_bytes / (chips * link_bw)

FLOPs/bytes come from compiled.cost_analysis() (per-device SPMD program;
multiplied back by `chips` to report whole-job HLO numbers per the spec).
Collective bytes are parsed from the optimized HLO text: the operand sizes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (async start/done pairs counted once).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# TPU v5e-class constants (per assignment)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %fusion.1 = bf16[8,4096,512]{2,1,0} all-gather(...)
_SHAPE_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s("
    + "|".join(_COLLECTIVES) + r")(?:-start)?\(")
_TUPLE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes per collective kind (per-device program)."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        # skip the 'done' half of async pairs — the start carries the shape
        if "-done(" in line or "-done." in line:
            continue
        hit = None
        for kind in _COLLECTIVES:
            if f" {kind}(" in line or f" {kind}-start(" in line:
                hit = kind
                break
        if hit is None:
            continue
        # the result shape(s) sit between '=' and the op name
        lhs = line.split("=", 1)
        if len(lhs) != 2:
            continue
        rhs = lhs[1]
        opidx = rhs.find(hit)
        shapes = _TUPLE_RE.findall(rhs[:opidx])
        nbytes = sum(_shape_bytes(d, s) for d, s in shapes)
        out[hit] += nbytes
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    flops_total: float           # whole-job HLO flops (= per-dev x chips)
    bytes_total: float
    collective_bytes_per_dev: float
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: Optional[float] = None
    useful_ratio: Optional[float] = None

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def roofline_terms(cost: dict, coll: Dict[str, int], chips: int,
                   model_flops: Optional[float] = None) -> Roofline:
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll_dev = float(coll.get("total", 0))
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    useful = None
    if model_flops:
        useful = model_flops / max(flops_dev * chips, 1.0)
    return Roofline(
        flops_total=flops_dev * chips, bytes_total=bytes_dev * chips,
        collective_bytes_per_dev=coll_dev, chips=chips,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops, useful_ratio=useful)


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS per the assignment: 6*N*D train (N_active for MoE);
    2*N_active per generated token for decode; 2*N_active*D for prefill."""
    n_active = cfg.n_active_params()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    # decode: one token per sequence per step
    return 2.0 * n_active * shape.global_batch


def memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # backend may not implement it
        return {"error": str(e)}
    if ma is None:
        return {"error": "memory_analysis() returned None"}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        out["total_hbm_bytes"] = (out.get("argument_size_in_bytes", 0)
                                  + out.get("output_size_in_bytes", 0)
                                  + out.get("temp_size_in_bytes", 0)
                                  - out.get("alias_size_in_bytes", 0))
    return out
