"""Component-wise cost analysis — exact FLOP/byte/collective accounting.

XLA's HloCostAnalysis visits a while-loop body ONCE, so cost_analysis() on a
scan-over-layers program undercounts by ~n_layers. We therefore lower +
compile each repeated component separately (with inner scans unrolled), read
its per-device cost, and combine:

    train:   n_layers x grad(block) + grad(head) + optimizer + grad-sync
    prefill: n_layers x block + head(last-token)
    decode:  n_layers x decode(block) + decode(head)

Each component is compiled on the same production mesh with the same
shardings as the full program, so TP/EP collectives inside a layer are
captured per-execution. The whole-program compile (dryrun.run_cell) remains
the source of truth for memory_analysis and for "it lowers+compiles".
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCfg
from repro.core.gqs_layer import GQSAConfig
from repro.core.model_compress import compress_params_shapes
from repro.dist.sharding import DistContext, param_shardings
from repro.launch import hlo_analysis as H
from repro.launch.steps import make_dist
from repro.models import encdec as ED
from repro.models import hybrid as HY
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models import ssm_lm as SL
from repro.models import transformer as TF
from repro.models.registry import get_model, lm_loss
from repro.optim import adamw


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _slice_layer(tree):
    """Drop the leading stack dim from every leaf (SDS-safe)."""
    return jax.tree_util.tree_map(
        lambda l: _sds(l.shape[1:], l.dtype), tree)


def _compile_component(fn, arg_sds: Tuple, arg_sh: Tuple, mesh,
                       out_sh=None):
    with mesh:
        kw = {"in_shardings": arg_sh}
        if out_sh is not None:
            kw["out_shardings"] = out_sh
        lowered = jax.jit(fn, **kw).lower(*arg_sds)
        compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    coll = H.collective_bytes_from_hlo(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(coll.get("total", 0.0))}


def _acfg(cfg: ModelConfig, shape: ShapeCfg,
          unroll: bool = True) -> ModelConfig:
    """Analysis config. unroll=True: inner scans unrolled + wide attention
    blocks (exact FLOPs / collectives; pair count stays ~36 even at 32k).
    unroll=False: scans kept + small blocks — HloCostAnalysis then counts
    each loop body once, which approximates the HBM traffic of a *fused*
    attention/SSD kernel (block intermediates live in VMEM on TPU), so this
    pass feeds the memory roofline term."""
    if unroll:
        blk = max(512, shape.seq_len // 8)
        return dataclasses.replace(cfg, analysis_unroll=True,
                                   attn_block_q=blk, attn_block_k=blk)
    return dataclasses.replace(cfg, analysis_unroll=False,
                               attn_block_q=512, attn_block_k=512)


def _batch_sh(dist, ndim, batch, b_dim=0):
    dp = int(np.prod([dist.axis_size(a) for a in dist.batch_axes]))
    spec = [None] * ndim
    if batch % dp == 0 and batch >= dp:
        spec[b_dim] = dist.batch_axes
    return NamedSharding(dist.mesh, P(*spec))


def _h_sh(dist, batch, seq):
    """Residual-stream sharding for layer components: matches the
    whole-program constraint (seq@model when sequence-parallel)."""
    dp = int(np.prod([dist.axis_size(a) for a in dist.batch_axes]))
    spec = [None, None, None]
    if batch % dp == 0 and batch >= dp:
        spec[0] = dist.batch_axes
    if getattr(dist, "sp_attention", False) and \
            seq % dist.axis_size(dist.model_axis) == 0:
        spec[1] = dist.model_axis
    return NamedSharding(dist.mesh, P(*spec))


def _rep(dist, ndim):
    return NamedSharding(dist.mesh, P(*([None] * ndim)))


# ---------------------------------------------------------------------------
# component definitions per family
# ---------------------------------------------------------------------------

def _train_components(cfg, shape, dist, mesh, accum: int,
                      unroll: bool = True) -> List[Tuple]:
    """[(name, multiplier, fn, arg_sds, arg_sh)] for a train step."""
    api = get_model(cfg)
    acfg = _acfg(cfg, shape, unroll)
    dp = int(np.prod([dist.axis_size(a) for a in dist.batch_axes]))
    b = shape.global_batch // max(accum, 1)
    s = shape.seq_len
    dt = cfg.compute_dtype
    d = cfg.d_model
    params_sds = jax.eval_shape(
        functools.partial(api.init_params, cfg=cfg), jax.random.PRNGKey(0))
    p_sh_full = param_shardings(params_sds, dist)
    comps = []

    h_sds = _sds((b, s, d), dt)
    h_sh = _h_sh(dist, b, s)
    pos_sds = _sds((b, s), jnp.int32)

    def add_block(name, mult, block_fn, lp_key):
        lp_sds = _slice_layer(params_sds[lp_key])
        lp_sh = param_shardings(lp_sds, dist)

        def g(lp, h, positions):
            def f(lp_, h_):
                out = block_fn(lp_, h_, positions)
                return jnp.sum(out.astype(jnp.float32))
            _, grads = jax.value_and_grad(f, argnums=(0, 1))(lp, h)
            return grads
        # grads land with the PARAM shardings (ZeRO reduce-scatter over the
        # FSDP axis rather than a full all-reduce)
        comps.append((name, mult, g, (lp_sds, h_sds, pos_sds),
                      (lp_sh, h_sh, _batch_sh(dist, 2, b)),
                      (lp_sh, h_sh)))

    if cfg.family in ("dense", "moe", "mla_moe", "vlm"):
        def block_fn(lp, h, positions):
            out, aux = TF._block(lp, h, positions, acfg, dist, False)
            return out + 0 * aux
        add_block("layer_grad", cfg.n_layers * accum, block_fn, "layers")
    elif cfg.family == "ssm":
        def block_fn(lp, h, positions):
            hn = L.rmsnorm(h, lp["ln"], cfg.norm_eps)
            return h + SSM.mamba_block(lp["mamba"], hn, acfg)
        add_block("layer_grad", cfg.n_layers * accum, block_fn, "layers")
    elif cfg.family == "hybrid":
        ng, rem = HY._n_groups(cfg)
        grouped = params_sds["groups"]
        one_m = jax.tree_util.tree_map(
            lambda l: _sds(l.shape[2:], l.dtype), grouped)
        mp_sh = param_shardings(one_m, dist)

        def mamba_fn(lp, h, positions):
            return h + SSM.mamba_block(lp, h, acfg)

        def g_m(lp, h, positions):
            def f(lp_, h_):
                return jnp.sum(mamba_fn(lp_, h_, positions)
                               .astype(jnp.float32))
            return jax.value_and_grad(f, argnums=(0, 1))(lp, h)[1]
        comps.append(("mamba_grad", cfg.n_layers * accum, g_m,
                      (one_m, h_sds, pos_sds),
                      (mp_sh, h_sh, _batch_sh(dist, 2, b))))

        sp_sds = params_sds["shared"]
        sp_sh = param_shardings(sp_sds, dist)

        def g_s(sp, h, positions):
            def f(sp_, h_):
                return jnp.sum(HY._shared_block(sp_, h_, positions, acfg,
                                                False).astype(jnp.float32))
            return jax.value_and_grad(f, argnums=(0, 1))(sp, h)[1]
        comps.append(("shared_attn_grad", ng * accum, g_s,
                      (sp_sds, h_sds, pos_sds),
                      (sp_sh, h_sh, _batch_sh(dist, 2, b))))
    elif cfg.family == "encdec":
        f_sds = _sds((b, cfg.n_frames, d), dt)
        enc_l = _slice_layer(params_sds["enc_layers"])
        dec_l = _slice_layer(params_sds["dec_layers"])
        epos = _sds((b, cfg.n_frames), jnp.int32)

        def enc_fn(lp, h, positions):
            a = L.attention_block(lp["attn"],
                                  L.rmsnorm(h, lp["ln1"], cfg.norm_eps),
                                  positions, acfg, causal=False)
            h = h + a
            return h + L.mlp_block(
                lp["mlp"], L.rmsnorm(h, lp["ln2"], cfg.norm_eps),
                cfg.mlp_type)

        def g_enc(lp, h, positions):
            def f(lp_, h_):
                return jnp.sum(enc_fn(lp_, h_, positions)
                               .astype(jnp.float32))
            return jax.value_and_grad(f, argnums=(0, 1))(lp, h)[1]
        comps.append(("enc_layer_grad", cfg.enc_layers * accum, g_enc,
                      (enc_l, f_sds, epos),
                      (param_shardings(enc_l, dist), _batch_sh(dist, 3, b),
                       _batch_sh(dist, 2, b))))

        def dec_fn(lp, h, enc_out, positions):
            a = L.attention_block(lp["self_attn"],
                                  L.rmsnorm(h, lp["ln1"], cfg.norm_eps),
                                  positions, acfg)
            h = h + a
            ek, ev = ED._cross_kv(lp["cross"], enc_out, acfg, False)
            c = ED._cross_attend(lp["cross"],
                                 L.rmsnorm(h, lp["ln2"], cfg.norm_eps),
                                 ek, ev, acfg, False)
            h = h + c
            return h + L.mlp_block(
                lp["mlp"], L.rmsnorm(h, lp["ln3"], cfg.norm_eps),
                cfg.mlp_type)

        def g_dec(lp, h, enc_out, positions):
            def f(lp_, h_, e_):
                return jnp.sum(dec_fn(lp_, h_, e_, positions)
                               .astype(jnp.float32))
            return jax.value_and_grad(f, argnums=(0, 1, 2))(lp, h, enc_out)[1]
        comps.append(("dec_layer_grad", cfg.n_layers * accum, g_dec,
                      (dec_l, h_sds, f_sds, pos_sds),
                      (param_shardings(dec_l, dist), h_sh,
                       _batch_sh(dist, 3, b), _batch_sh(dist, 2, b))))

    # head: embed + final norm + unembed + loss (+ backward)
    tok_sds = _sds((b, s), jnp.int32)
    head_keys = [k for k in ("embed", "final_norm", "lm_head") if
                 k in params_sds]
    hp_sds = {k: params_sds[k] for k in head_keys}
    hp_sh = param_shardings(hp_sds, dist)

    def head_fn(hp, h_res, tokens, labels):
        h = jnp.take(hp["embed"], tokens, axis=0).astype(dt) + h_res
        if cfg.family == "encdec":
            h2 = L.rmsnorm(h, hp["final_norm"], cfg.norm_eps)
            logits = jnp.einsum("bsd,vd->bsv", h2,
                                hp["lm_head"]["w"].astype(h2.dtype)) \
                if "lm_head" in hp else None
        else:
            logits = TF.unembed(hp, h, cfg)
        return lm_loss(logits, labels)

    def g_head(hp, h_res, tokens, labels):
        return jax.value_and_grad(head_fn, argnums=(0, 1))(
            hp, h_res, tokens, labels)[1]
    comps.append(("head_grad", accum, g_head,
                  (hp_sds, h_sds, tok_sds, tok_sds),
                  (hp_sh, h_sh, _batch_sh(dist, 2, b),
                   _batch_sh(dist, 2, b))))

    # optimizer update over the full tree
    opt_sds = jax.eval_shape(adamw.init_state, params_sds)
    o_sh = {"m": p_sh_full, "v": p_sh_full, "step": _rep(dist, 0)}
    grads_sh = p_sh_full

    def opt_fn(params, grads, opt_state):
        newp, news, _ = adamw.apply_updates(params, grads, opt_state,
                                            adamw.AdamWConfig())
        return newp, news
    comps.append(("optimizer", 1, opt_fn,
                  (params_sds, params_sds, opt_sds),
                  (p_sh_full, grads_sh, o_sh)))

    # gradient sync across DP axes (psum on replicated-across-data grads)
    if dp > 1 and not cfg.fsdp:
        from jax.experimental.shard_map import shard_map
        axes = dist.batch_axes

        def sync_fn(grads):
            spec = jax.tree_util.tree_map(
                lambda l: P(*([None] * l.ndim)), grads)
            return shard_map(
                lambda g: jax.tree_util.tree_map(
                    lambda x: jax.lax.psum(x, axes), g),
                mesh=mesh, in_specs=(spec,), out_specs=spec,
                check_rep=False)(grads)
        comps.append(("grad_sync", 1, sync_fn, (params_sds,), (p_sh_full,)))
    return comps


def _forward_components(cfg, shape, dist, mesh,
                        unroll: bool = True) -> List[Tuple]:
    """Prefill: forward-only blocks + last-token head."""
    out = []
    acfg = _acfg(cfg, shape, unroll)
    b, s, d = shape.global_batch, shape.seq_len, cfg.d_model
    dt = cfg.compute_dtype
    api = get_model(cfg)
    params_sds = jax.eval_shape(
        functools.partial(api.init_params, cfg=cfg), jax.random.PRNGKey(0))
    h_sds = _sds((b, s, d), dt)
    h_sh = _h_sh(dist, b, s)
    pos_sds = _sds((b, s), jnp.int32)

    def fwd_only(name, mult, block_fn, lp_key):
        lp_sds = _slice_layer(params_sds[lp_key])
        lp_sh = param_shardings(lp_sds, dist)

        def f(lp, h, positions):
            return block_fn(lp, h, positions)
        out.append((name.replace("_grad", "_fwd"), mult, f,
                    (lp_sds, h_sds, pos_sds),
                    (lp_sh, h_sh, _batch_sh(dist, 2, b))))

    if cfg.family in ("dense", "moe", "mla_moe", "vlm"):
        def block_fn(lp, h, positions):
            o, aux = TF._block(lp, h, positions, acfg, dist, False)
            return o
        fwd_only("layer_grad", cfg.n_layers, block_fn, "layers")
    elif cfg.family == "ssm":
        def block_fn(lp, h, positions):
            hn = L.rmsnorm(h, lp["ln"], cfg.norm_eps)
            return h + SSM.mamba_block(lp["mamba"], hn, acfg)
        fwd_only("layer_grad", cfg.n_layers, block_fn, "layers")
    elif cfg.family == "hybrid":
        ng, _ = HY._n_groups(cfg)
        one_m = jax.tree_util.tree_map(
            lambda l: _sds(l.shape[2:], l.dtype), params_sds["groups"])
        out.append(("mamba_fwd", cfg.n_layers,
                    lambda lp, h, positions: h + SSM.mamba_block(lp, h, acfg),
                    (one_m, h_sds, pos_sds),
                    (param_shardings(one_m, dist), h_sh,
                     _batch_sh(dist, 2, b))))
        out.append(("shared_attn_fwd", ng,
                    lambda sp, h, positions: HY._shared_block(
                        sp, h, positions, acfg, False),
                    (params_sds["shared"], h_sds, pos_sds),
                    (param_shardings(params_sds["shared"], dist), h_sh,
                     _batch_sh(dist, 2, b))))
    elif cfg.family == "encdec":
        f_sds = _sds((b, cfg.n_frames, d), dt)
        enc_l = _slice_layer(params_sds["enc_layers"])
        dec_l = _slice_layer(params_sds["dec_layers"])
        epos = _sds((b, cfg.n_frames), jnp.int32)

        def enc_fn(lp, h, positions):
            a = L.attention_block(lp["attn"],
                                  L.rmsnorm(h, lp["ln1"], cfg.norm_eps),
                                  positions, acfg, causal=False)
            h = h + a
            return h + L.mlp_block(lp["mlp"],
                                   L.rmsnorm(h, lp["ln2"], cfg.norm_eps),
                                   cfg.mlp_type)
        out.append(("enc_layer_fwd", cfg.enc_layers, enc_fn,
                    (enc_l, f_sds, epos),
                    (param_shardings(enc_l, dist), _batch_sh(dist, 3, b),
                     _batch_sh(dist, 2, b))))

        def dec_fn(lp, h, enc_out, positions):
            a = L.attention_block(lp["self_attn"],
                                  L.rmsnorm(h, lp["ln1"], cfg.norm_eps),
                                  positions, acfg)
            h = h + a
            ek, ev = ED._cross_kv(lp["cross"], enc_out, acfg, False)
            c = ED._cross_attend(lp["cross"],
                                 L.rmsnorm(h, lp["ln2"], cfg.norm_eps),
                                 ek, ev, acfg, False)
            h = h + c
            return h + L.mlp_block(lp["mlp"],
                                   L.rmsnorm(h, lp["ln3"], cfg.norm_eps),
                                   cfg.mlp_type)
        out.append(("dec_layer_fwd", cfg.n_layers, dec_fn,
                    (dec_l, h_sds, f_sds, pos_sds),
                    (param_shardings(dec_l, dist), h_sh,
                     _batch_sh(dist, 3, b), _batch_sh(dist, 2, b))))

    # last-token head (embed fwd + unembed of one position)
    tok_sds = _sds((b, s), jnp.int32)
    head_keys = [k for k in ("embed", "final_norm", "lm_head") if
                 k in params_sds]
    hp_sds = {k: params_sds[k] for k in head_keys}

    def head_fn(hp, h_res, tokens):
        h = jnp.take(hp["embed"], tokens, axis=0).astype(dt) + h_res
        return TF.unembed(hp, h[:, -1:, :], cfg)
    out.append(("head_fwd", 1, head_fn, (hp_sds, h_sds, tok_sds),
                (param_shardings(hp_sds, dist), h_sh,
                 _batch_sh(dist, 2, b))))
    return out


def _decode_components(cfg, shape, dist, mesh,
                       gqsa: Optional[GQSAConfig]) -> List[Tuple]:
    api = get_model(cfg)
    b, s, d = shape.global_batch, shape.seq_len, cfg.d_model
    dt = cfg.compute_dtype
    params_sds = jax.eval_shape(
        functools.partial(api.init_params, cfg=cfg), jax.random.PRNGKey(0))
    if gqsa is not None:
        params_sds = compress_params_shapes(params_sds, cfg, gqsa)
    cache_sds = jax.eval_shape(
        functools.partial(api.init_cache, cfg, b, s))
    from repro.launch.steps import cache_shardings
    cache_sh_full = cache_shardings(cache_sds, b, s, dist)
    h_sds = _sds((b, 1, d), dt)
    h_sh = _batch_sh(dist, 3, b)
    pos_sds = _sds((), jnp.int32)
    pos_sh = _rep(dist, 0)
    comps = []

    def slice_cache(tree, sh_tree):
        return (jax.tree_util.tree_map(
            lambda l: _sds(l.shape[1:], l.dtype), tree),
            jax.tree_util.tree_map(
                lambda ns: NamedSharding(ns.mesh, P(*ns.spec[1:])), sh_tree))

    if cfg.family in ("dense", "moe", "mla_moe", "vlm"):
        lp_sds = _slice_layer(params_sds["layers"])
        lp_sh = param_shardings(lp_sds, dist)
        lc_sds, lc_sh = slice_cache(cache_sds, cache_sh_full)

        def block_dec(lp, lc, h, pos):
            hn = L.rmsnorm(h, lp["ln1"], cfg.norm_eps)
            if cfg.family == "mla_moe":
                a, new_c = MLA.mla_decode(lp["attn"], hn, lc, pos, cfg)
            else:
                a, new_c = L.attention_decode(lp["attn"], hn, lc, pos, cfg)
            h = h + a
            hn = L.rmsnorm(h, lp["ln2"], cfg.norm_eps)
            if cfg.moe is not None:
                m, _ = MOE.moe_block(lp["moe"], hn, cfg, dist)
            else:
                m = L.mlp_block(lp["mlp"], hn, cfg.mlp_type)
            return h + m, new_c
        comps.append(("layer_decode", cfg.n_layers, block_dec,
                      (lp_sds, lc_sds, h_sds, pos_sds),
                      (lp_sh, lc_sh, h_sh, pos_sh)))
    elif cfg.family == "ssm":
        lp_sds = _slice_layer(params_sds["layers"])
        lp_sh = param_shardings(lp_sds, dist)
        lc_sds, lc_sh = slice_cache(cache_sds, cache_sh_full)

        def block_dec(lp, lc, h, pos):
            hn = L.rmsnorm(h, lp["ln"], cfg.norm_eps)
            y, new_c = SSM.mamba_decode(lp["mamba"], hn, lc, cfg)
            return h + y, new_c
        comps.append(("layer_decode", cfg.n_layers, block_dec,
                      (lp_sds, lc_sds, h_sds, pos_sds),
                      (lp_sh, lc_sh, h_sh, pos_sh)))
    elif cfg.family == "hybrid":
        ng, _ = HY._n_groups(cfg)
        one_m = jax.tree_util.tree_map(
            lambda l: _sds(l.shape[2:], l.dtype), params_sds["groups"])
        mc_sds = jax.tree_util.tree_map(
            lambda l: _sds(l.shape[2:], l.dtype), cache_sds["groups"])
        mc_sh = jax.tree_util.tree_map(
            lambda ns: NamedSharding(ns.mesh, P(*ns.spec[2:])),
            cache_shardings(cache_sds, b, s, dist)["groups"])

        def mamba_dec(lp, lc, h, pos):
            y, new_c = SSM.mamba_decode(lp, h, lc, cfg)
            return h + y, new_c
        comps.append(("mamba_decode", cfg.n_layers, mamba_dec,
                      (one_m, mc_sds, h_sds, pos_sds),
                      (param_shardings(one_m, dist), mc_sh, h_sh, pos_sh)))

        kv_sds, kv_sh = slice_cache(cache_sds["attn"],
                                    cache_shardings(cache_sds, b, s,
                                                    dist)["attn"])
        sp_sds = params_sds["shared"]

        def attn_dec(sp, kv, h, pos):
            hn = L.rmsnorm(h, sp["ln1"], cfg.norm_eps)
            a, new_kv = HY._attn_decode_dist(sp, hn, kv, pos, cfg, dist,
                                             False)
            h = h + a
            m = L.mlp_block(sp["mlp"], L.rmsnorm(h, sp["ln2"], cfg.norm_eps),
                            cfg.mlp_type)
            return h + m, new_kv
        comps.append(("shared_attn_decode", ng, attn_dec,
                      (sp_sds, kv_sds, h_sds, pos_sds),
                      (param_shardings(sp_sds, dist), kv_sh, h_sh, pos_sh)))
    elif cfg.family == "encdec":
        dec_l = _slice_layer(params_sds["dec_layers"])
        lc_sds, lc_sh = slice_cache(cache_sds, cache_sh_full)

        def block_dec(lp, lc, h, pos):
            hn = L.rmsnorm(h, lp["ln1"], cfg.norm_eps)
            a, new_kv = L.attention_decode(lp["self_attn"], hn,
                                           {"k": lc["k"], "v": lc["v"]},
                                           pos, cfg)
            h = h + a
            hn = L.rmsnorm(h, lp["ln2"], cfg.norm_eps)
            q = jnp.reshape(
                jnp.einsum("bsd,od->bso", hn,
                           lp["cross"]["wq"]["w"].astype(hn.dtype))
                if "w" in lp["cross"]["wq"] else
                jnp.zeros((b, 1, cfg.n_heads * cfg.hd), hn.dtype),
                (b, 1, cfg.n_heads, cfg.hd))
            o = L.decode_attention(q, lc["cross_k"], lc["cross_v"],
                                   jnp.int32(cfg.n_frames))
            from repro.core.gqs_layer import apply_linear
            c = apply_linear(lp["cross"]["wo"], o.reshape(b, 1, -1))
            h = h + c
            m = L.mlp_block(lp["mlp"], L.rmsnorm(h, lp["ln3"], cfg.norm_eps),
                            cfg.mlp_type)
            return h + m, new_kv
        comps.append(("dec_layer_decode", cfg.n_layers, block_dec,
                      (dec_l, lc_sds, h_sds, pos_sds),
                      (param_shardings(dec_l, dist), lc_sh, h_sh, pos_sh)))

    # decode head: embed 1 token + unembed 1 position
    tok_sds = _sds((b, 1), jnp.int32)
    head_keys = [k for k in ("embed", "final_norm", "lm_head") if
                 k in params_sds]
    hp_sds = {k: params_sds[k] for k in head_keys}

    def head_dec(hp, h_res, tokens):
        h = jnp.take(hp["embed"], tokens, axis=0).astype(dt) + h_res
        return TF.unembed(hp, h, cfg)
    comps.append(("head_decode", 1, head_dec, (hp_sds, h_sds, tok_sds),
                  (param_shardings(hp_sds, dist), h_sh,
                   _batch_sh(dist, 2, b))))
    return comps


# ---------------------------------------------------------------------------

def analyze_cell(cfg: ModelConfig, shape: ShapeCfg, mesh, multi_pod: bool,
                 gqsa: Optional[GQSAConfig] = None,
                 accum: int = 1, sp_attention: bool = False) -> Dict:
    dist = make_dist(cfg, mesh, multi_pod, shape,
                     sp_attention=sp_attention)

    def build(unroll: bool):
        if shape.kind == "train":
            return _train_components(cfg, shape, dist, mesh, accum, unroll)
        if shape.kind == "prefill":
            return _forward_components(cfg, shape, dist, mesh, unroll)
        return _decode_components(cfg, shape, dist, mesh, gqsa)

    comps_u = build(True)    # pass A: exact flops + collectives
    comps_s = build(False)   # pass B: fused-kernel-like bytes

    per = {}
    tot = {"flops": 0.0, "bytes": 0.0, "coll": 0.0}
    for (cu, cs) in zip(comps_u, comps_s):
        name, mult, fn_u, sds_u, sh_u = cu[:5]
        out_sh = cu[5] if len(cu) > 5 else None
        fn_s, sds_s, sh_s = cs[2], cs[3], cs[4]
        rec = {"multiplier": mult}
        try:
            a = _compile_component(fn_u, sds_u, sh_u, mesh, out_sh)
            rec.update(flops=a["flops"], coll=a["coll"],
                       bytes_unrolled=a["bytes"])
        except Exception as e:
            per[name] = {"error": f"{type(e).__name__}: {e}"}
            continue
        if shape.kind == "decode":
            rec["bytes"] = a["bytes"]   # no inner scans in decode
        else:
            try:
                b = _compile_component(fn_s, sds_s, sh_s, mesh, out_sh)
                rec["bytes"] = b["bytes"]
            except Exception as e:
                rec["bytes"] = a["bytes"]
                rec["bytes_pass_error"] = f"{type(e).__name__}: {e}"
        per[name] = rec
        tot["flops"] += rec["flops"] * mult
        tot["bytes"] += rec["bytes"] * mult
        tot["coll"] += rec["coll"] * mult
    chips = mesh.devices.size
    mf = H.model_flops_estimate(cfg, shape)
    roof = H.roofline_terms({"flops": tot["flops"],
                             "bytes accessed": tot["bytes"]},
                            {"total": tot["coll"]}, chips, model_flops=mf)
    return {"components": per, "totals": tot,
            "roofline": roof.as_dict()}
