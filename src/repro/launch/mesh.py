"""Production mesh construction (spec'd by the assignment).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device count is locked at first jax init, and tests/benches
must see 1 CPU device while the dry-run sees 512 host devices).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (256 chips/pod) single-pod, or 2x16x16 = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)
