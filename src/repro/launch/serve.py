"""Batched serving loop with GQSA-compressed weights.

    PYTHONPATH=src python -m repro.launch.serve --arch llama2_7b --reduced \
        --compress gqsa --requests 16 --max-new 32

Continuous-batching-lite: a fixed pool of batch slots; each slot runs one
request; finished requests (EOS-by-length) are swapped for queued ones
without stopping the decode loop. Reports tokens/s + per-phase latency.
"""
from __future__ import annotations

import argparse
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.gqs_layer import GQSAConfig
from repro.core.model_compress import (compress_params, compress_params_w4)
from repro.core.pruning import PruneConfig
from repro.core.quant import QuantConfig
from repro.models.registry import get_model


def make_requests(n, vocab, rng, lo=4, hi=16):
    lens = rng.integers(lo, hi, size=n)
    return [rng.integers(0, vocab, size=l).astype(np.int32) for l in lens]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2_7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--compress", default="gqsa",
                    choices=["none", "w4", "gqsa"])
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--group-size", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    api = get_model(cfg)
    rng = jax.random.PRNGKey(args.seed)
    params = api.init_params(rng, cfg)

    t0 = time.time()
    if args.compress == "gqsa":
        gqsa = GQSAConfig(
            quant=QuantConfig(bits=4, group_size=args.group_size),
            prune=PruneConfig(sparsity=args.sparsity,
                              group_size=args.group_size))
        params = compress_params(params, cfg, gqsa)
        print(f"packed GQSA W4 S{int(args.sparsity*100)}% "
              f"G{args.group_size} in {time.time()-t0:.1f}s")
    elif args.compress == "w4":
        params = compress_params_w4(params, cfg, QuantConfig(
            bits=4, group_size=args.group_size))
        print(f"packed W4 in {time.time()-t0:.1f}s")

    nprng = np.random.default_rng(args.seed)
    queue: List[np.ndarray] = make_requests(args.requests, cfg.vocab, nprng)
    slots = args.slots
    cache = api.init_cache(cfg, slots, args.max_seq)

    @jax.jit
    def decode(params, cache, tokens, pos):
        logits, cache = api.decode_step(params, cache, tokens, pos, cfg)
        return jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32), cache

    # slot state
    active = [None] * slots          # request prompt or None
    produced = [0] * slots
    outputs = []
    tokens = jnp.zeros((slots, 1), jnp.int32)
    t_start = time.time()
    n_tokens = 0
    pos = 0

    def refill(slot):
        nonlocal tokens
        if queue:
            req = queue.pop()
            active[slot] = req
            produced[slot] = 0
            # feed the prompt one token per step (shared-pos simple scheduler)
            tokens = tokens.at[slot, 0].set(int(req[0]))

    for s in range(slots):
        refill(s)

    while any(a is not None for a in active) and pos < args.max_seq - 1:
        next_tok, cache = decode(params, cache, tokens, jnp.int32(pos))
        pos += 1
        for s in range(slots):
            if active[s] is None:
                continue
            req = active[s]
            if pos < len(req):               # still feeding the prompt
                tokens = tokens.at[s, 0].set(int(req[pos]))
            else:
                tokens = tokens.at[s, 0].set(int(next_tok[s]))
                produced[s] += 1
                n_tokens += 1
                if produced[s] >= args.max_new:
                    outputs.append((len(req), produced[s]))
                    active[s] = None
                    refill(s)

    dt = time.time() - t_start
    print(f"served {len(outputs)} requests, {n_tokens} new tokens "
          f"in {dt:.2f}s -> {n_tokens/max(dt,1e-9):.1f} tok/s "
          f"({slots} slots, pos<={pos})")
    return {"requests": len(outputs), "tokens": n_tokens, "seconds": dt,
            "tok_per_s": n_tokens / max(dt, 1e-9)}


if __name__ == "__main__":
    main()
