"""Serving CLI: a thin wrapper over the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch llama2_7b --reduced \
        --compress gqsa --requests 16 --max-new 32

Requests are admitted in FIFO arrival order into a fixed pool of batch
slots backed by a paged KV cache; prompts are prefilled in one batched
flash-attention call (no one-token-per-step prompt feeding) and decode
runs one fused per-slot-position step with device-side token feedback.
Reports tokens/s, TTFT, TPOT and p50/p99 latency (repro.engine).

Load-conditioned serving (DESIGN.md §11): ``--workload`` replaces the
submit-everything-up-front default with a seeded traffic spec (open-loop
Poisson / bursty / closed-loop arrival processes, prompt and budget
distributions, shared-prefix pools) whose requests arrive MID-RUN
through the engine's timed-admission loop, and ``--slo`` judges every
request against TTFT/TPOT/e2e deadlines — printing attainment, goodput
(tokens delivered within deadline) and per-miss phase attribution:

    PYTHONPATH=src python -m repro.launch.serve --arch llama2_7b \
        --workload 'process=poisson,rate=20,requests=16' \
        --slo ttft=500,tpot=50 --slo-json /tmp/slo.json
"""
from __future__ import annotations

import argparse
import hashlib
import json
import time
from typing import List

import jax
import numpy as np

from repro.configs.registry import get_config, list_draft_profiles
from repro.core.gqs_layer import GQSAConfig
from repro.core.model_compress import (compress_draft, compress_params,
                                       compress_params_w4, draft_layers)
from repro.core.pruning import PruneConfig
from repro.core.quant import QuantConfig
from repro.engine import (ChaosConfig, EngineConfig, InferenceEngine,
                          ResilienceConfig, SamplingParams, Telemetry)
from repro.engine.loadgen import SLO, SLOLedger, generate, make_source
from repro.engine.loadgen import WorkloadSpec
from repro.models.registry import get_model


def make_requests(n, vocab, rng, lo=4, hi=16):
    lens = rng.integers(lo, hi, size=n)
    return [rng.integers(0, vocab, size=l).astype(np.int32) for l in lens]


def compressed_params(cfg, args, rng, fp_params=None):
    api = get_model(cfg)
    params = api.init_params(rng, cfg) if fp_params is None else fp_params
    t0 = time.time()
    if args.compress == "gqsa":
        gqsa = GQSAConfig(
            quant=QuantConfig(bits=4, group_size=args.group_size),
            prune=PruneConfig(sparsity=args.sparsity,
                              group_size=args.group_size))
        params = compress_params(params, cfg, gqsa)
        print(f"packed GQSA W4 S{int(args.sparsity*100)}% "
              f"G{args.group_size} in {time.time()-t0:.1f}s")
    elif args.compress == "w4":
        params = compress_params_w4(params, cfg, QuantConfig(
            bits=4, group_size=args.group_size))
        print(f"packed W4 in {time.time()-t0:.1f}s")
    return params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2_7b")
    # reduced is the default: this CLI's job is exercising the serving
    # stack, which the reduced configs do at a fraction of the cost
    # (--full restores full-scale params for real measurements)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false",
                    help="full-scale params (default: reduced config)")
    ap.add_argument("--compress", default="gqsa",
                    choices=["none", "w4", "gqsa"])
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--group-size", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=None,
                    help="KV page pool size (default: slots*max_seq worth)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="shared-prefix KV reuse (DESIGN.md §13): radix "
                         "cache of full-page prompt blocks; admissions "
                         "map cached prefixes to existing pages "
                         "(refcounted, copy-on-write) and prefill only "
                         "the unshared tail — lossless for greedy")
    ap.add_argument("--chunked-prefill", type=int, default=0, metavar="N",
                    help="Sarathi-style chunked prefill (DESIGN.md §14): "
                         "split admitted prompts into N-token chunks fed "
                         "between decode steps instead of one monolithic "
                         "prefill — bounds decode-latency interference; "
                         "greedy output is bit-identical (0 = off)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--spec", type=int, default=0, metavar="K",
                    help="speculative decoding: draft K tokens per round "
                         "(0 = off); lossless — output matches non-spec")
    ap.add_argument("--spec-tree", default=None, metavar="F1,F2,..",
                    help="token-TREE drafting: top-k fanout per draft "
                         "depth (e.g. 4,2,2 = 28 nodes / depth 3); one "
                         "tree-attention verify call per round; implies "
                         "--spec; lossless like the chain")
    ap.add_argument("--spec-adaptive", action="store_true",
                    help="retune the tree online from the observed "
                         "acceptance rate (per-slot EWMA: thrash shrinks "
                         "to a chain K=1, sustained acceptance widens "
                         "back to the full --spec-tree profile)")
    ap.add_argument("--draft-profile", default="w4s75",
                    choices=list_draft_profiles(),
                    help="draft compression of the same checkpoint")
    ap.add_argument("--use-pallas", action="store_true",
                    help="Pallas kernel path (interpret off-TPU): packed "
                         "linears AND the fused paged-attention decode "
                         "kernel (attends in place on the KV pool; the "
                         "jnp reference gathers pages densely)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record phase spans + per-request flow events "
                         "and export Chrome trace-event JSON (load at "
                         "ui.perfetto.dev); also prints the phase "
                         "breakdown after the run")
    ap.add_argument("--stats-interval", type=float, default=0.0,
                    metavar="SEC",
                    help="print a one-line engine stats snapshot every "
                         "SEC seconds of serving (0 = off)")
    ap.add_argument("--workload", default=None, metavar="SPEC",
                    help="load-conditioned serving: a workload-spec JSON "
                         "file or inline k=v list ('process=poisson,"
                         "rate=20,requests=16,prompt=4:12,max_new=8'); "
                         "requests arrive mid-run through timed "
                         "admission instead of all up front (overrides "
                         "--requests/--max-new)")
    ap.add_argument("--slo", default=None, metavar="DEADLINES",
                    help="judge every request against deadlines (ms): "
                         "'ttft=500,tpot=50,e2e=2000' (any subset; also "
                         "'stall=50' — worst single prefill stall in the "
                         "decode window, needs --trace); prints "
                         "attainment + goodput + per-miss phase "
                         "attribution after the run")
    ap.add_argument("--slo-json", default=None, metavar="OUT.json",
                    help="also write the SLO ledger (summary + "
                         "per-request verdicts) as JSON")
    ap.add_argument("--deadline", type=float, default=None, metavar="MS",
                    help="per-request TTFT deadline (ms from arrival): "
                         "queued requests past it are SHED before "
                         "prefill instead of served late (first-class "
                         "SLO verdicts, DESIGN.md §12)")
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="deterministic fault injection: k=v rates per "
                         "injection-point visit ('alloc_fail=0.05,"
                         "latency=0.02,device_err=0.01,nan_logits=0.01' "
                         "— any subset, plus latency_spike_ms/retries/"
                         "backoff_ms/quarantine knobs), seeded by "
                         "--seed; same seed + same spec replays "
                         "bit-identically (offline mode)")
    args = ap.parse_args(argv)

    workload_spec = None
    if args.workload is not None:
        try:
            workload_spec = WorkloadSpec.parse(args.workload)
        except (ValueError, OSError) as e:
            ap.error(f"--workload: {e}")
    slo = None
    if args.slo is not None:
        try:
            slo = SLO.parse(args.slo)
        except ValueError as e:
            ap.error(f"--slo: {e}")
    if args.slo_json and slo is None:
        ap.error("--slo-json requires --slo")
    chaos = None
    if args.chaos is not None:
        try:
            chaos = ChaosConfig.parse(args.chaos, seed=args.seed)
        except ValueError as e:
            ap.error(f"--chaos: {e}")
    resilience = ResilienceConfig(deadline_ttft_ms=args.deadline,
                                  chaos=chaos)

    spec_fanout = None
    if args.spec_tree:
        try:
            spec_fanout = tuple(int(f) for f in args.spec_tree.split(","))
        except ValueError:
            ap.error(f"--spec-tree wants a comma list of fanouts, "
                     f"got {args.spec_tree!r}")
    spec_on = args.spec > 0 or spec_fanout is not None
    if args.spec_adaptive and spec_fanout is None:
        ap.error("--spec-adaptive requires --spec-tree")

    cfg = get_config(args.arch, reduced=args.reduced)
    # fail early, before any params are built: the engine needs the
    # family's paged cache + batched prefill (registry capability flag)
    if not get_model(cfg).supports_paged_cache:
        from repro.models.registry import paged_families
        ap.error(f"--arch {args.arch}: family {cfg.family!r} has no "
                 f"paged-cache support "
                 f"(supported: {', '.join(paged_families())})")
    rng = jax.random.PRNGKey(args.seed)
    # the FP tree is only needed as the shared source of target + draft
    # compression; don't keep a full-scale checkpoint alive otherwise
    fp_params = get_model(cfg).init_params(rng, cfg) if spec_on else None
    params = compressed_params(cfg, args, rng, fp_params=fp_params)
    draft_params = None
    dlayers = None
    if spec_on:
        t0 = time.time()
        draft_params = compress_draft(fp_params, cfg,
                                      profile=args.draft_profile,
                                      group_size=args.group_size)
        dlayers = draft_layers(cfg, args.draft_profile)
        print(f"packed draft profile {args.draft_profile} "
              f"({dlayers}/{cfg.n_layers} layers) in {time.time()-t0:.1f}s")
        if spec_fanout is not None:
            print(f"token-tree drafting: fanout {spec_fanout}"
                  + (" (adaptive)" if args.spec_adaptive else ""))
        fp_params = None                 # free the FP tree before serving

    telemetry = Telemetry(trace=args.trace is not None,
                          stats_interval_s=args.stats_interval)
    engine = InferenceEngine(
        cfg, params,
        EngineConfig(num_slots=args.slots, max_seq=args.max_seq,
                     page_size=args.page_size, num_pages=args.num_pages,
                     prefix_cache=args.prefix_cache,
                     prefill_chunk_tokens=args.chunked_prefill,
                     use_pallas=args.use_pallas, seed=args.seed,
                     spec_k=args.spec, spec_draft_layers=dlayers,
                     spec_fanout=spec_fanout,
                     spec_adaptive=args.spec_adaptive,
                     resilience=resilience),
        SamplingParams(temperature=args.temperature, top_k=args.top_k,
                       top_p=args.top_p),
        draft_params=draft_params, telemetry=telemetry)

    if workload_spec is not None:
        # every stream request must fit: worst-case prompt + budget
        worst = workload_spec.prompt_max + workload_spec.max_new_max
        if worst > args.max_seq:
            ap.error(f"--workload: prompt_max + max_new_max = {worst} "
                     f"exceeds --max-seq {args.max_seq}")
        workload = generate(workload_spec, cfg.vocab)
        rate = workload.offered_rate
        print(f"workload: {workload_spec.process}, "
              f"{workload_spec.requests} requests"
              + (f", offered {rate:.1f} req/s" if rate is not None
                 else f", {workload_spec.concurrency} users closed-loop"))
        out = engine.run(source=make_source(workload))
    else:
        nprng = np.random.default_rng(args.seed)
        # prompts must leave room for the generation budget within max_seq
        maxlen = args.max_seq - args.max_new
        if maxlen < 1:
            ap.error(f"--max-new {args.max_new} leaves no prompt room "
                     f"within --max-seq {args.max_seq}")
        lo = min(4, maxlen)
        hi = max(lo + 1, min(16, maxlen + 1))
        prompts: List[np.ndarray] = make_requests(args.requests, cfg.vocab,
                                                  nprng, lo=lo, hi=hi)
        for p in prompts:
            engine.submit(p, args.max_new)
        out = engine.run()

    m = out["metrics"]
    print(engine.metrics.format_summary()
          + f" ({args.slots} slots, {m['decode_steps']} decode steps)")
    if out.get("interrupted"):
        print("[interrupted] graceful drain: queue shed, in-flight "
              "requests accounted, all pages freed")
    # results digest: sha256 over (rid, tokens) in rid order — the replay
    # pin the CI chaos smoke compares across two identically-seeded runs
    h = hashlib.sha256()
    for r in sorted(out["results"], key=lambda d: d["rid"]):
        h.update(np.int64(r["rid"]).tobytes())
        h.update(np.asarray(r["tokens"], np.int32).tobytes())
    print(f"[digest] {h.hexdigest()}")
    if args.prefix_cache:
        reg = telemetry.registry
        print("[prefix] hits="
              f"{int(reg.counter('prefix.hits').value)} "
              f"misses={int(reg.counter('prefix.misses').value)} "
              f"hit_tokens={int(reg.counter('prefix.hit_tokens').value)} "
              f"cow={int(reg.counter('prefix.cow_copies').value)} "
              f"evicted={int(reg.counter('prefix.evicted_pages').value)} "
              f"cached={int(reg.gauge('prefix.cached_pages').value)}")
    if engine.chaos is not None:
        snap = engine.chaos.snapshot()
        retries = int(telemetry.registry.counter(
            "chaos.device_retries").value)
        print("[chaos] injected "
              + " ".join(f"{k}={v}" for k, v in sorted(snap.items()))
              + f" device_retries={retries} | recovered: "
              f"{int(m['preemptions'])} preemptions, "
              f"{int(m['shed'])} shed")
    slo_summary = None
    if slo is not None:
        ledger = SLOLedger(slo, registry=telemetry.registry)
        verdicts = ledger.judge(engine.metrics, telemetry.tracer)
        slo_summary = ledger.summary()
        print(ledger.format_summary())
        if args.slo_json:
            doc = {"slo": {d: slo.limit(d) for d in ("ttft", "tpot", "e2e")
                           if slo.limit(d) is not None},
                   "summary": slo_summary,
                   "requests": [{"rid": v.rid, "met": v.met,
                                 "verdict": v.verdict,
                                 "n_tokens": v.n_tokens,
                                 "ttft_ms": round(v.ttft_ms, 3),
                                 # single-token requests have no TPOT
                                 "tpot_ms": (None if v.tpot_ms != v.tpot_ms
                                             else round(v.tpot_ms, 3)),
                                 "e2e_ms": round(v.e2e_ms, 3),
                                 "queue_wait_ms": round(v.queue_wait_ms, 3),
                                 "misses": v.misses} for v in verdicts]}
            with open(args.slo_json, "w") as f:
                json.dump(doc, f, indent=2)
            print(f"wrote SLO ledger -> {args.slo_json}")
    if args.trace is not None:
        path = telemetry.tracer.export(args.trace)
        totals = telemetry.tracer.phase_totals()
        print(f"wrote trace ({len(telemetry.tracer.events)} events) -> "
              f"{path} (load at ui.perfetto.dev)")
        for name, d in sorted(totals.items(), key=lambda kv: -kv[1]["ms"]):
            print(f"  {name:16s} {d['ms']:9.2f}ms  x{d['count']}")
    # legacy result keys (kept stable for tests + examples)
    res = dict(m, requests=int(m["requests"]), tokens=int(m["tokens"]),
               results=out["results"])
    if slo_summary is not None:
        res["slo"] = slo_summary
    return res


if __name__ == "__main__":
    main()
