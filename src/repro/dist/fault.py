"""Fault tolerance for the training loop: straggler watchdog + retrying
step wrapper. Both are host-side and framework-free."""
from __future__ import annotations

import functools
import time
from typing import Callable, Optional


class StepWatchdog:
    """EMA step-time tracker that flags stragglers.

    A step slower than ``threshold * ema`` (after ``warmup_steps``
    observations) is counted as a straggler and does NOT pollute the EMA,
    so one slow host can't mask the next."""

    def __init__(self, warmup_steps: int = 5, threshold: float = 3.0,
                 decay: float = 0.9):
        self.warmup_steps = warmup_steps
        self.threshold = threshold
        self.decay = decay
        self.ema: Optional[float] = None
        self.steps = 0
        self.stragglers = 0

    def observe(self, dt: float) -> bool:
        self.steps += 1
        if self.ema is not None and self.steps > self.warmup_steps \
                and dt > self.threshold * self.ema:
            self.stragglers += 1
            return True
        self.ema = dt if self.ema is None \
            else self.decay * self.ema + (1 - self.decay) * dt
        return False


class TrainerHealth:
    """Aggregated view for log lines / health endpoints."""

    def __init__(self, watchdog: StepWatchdog):
        self.watchdog = watchdog
        self.started = time.time()

    def as_dict(self) -> dict:
        w = self.watchdog
        return {"steps": w.steps, "stragglers": w.stragglers,
                "ema_s": round(w.ema, 4) if w.ema is not None else None}


def retrying(fn: Callable, max_retries: int = 3,
             backoff_s: float = 0.0) -> Callable:
    """Retry transient failures (preempted host, flaky link): up to
    ``max_retries`` total attempts, re-raising the last error."""

    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        for attempt in range(max_retries):
            try:
                return fn(*args, **kwargs)
            except Exception:
                if attempt == max_retries - 1:
                    raise
                if backoff_s:
                    time.sleep(backoff_s * (2 ** attempt))
    return wrapped
