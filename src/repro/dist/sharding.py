"""DistContext + parameter sharding rules (TP / FSDP over a named mesh).

The context is a thin, picklable description of how this process wants
tensors laid out; model code only calls :meth:`constrain`,
:meth:`batch_spec` and :meth:`axis_size`, so a ``mesh=None`` context is a
valid single-device no-op.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class DistContext:
    mesh: Optional[object] = None          # jax.sharding.Mesh or None
    batch_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    fsdp: bool = False                     # ZeRO-3 param sharding over data
    seq_axis: Optional[str] = None         # sequence-sharded KV (long ctx)
    sp_attention: bool = False             # sequence-parallel attention

    @property
    def fsdp_axis(self) -> Optional[str]:
        return self.batch_axes[-1] if self.fsdp else None

    def axis_size(self, name: str) -> int:
        if self.mesh is None or name is None:
            return 1
        return int(self.mesh.shape.get(name, 1))

    def batch_spec(self, ndim: int) -> P:
        """Batch on dim 0, replicated elsewhere."""
        return P(self.batch_axes, *([None] * (ndim - 1)))

    def constrain(self, x, spec):
        if self.mesh is None or spec is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


def _leaf_spec(leaf, dist: DistContext) -> P:
    """TP rule: shard the widest model-axis-divisible trailing dim; under
    FSDP additionally shard one other dim over the data axis (ZeRO-3).
    Stacked-layer leaves carry a leading [L] dim that stays replicated."""
    shape = getattr(leaf, "shape", ())
    ndim = len(shape)
    spec = [None] * ndim
    mp = dist.axis_size(dist.model_axis)
    tp_dim = None
    if mp > 1 and ndim >= 1:
        # prefer the LAST eligible dim (the contraction/feature dim), so
        # e.g. [L, N, K] shards K and stacked-layer dims stay whole
        for i in range(ndim - 1, 0, -1):
            if shape[i] % mp == 0 and shape[i] >= mp:
                spec[i] = dist.model_axis
                tp_dim = i
                break
    if dist.fsdp:
        dp = dist.fsdp_axis
        dsz = dist.axis_size(dp)
        if dsz > 1:
            for i in range(ndim - 1, 0, -1):
                if i != tp_dim and shape[i] % dsz == 0 and shape[i] >= dsz:
                    spec[i] = dp
                    break
    return P(*spec)


def param_shardings(params, dist: DistContext):
    """Tree of NamedShardings (or None when there is no mesh)."""
    if dist is None or dist.mesh is None:
        return None
    return jax.tree_util.tree_map(
        lambda l: NamedSharding(dist.mesh, _leaf_spec(l, dist)), params)
