"""Custom collectives: int8 error-feedback gradient all-reduce and
sequence-sharded decode attention (distributed flash-decoding).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# int8 error-feedback psum (EF-SGD)
# ---------------------------------------------------------------------------

def compressed_psum_leaf(g: jnp.ndarray, err: jnp.ndarray, axis: str):
    """One leaf inside shard_map: returns (mean over axis, new error).

    (g + err) is quantized to int8 with a pmax-shared per-tensor scale,
    psum'd exactly in int32, dequantized; the local quantization residual
    becomes the next step's error feedback."""
    gf = g.astype(jnp.float32) + err
    amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    mean = jax.lax.psum(q.astype(jnp.int32), axis).astype(jnp.float32) \
        * scale / n
    return mean, gf - deq


def compressed_psum(grads, err_state, axis: str):
    """Tree version: returns (mean grads, new error state)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(err_state)
    out = [compressed_psum_leaf(g, e, axis)
           for g, e in zip(flat_g, flat_e)]
    return (jax.tree_util.tree_unflatten(treedef, [o[0] for o in out]),
            jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]))


# ---------------------------------------------------------------------------
# sequence-sharded decode (long-context: KV cache sharded over 'data')
# ---------------------------------------------------------------------------

def update_sharded_cache(cache: jnp.ndarray, new: jnp.ndarray,
                         pos, mesh, axis: str) -> jnp.ndarray:
    """Write ``new`` [B, 1, KH, D] at sequence position ``pos`` of a cache
    [B, S, KH, D] sharded over ``axis`` on the S dim. Only the owning
    shard writes; others pass their slice through."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    s = cache.shape[1]
    s_loc = s // int(mesh.shape[axis])

    def local(c, nw, p):
        start = jax.lax.axis_index(axis) * s_loc
        off = jnp.clip(p - start, 0, s_loc - 1)
        upd = jax.lax.dynamic_update_slice(
            c, nw.astype(c.dtype), (0, off, 0, 0))
        mine = (p >= start) & (p < start + s_loc)
        return jnp.where(mine, upd, c)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(None, axis), P(), P()),
                     out_specs=P(None, axis), check_rep=False)(
                         cache, new, jnp.asarray(pos, jnp.int32))


def sharded_decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                             v_cache: jnp.ndarray, length, mesh,
                             axis: str) -> jnp.ndarray:
    """Flash-decoding over a sequence-sharded KV cache: each shard computes
    partial (max, exp-sum, weighted values) over its local keys; pmax/psum
    combine to the exact softmax. q: [B, 1, H, D]; caches: [B, S, KH, D]."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    b, s, kh, d = k_cache.shape
    h = q.shape[2]
    r = h // kh
    nsh = int(mesh.shape[axis])
    s_loc = s // nsh
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    def local(qq, kl, vl, ln):
        start = jax.lax.axis_index(axis) * s_loc
        qh = qq.reshape(b, kh, r, d).astype(jnp.float32)
        sco = jnp.einsum("bkrd,bskd->bkrs", qh,
                         kl.astype(jnp.float32)) * scale
        pos = start + jnp.arange(s_loc)
        valid = pos[None, :] < jnp.reshape(ln, (-1, 1))
        sco = jnp.where(valid[:, None, None, :], sco, -jnp.inf)
        m = jax.lax.pmax(jnp.max(sco, axis=-1), axis)
        msafe = jnp.where(jnp.isinf(m), 0.0, m)
        p = jnp.where(jnp.isinf(sco), 0.0, jnp.exp(sco - msafe[..., None]))
        l = jax.lax.psum(jnp.sum(p, axis=-1), axis)
        o = jax.lax.psum(
            jnp.einsum("bkrs,bskd->bkrd", p, vl.astype(jnp.float32)), axis)
        o = o / jnp.maximum(l, 1e-30)[..., None]
        return o.reshape(b, 1, h, d).astype(qq.dtype)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(), P(None, axis), P(None, axis), P()),
                     out_specs=P(), check_rep=False)(
                         q, k_cache, v_cache,
                         jnp.asarray(length, jnp.int32))
