"""Elastic mesh planning: build the largest usable mesh from the devices
that are actually alive, preserving tensor parallelism when possible.

A TPU "pod" is modeled as 256 chips; multi-pod plans add a leading 'pod'
axis so cross-pod traffic (DCN) is separable from in-pod ICI.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

POD_SIZE = 256


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def n_devices(self) -> int:
        return int(np.prod(self.shape))


def plan_mesh(n_devices: int, model_parallel: int = 1,
              multi_pod: bool = False) -> MeshPlan:
    mp = max(1, model_parallel)
    if n_devices % mp:
        raise ValueError(f"{n_devices} devices not divisible by "
                         f"model_parallel={mp}")
    if multi_pod and n_devices > POD_SIZE:
        if n_devices % POD_SIZE:
            raise ValueError(f"multi-pod plan needs a multiple of "
                             f"{POD_SIZE} devices, got {n_devices}")
        pods = n_devices // POD_SIZE
        return MeshPlan((pods, POD_SIZE // mp, mp), ("pod", "data", "model"))
    return MeshPlan((n_devices // mp, mp), ("data", "model"))


def degrade_after_failure(plan: MeshPlan, surviving: int) -> MeshPlan:
    """Largest plan that fits on ``surviving`` devices. The data axis
    shrinks first; TP degrades (halves) only when even data=1 won't fit."""
    mp = plan.shape[-1]
    while mp > 1 and surviving < mp:
        mp //= 2
    data = max(1, surviving // mp)
    return MeshPlan((data, mp), ("data", "model"))


def build_mesh(plan: MeshPlan):
    import jax
    from jax.sharding import Mesh
    devs = np.asarray(jax.devices()[:plan.n_devices]).reshape(plan.shape)
    return Mesh(devs, plan.axes)
