"""Distribution utilities: mesh planning, sharding rules, collectives,
fault tolerance. ``DistContext`` is the single handle model code receives."""
