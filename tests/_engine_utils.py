"""Shared engine-test harness (tests only, not part of the package).

Consolidates the poll-scripted-arrival source and the prompt builders
that the resilience, prefix-cache and chunked-prefill suites all need:
deterministic mid-run arrivals let a test inject a request at an exact
scheduling boundary (e.g. while a victim is decoding, or mid-chunk), so
preemption paths replay bit-identically without wall-clock sleeps.
"""
import numpy as np

from repro.engine.loadgen import ArrivalSource, GeneratedRequest


class ScriptedSource(ArrivalSource):
    """Poll-count-scheduled arrivals: request i is delivered at the
    engine's N-th poll of the source, independent of wall clock — the
    engine polls once per scheduling boundary, so mid-run arrivals land
    at deterministic boundaries and preemption tests replay exactly."""

    def __init__(self, schedule):
        # schedule: [(poll_index, prompt, max_new, priority), ...]
        self._sched = sorted(schedule, key=lambda s: s[0])
        self._polls = 0
        self._i = 0

    def due(self, now_s):
        self._polls += 1
        out = []
        while (self._i < len(self._sched)
               and self._sched[self._i][0] <= self._polls):
            _, prompt, max_new, prio = self._sched[self._i]
            out.append(GeneratedRequest(
                idx=self._i, arrival_s=None, think_s=None,
                prompt=prompt, max_new=max_new, priority=prio))
            self._i += 1
        return out

    def next_at(self):
        return None

    @property
    def exhausted(self):
        return self._i >= len(self._sched)


def make_prompts(vocab, lens, seed=0):
    """Random prompts of the given lengths (one seeded stream)."""
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=l).astype(np.int32) for l in lens]


def shared_prompts(vocab, prefix_len, tail_lens, seed=0):
    """Prompts sharing one random prefix, with random tails of the given
    lengths (0 = the bare prefix: the page-aligned COW case)."""
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, vocab, size=prefix_len).astype(np.int32)
    return [np.concatenate([pre, rng.integers(0, vocab, size=n)
                            .astype(np.int32)]) for n in tail_lens]


def by_rid(res):
    """{rid: [tokens]} from an engine run() result dict."""
    return {r["rid"]: list(r["tokens"]) for r in res["results"]}
