"""Load-conditioned serving: workload determinism, arrival sources,
SLO-ledger math vs hand-computed verdicts, and the engine's timed
admission path (open- and closed-loop) with backdated arrivals."""
import dataclasses
import types

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.engine import EngineConfig, InferenceEngine, SamplingParams
from repro.engine.loadgen import (SLO, ClosedLoopSource, OpenLoopSource,
                                  SLOLedger, WorkloadSpec, generate,
                                  make_source)
from repro.engine.metrics import EngineMetrics, RequestTiming
from repro.engine.telemetry import MetricsRegistry
from repro.models.registry import get_model


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("llama2_7b", reduced=True)
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, api, params


# ---------------------------------------------------------------------------
# workload generation: determinism, distributions, prefix pools
# ---------------------------------------------------------------------------

def test_generate_bit_identical_for_equal_specs():
    spec = WorkloadSpec(process="poisson", rate=20.0, requests=32,
                        prompt_min=4, prompt_max=12, max_new_min=2,
                        max_new_max=8, prefix_pool=3, prefix_len=4,
                        prefix_share=0.5, seed=7)
    a = generate(spec, vocab=256)
    b = generate(spec, vocab=256)
    # and a JSON round trip of the spec regenerates the same stream
    c = generate(WorkloadSpec.from_json(spec.to_json()), vocab=256)
    for other in (b, c):
        assert len(other.requests) == len(a.requests)
        for ra, rb in zip(a.requests, other.requests):
            assert ra.arrival_s == rb.arrival_s
            assert ra.max_new == rb.max_new
            assert ra.template == rb.template
            np.testing.assert_array_equal(ra.prompt, rb.prompt)


def test_generate_seed_changes_stream():
    base = WorkloadSpec(requests=16, seed=0)
    a = generate(base, vocab=256)
    b = generate(dataclasses.replace(base, seed=1), vocab=256)
    assert any(ra.arrival_s != rb.arrival_s
               for ra, rb in zip(a.requests, b.requests))


def test_generate_respects_ranges_and_ordering():
    spec = WorkloadSpec(process="poisson", rate=50.0, requests=64,
                        prompt_min=3, prompt_max=9, max_new_min=2,
                        max_new_max=5, seed=11)
    wl = generate(spec, vocab=256)
    arrivals = [r.arrival_s for r in wl.requests]
    assert all(a > 0 for a in arrivals)
    assert arrivals == sorted(arrivals)
    for r in wl.requests:
        assert 3 <= len(r.prompt) <= 9
        assert 2 <= r.max_new <= 5
        assert r.prompt.dtype == np.int32
        assert r.prompt.min() >= 0 and r.prompt.max() < 256
    assert wl.offered_rate == pytest.approx(64 / arrivals[-1])


def test_prefix_pool_shares_templates():
    spec = WorkloadSpec(requests=24, prompt_min=6, prompt_max=10,
                        prefix_pool=2, prefix_len=4, prefix_share=1.0,
                        seed=3)
    wl = generate(spec, vocab=256)
    by_template = {}
    for r in wl.requests:
        assert r.template in (0, 1)
        by_template.setdefault(r.template, []).append(r.prompt[:4])
    assert set(by_template) == {0, 1}
    for group in by_template.values():
        for p in group[1:]:
            np.testing.assert_array_equal(group[0], p)
    # the two templates differ (else "sharing" is vacuous)
    assert not np.array_equal(by_template[0][0], by_template[1][0])
    # share=0 disables templates entirely
    wl0 = generate(dataclasses.replace(spec, prefix_share=0.0), vocab=256)
    assert all(r.template is None for r in wl0.requests)


def test_bursty_matches_mean_rate_but_clusters():
    rate, n = 8.0, 2000
    pois = generate(WorkloadSpec(process="poisson", rate=rate, requests=n,
                                 seed=5), vocab=16)
    burst = generate(WorkloadSpec(process="bursty", rate=rate,
                                  burstiness=0.25, requests=n, seed=5),
                     vocab=16)
    for wl in (pois, burst):
        gaps = np.diff([0.0] + [r.arrival_s for r in wl.requests])
        assert np.mean(gaps) == pytest.approx(1.0 / rate, rel=0.15)
    bgaps = np.diff([0.0] + [r.arrival_s for r in burst.requests])
    pgaps = np.diff([0.0] + [r.arrival_s for r in pois.requests])
    # gamma shape 0.25 -> CV 2; poisson -> CV 1
    assert np.std(bgaps) / np.mean(bgaps) > \
        1.3 * np.std(pgaps) / np.mean(pgaps)


def test_spec_validation_rejects_bad_fields():
    with pytest.raises(ValueError):
        WorkloadSpec(process="uniform")
    with pytest.raises(ValueError):
        WorkloadSpec(requests=0)
    with pytest.raises(ValueError):
        WorkloadSpec(prompt_min=8, prompt_max=4)
    with pytest.raises(ValueError):
        WorkloadSpec(prefix_share=0.5)          # needs pool + len
    with pytest.raises(ValueError):
        WorkloadSpec(prefix_pool=1, prefix_len=8, prefix_share=0.5,
                     prompt_min=4)              # prefix longer than prompt


def test_spec_parse_inline_and_file(tmp_path):
    spec = WorkloadSpec.parse(
        "process=bursty,rate=20,burstiness=0.5,requests=4,"
        "prompt=4:12,max_new=6,seed=3")
    assert spec == WorkloadSpec(process="bursty", rate=20.0,
                                burstiness=0.5, requests=4, prompt_min=4,
                                prompt_max=12, max_new_min=6,
                                max_new_max=6, seed=3)
    path = tmp_path / "wl.json"
    path.write_text(spec.to_json())
    assert WorkloadSpec.parse(str(path)) == spec
    with pytest.raises(ValueError):
        WorkloadSpec.parse("rate=20,bogus_key=1")
    with pytest.raises(ValueError):
        WorkloadSpec.parse("just-a-word")


# ---------------------------------------------------------------------------
# arrival sources
# ---------------------------------------------------------------------------

def test_open_loop_source_releases_by_clock():
    wl = generate(WorkloadSpec(process="poisson", rate=10.0, requests=6,
                               seed=2), vocab=16)
    src = make_source(wl)
    assert isinstance(src, OpenLoopSource)
    times = [r.arrival_s for r in wl.requests]
    assert src.due(times[1] + 1e-9) == wl.requests[:2]
    assert src.due(times[1]) == []              # already drained
    assert src.next_at() == times[2]
    assert not src.exhausted
    assert src.due(times[-1] + 1.0) == wl.requests[2:]
    assert src.exhausted and src.next_at() is None


def test_closed_loop_source_population_feedback():
    wl = generate(WorkloadSpec(process="closed", concurrency=2,
                               think_s=0.5, requests=5, seed=4), vocab=16)
    src = make_source(wl)
    assert isinstance(src, ClosedLoopSource)
    first = src.due(0.0)
    assert [r.idx for r in first] == [0, 1]     # population primed at t=0
    assert all(r.arrival_s == 0.0 for r in first)
    assert src.due(100.0) == []                 # nothing until a finish
    src.on_finish(1.0)                          # user slot frees at t=1
    nxt = src.next_at()
    assert nxt == pytest.approx(1.0 + wl.requests[2].think_s)
    assert src.due(nxt - 1e-6) == []
    got = src.due(nxt)
    assert [r.idx for r in got] == [2]
    assert got[0].arrival_s == pytest.approx(nxt)   # realized stamp
    src.on_finish(2.0)
    src.on_finish(2.0)
    src.due(100.0)
    src.on_finish(3.0)                          # stream spent: no-op
    assert src.due(100.0) == [] and src.exhausted


def test_source_type_mismatch_rejected():
    open_wl = generate(WorkloadSpec(requests=2), vocab=8)
    closed_wl = generate(WorkloadSpec(process="closed", requests=2),
                         vocab=8)
    with pytest.raises(ValueError):
        ClosedLoopSource(open_wl)
    with pytest.raises(ValueError):
        OpenLoopSource(closed_wl)


# ---------------------------------------------------------------------------
# SLO ledger vs hand-computed verdicts
# ---------------------------------------------------------------------------

def _metrics(rows):
    """rows: rid -> (enqueue, admit, first_token, finish, n_generated),
    seconds on a synthetic clock starting at 0."""
    m = EngineMetrics()
    m.start_t, m.end_t = 0.0, 10.0
    for rid, row in rows.items():
        enq, adm, first, fin, n = row
        m.requests[rid] = RequestTiming(enqueue_t=enq, admit_t=adm,
                                        first_token_t=first, finish_t=fin,
                                        n_generated=n)
    return m


def test_ledger_matches_hand_computed_attainment_and_goodput():
    m = _metrics({
        # met: ttft 100ms, tpot 100ms, e2e 1100ms, 11 tokens
        0: (0.0, 0.05, 0.1, 1.1, 11),
        # ttft 600ms miss; queue 500ms >= prefill 100ms -> queue_wait
        1: (0.0, 0.5, 0.6, 1.6, 11),
        # ttft 500ms miss via prefill (queue 10ms); tpot 500ms miss,
        # no trace -> decode_segment
        2: (0.0, 0.01, 0.5, 1.0, 2),
        # unfinished: never judged
        3: (0.0, 0.0, 0.0, 0.0, 0),
    })
    reg = MetricsRegistry()
    ledger = SLOLedger(SLO(ttft_ms=200.0, tpot_ms=150.0, e2e_ms=2000.0),
                       registry=reg)
    verdicts = {v.rid: v for v in ledger.judge(m)}
    assert set(verdicts) == {0, 1, 2}
    assert verdicts[0].met and not verdicts[0].misses
    assert verdicts[1].misses == {"ttft": "queue_wait"}
    assert verdicts[2].misses == {"ttft": "prefill",
                                  "tpot": "decode_segment"}

    s = ledger.summary()
    assert s["requests"] == 3 and s["met"] == 1
    assert s["attainment"] == pytest.approx(1 / 3)
    assert s["tokens"] == 24 and s["goodput_tokens"] == 11
    assert s["tok_per_s"] == pytest.approx(24 / 10.0)
    assert s["goodput_tok_per_s"] == pytest.approx(11 / 10.0)
    assert s["missed_ttft"] == 2 and s["missed_tpot"] == 1
    assert s["missed_e2e"] == 0
    assert s["miss_phase_queue_wait"] == 1
    assert s["miss_phase_prefill"] == 1
    assert s["miss_phase_decode_segment"] == 1
    # ledger publishes into the shared registry
    snap = reg.snapshot()
    assert snap["slo.requests_met"] == 1
    assert snap["slo.requests_missed"] == 2
    assert snap["slo.goodput_tokens"] == 11

    line = ledger.format_summary()
    assert "attainment 33.3% (1/3)" in line
    assert "goodput 1.1 tok/s (11/24 tokens in SLO)" in line
    assert "ttft 2" in line and "queue_wait 1" in line


def test_ledger_tpot_miss_attributed_to_prefill_interference():
    # decode window [0.5s, 1.0s]; tpot 500ms vs 150ms limit -> overshoot
    # 350ms; a concurrent 400ms prefill span covers it -> interference
    m = _metrics({0: (0.0, 0.01, 0.5, 1.0, 2)})
    tracer = types.SimpleNamespace(
        enabled=True, origin=0.0,
        events=[{"ph": "X", "name": "prefill",
                 "ts": 550_000.0, "dur": 400_000.0}])
    ledger = SLOLedger(SLO(tpot_ms=150.0))
    v, = ledger.judge(m, tracer)
    assert v.misses == {"tpot": "prefill"}
    # same run, trace off: the span evidence is unavailable
    ledger2 = SLOLedger(SLO(tpot_ms=150.0))
    v2, = ledger2.judge(m, None)
    assert v2.misses == {"tpot": "decode_segment"}


def test_ledger_e2e_miss_attributed_to_largest_phase():
    # queue 0.1s, prefill 0.2s (admit->first), decode 3.0s
    m = _metrics({0: (0.0, 0.1, 0.3, 3.3, 4)})
    ledger = SLOLedger(SLO(e2e_ms=1000.0))
    v, = ledger.judge(m)
    assert v.misses == {"e2e": "decode_segment"}


def test_slo_parse():
    slo = SLO.parse("ttft=200,tpot=25,e2e=2000")
    assert (slo.ttft_ms, slo.tpot_ms, slo.e2e_ms) == (200.0, 25.0, 2000.0)
    assert SLO.parse("ttft=200").tpot_ms is None
    with pytest.raises(ValueError):
        SLO.parse("latency=5")
    with pytest.raises(ValueError):
        SLO.parse("")


# ---------------------------------------------------------------------------
# timed admission through the real engine
# ---------------------------------------------------------------------------

def test_open_loop_engine_run_backdates_arrivals(tiny):
    cfg, api, params = tiny
    spec = WorkloadSpec(process="poisson", rate=100.0, requests=5,
                        prompt_min=3, prompt_max=6, max_new_min=3,
                        max_new_max=3, seed=0)
    wl = generate(spec, cfg.vocab)
    eng = InferenceEngine(cfg, params,
                          EngineConfig(num_slots=2, max_seq=32),
                          SamplingParams())
    out = eng.run(source=make_source(wl))
    m = eng.metrics
    assert out["metrics"]["requests"] == 5
    assert len(out["results"]) == 5
    # submits happen in arrival order, so rid i is workload request i:
    # every enqueue is backdated to exactly t0 + generated arrival
    for i, g in enumerate(wl.requests):
        rt = m.requests[i]
        assert rt.finish_t > 0.0
        assert rt.enqueue_t == pytest.approx(m.start_t + g.arrival_s,
                                             abs=1e-9)
        assert rt.admit_t >= rt.enqueue_t
    # a generous SLO judges the whole run attained
    ledger = SLOLedger(SLO.parse("ttft=60000,e2e=120000"))
    ledger.judge(m)
    s = ledger.summary()
    assert s["attainment"] == 1.0
    assert s["goodput_tokens"] == s["tokens"] == out["metrics"]["tokens"]


def test_closed_loop_engine_run_completes_population(tiny):
    cfg, api, params = tiny
    spec = WorkloadSpec(process="closed", concurrency=2, think_s=0.0,
                        requests=4, prompt_min=3, prompt_max=5,
                        max_new_min=2, max_new_max=2, seed=1)
    wl = generate(spec, cfg.vocab)
    eng = InferenceEngine(cfg, params,
                          EngineConfig(num_slots=2, max_seq=32),
                          SamplingParams())
    out = eng.run(source=make_source(wl))
    assert out["metrics"]["requests"] == 4
    # realized arrivals were stamped at run time, later users later
    arrivals = [r.arrival_s for r in wl.requests]
    assert all(a is not None for a in arrivals)
    assert arrivals[:2] == [0.0, 0.0]
    assert arrivals[2] > 0.0 and arrivals[3] >= arrivals[2]
