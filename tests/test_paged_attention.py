"""Fused paged-attention decode kernel vs the jnp dense-gather oracle
(interpret mode, per the repo's off-TPU kernel convention): T=1 decode and
T=K+1 staircase verify, bf16/f32 and int8+scales pages, ragged lengths,
GQA ratios, OOB-sentinel block tables, and the occupied-page clamp."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # pragma: no cover
    from _hyp import given, settings, st

from repro.kernels import ops, ref as kref
from repro.models.layers import quantize_kv, staircase_mask


def _case(seed, b, t, kh, r, d, ps, mp, num_pages, int8=False,
          dtype=jnp.float32):
    """Random paged-attention instance. Block tables hold each slot's
    occupied prefix of distinct pages followed by OOB sentinels; lengths
    are a ragged per-slot staircase inside the occupied span."""
    assert num_pages >= b * mp
    g = np.random.default_rng(seed)
    q = jnp.asarray(g.normal(size=(b, t, kh * r, d)), dtype)
    kp = jnp.asarray(g.normal(size=(num_pages, ps, kh, d)), dtype)
    vp = jnp.asarray(g.normal(size=(num_pages, ps, kh, d)), dtype)
    pages = g.permutation(num_pages)[:b * mp].reshape(b, mp).astype(np.int32)
    occ = g.integers(1, mp + 1, size=b)                  # ragged occupancy
    bt = np.where(np.arange(mp)[None, :] < occ[:, None], pages,
                  num_pages)                             # sentinel tail
    lengths = np.sort(np.stack(
        [g.integers(1, occ[i] * ps + 1, size=t) for i in range(b)]), axis=1)
    ksc = vsc = None
    if int8:
        kp, ksc = quantize_kv(kp.astype(jnp.float32))
        vp, vsc = quantize_kv(vp.astype(jnp.float32))
    return (q, kp, vp, jnp.asarray(lengths.astype(np.int32)),
            jnp.asarray(bt), ksc, vsc)


def _run_both(case):
    q, kp, vp, lengths, bt, ksc, vsc = case
    o_ref = kref.paged_attention_ref(q, kp, vp, lengths, bt, ksc, vsc)
    o_ker = ops.paged_decode_attention(q, kp, vp, lengths, bt, ksc, vsc,
                                       use_pallas=True, interpret=True)
    return np.asarray(o_ref), np.asarray(o_ker)


# ---------------------------------------------------------------------------
# parity grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t", [1, 4])                 # decode / K+1 verify
@pytest.mark.parametrize("int8", [False, True])
@pytest.mark.parametrize("kh,r", [(2, 4), (1, 8), (4, 1)])   # GQA ratios
def test_paged_kernel_matches_reference(t, int8, kh, r):
    o_ref, o_ker = _run_both(
        _case(7 * t + int8, b=3, t=t, kh=kh, r=r, d=32, ps=8, mp=4,
              num_pages=16, int8=int8))
    np.testing.assert_allclose(o_ker, o_ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("ps,mp", [(4, 7), (16, 2), (32, 3)])
def test_paged_kernel_page_geometries(ps, mp):
    o_ref, o_ker = _run_both(
        _case(ps + mp, b=2, t=3, kh=2, r=2, d=64, ps=ps, mp=mp,
              num_pages=2 * mp + 3))
    np.testing.assert_allclose(o_ker, o_ref, rtol=1e-4, atol=1e-4)


def test_paged_kernel_bf16_pages():
    o_ref, o_ker = _run_both(
        _case(11, b=2, t=2, kh=2, r=2, d=32, ps=8, mp=4, num_pages=12,
              dtype=jnp.bfloat16))
    np.testing.assert_allclose(o_ker, o_ref, rtol=2e-2, atol=2e-2)


def test_paged_kernel_staircase_is_causal():
    """T > 1 semantics: each query row equals a separate T=1 call at its
    own length — the staircase is exactly per-query causal masking."""
    q, kp, vp, lengths, bt, _, _ = _case(23, b=2, t=3, kh=2, r=2, d=32,
                                         ps=8, mp=4, num_pages=16)
    o = ops.paged_decode_attention(q, kp, vp, lengths, bt,
                                   use_pallas=True, interpret=True)
    for tt in range(3):
        o1 = ops.paged_decode_attention(
            q[:, tt:tt + 1], kp, vp, lengths[:, tt:tt + 1], bt,
            use_pallas=True, interpret=True)
        np.testing.assert_allclose(np.asarray(o[:, tt]),
                                   np.asarray(o1[:, 0]),
                                   rtol=1e-5, atol=1e-5)


def test_paged_kernel_all_sentinel_slot_is_finite():
    """A slot whose table is ALL sentinels (inactive slot with a stale
    position) must produce finite output in both implementations (both
    read the same clamped page, masked identically)."""
    q, kp, vp, lengths, bt, _, _ = _case(31, b=2, t=1, kh=2, r=2, d=32,
                                         ps=8, mp=4, num_pages=16)
    bt = bt.at[1].set(kp.shape[0])                     # slot 1: no pages
    o_ref, o_ker = _run_both((q, kp, vp, lengths, bt, None, None))
    assert np.isfinite(o_ker).all() and np.isfinite(o_ref).all()
    np.testing.assert_allclose(o_ker, o_ref, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# length-invariance: padding pages can NEVER change the output
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 4), st.integers(1, 3),
       st.booleans())
def test_padding_pages_never_change_output(seed, t, mp_extra, int8):
    """Property: widening the block table with sentinel entries and
    rewriting the contents of every page the lengths never reach leaves
    the kernel output BIT-IDENTICAL (dead pages are skipped, not merely
    masked)."""
    g = np.random.default_rng(seed)
    q, kp, vp, lengths, bt, ksc, vsc = _case(seed, b=2, t=t, kh=2, r=2,
                                             d=32, ps=8, mp=3,
                                             num_pages=12, int8=int8)
    base = np.asarray(ops.paged_decode_attention(
        q, kp, vp, lengths, bt, ksc, vsc, use_pallas=True, interpret=True))

    # 1) widen the table with sentinel columns
    wide = jnp.concatenate(
        [bt, jnp.full((2, mp_extra), kp.shape[0], jnp.int32)], axis=1)
    out_w = np.asarray(ops.paged_decode_attention(
        q, kp, vp, lengths, wide, ksc, vsc, use_pallas=True,
        interpret=True))
    np.testing.assert_array_equal(out_w, base)

    # 2) scribble over every (page, offset) no query can see
    b, mp = bt.shape
    ps = kp.shape[1]
    flat_pos = np.arange(mp * ps)
    lmax = np.asarray(lengths).max(axis=1)
    dead = np.zeros((kp.shape[0],), bool)
    seen = np.zeros((kp.shape[0],), bool)
    bt_np = np.asarray(bt)
    for i in range(b):
        live = bt_np[i][flat_pos[flat_pos < lmax[i]] // ps]
        seen[live[live < kp.shape[0]]] = True
    dead = ~seen
    noise = g.normal(size=kp.shape)
    kp2 = jnp.where(jnp.asarray(dead)[:, None, None, None],
                    jnp.asarray(noise, kp.dtype), kp)
    vp2 = jnp.where(jnp.asarray(dead)[:, None, None, None],
                    jnp.asarray(noise[::-1], vp.dtype), vp)
    out_s = np.asarray(ops.paged_decode_attention(
        q, kp2, vp2, lengths, bt, ksc, vsc, use_pallas=True,
        interpret=True))
    np.testing.assert_array_equal(out_s, base)


# ---------------------------------------------------------------------------
# model-level: decode_step kernel path vs jnp fallback, + occupied clamp
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny():
    from repro.configs import get_config
    from repro.models.registry import get_model
    cfg = get_config("llama2_7b", reduced=True)
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, api, params


def test_decode_step_pallas_matches_fallback(tiny):
    """Paged decode_step logits: Pallas kernel path == jnp gather path
    (f32 pool: same math, online vs full softmax only)."""
    from repro.models import transformer as T
    cfg, api, params = tiny
    B, PS, MP = 2, 4, 6
    pcache = T.init_paged_cache(cfg, B * MP, PS)
    bt = jnp.asarray(np.arange(B * MP, dtype=np.int32).reshape(B, MP))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, size=(B, 7)).astype(np.int32))
    lens = jnp.asarray([7, 4], jnp.int32)
    _, pcache = T.prefill(params, pcache, toks, lens, bt, cfg)
    nxt = jnp.asarray([[1], [2]], jnp.int32)
    lg_ref, _ = T.decode_step(params, pcache, nxt, lens, cfg,
                              block_tables=bt, use_pallas=False)
    lg_ker, _ = T.decode_step(params, pcache, nxt, lens, cfg,
                              block_tables=bt, use_pallas=True)
    np.testing.assert_allclose(np.asarray(lg_ker), np.asarray(lg_ref),
                               rtol=1e-4, atol=1e-4)
    # multi-token (verify-style) step, and the occupied-page clamp
    nxt4 = jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab, size=(B, 3)).astype(np.int32))
    outs = []
    for use_pallas in (False, True):
        for mlp in (None, 4):            # full table vs clamped
            lg, _ = T.decode_step(params, pcache, nxt4, lens, cfg,
                                  block_tables=bt, use_pallas=use_pallas,
                                  max_live_pages=mlp)
            outs.append(np.asarray(lg))
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-4, atol=1e-4)


def test_decode_step_int8_pallas_close_to_fallback(tiny):
    """int8 pool: the kernel dequantizes tiles (f32 contractions) while
    the jnp path re-quantizes q and the softmax weights — logits agree to
    quantization noise (same bar as the contiguous int8 test)."""
    import dataclasses
    from repro.models import transformer as T
    cfg, api, params_fp = tiny
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    params = params_fp
    B, PS, MP = 2, 4, 6
    pcache = T.init_paged_cache(cfg8, B * MP, PS)
    bt = jnp.asarray(np.arange(B * MP, dtype=np.int32).reshape(B, MP))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, size=(B, 6)).astype(np.int32))
    lens = jnp.asarray([6, 3], jnp.int32)
    _, pcache = T.prefill(params, pcache, toks, lens, bt, cfg8)
    nxt = jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab, size=(B, 3)).astype(np.int32))
    lg_ref, _ = T.decode_step(params, pcache, nxt, lens, cfg8,
                              block_tables=bt, use_pallas=False)
    lg_ker, _ = T.decode_step(params, pcache, nxt, lens, cfg8,
                              block_tables=bt, use_pallas=True)
    np.testing.assert_allclose(np.asarray(lg_ker), np.asarray(lg_ref),
                               atol=0.05)


def test_engine_pallas_matches_reference_outputs(tiny):
    """End-to-end greedy engine generations are identical with the kernel
    path on (FP params: linears are FP either way, attention flips)."""
    from repro.engine import EngineConfig, InferenceEngine, SamplingParams
    cfg, api, params = tiny
    prompts = [np.random.default_rng(s).integers(
        0, cfg.vocab, size=4 + s).astype(np.int32) for s in range(3)]

    def run(use_pallas):
        eng = InferenceEngine(
            cfg, params, EngineConfig(num_slots=2, max_seq=16, page_size=4,
                                      use_pallas=use_pallas))
        rids = [eng.submit(p, 4) for p in prompts]
        res = eng.run()
        return {r["rid"]: list(r["tokens"]) for r in res["results"]}, rids

    out_ref, rids_ref = run(False)
    out_ker, rids_ker = run(True)
    for r0, r1 in zip(rids_ref, rids_ker):
        assert out_ref[r0] == out_ker[r1]


def test_spec_greedy_lossless_with_kernel_path(tiny):
    """Acceptance pin: greedy spec decode == greedy non-spec, token for
    token, with the Pallas paged-attention path enabled in BOTH."""
    from repro.core.model_compress import compress_draft, draft_layers
    from repro.engine import EngineConfig, InferenceEngine, SamplingParams
    cfg, api, params = tiny
    draft = compress_draft(params, cfg, profile="w4l50")
    prompts = [np.random.default_rng(s).integers(
        0, cfg.vocab, size=4 + s).astype(np.int32) for s in range(3)]

    def run(spec_k):
        eng = InferenceEngine(
            cfg, params,
            EngineConfig(num_slots=2, max_seq=24, page_size=4,
                         use_pallas=True, spec_k=spec_k,
                         spec_draft_layers=(draft_layers(cfg, "w4l50")
                                            if spec_k else None)),
            SamplingParams(),
            draft_params=draft if spec_k else None)
        rids = [eng.submit(p, 5) for p in prompts]
        res = eng.run()
        return {r["rid"]: list(r["tokens"]) for r in res["results"]}, rids

    out0, rids0 = run(0)
    out1, rids1 = run(3)
    for r0, r1 in zip(rids0, rids1):
        assert out0[r0] == out1[r1]


# ---------------------------------------------------------------------------
# token-tree ancestor masks: kernel vs oracle (DESIGN.md §8)
# ---------------------------------------------------------------------------

def _tree_case(seed, b, fanout, kh, r, d, ps, mp, num_pages, int8=False):
    """Random tree-verify instance: every slot feeds the same BFS tree
    block (window = N+1 tokens) at its own random base position inside
    its occupied pages; block tables end in OOB sentinels."""
    from repro.engine.spec import TreeTemplate
    tpl = TreeTemplate(fanout)
    w = tpl.n_nodes + 1
    g = np.random.default_rng(seed)
    t = w
    q = jnp.asarray(g.normal(size=(b, t, kh * r, d)), jnp.float32)
    kp = jnp.asarray(g.normal(size=(num_pages, ps, kh, d)), jnp.float32)
    vp = jnp.asarray(g.normal(size=(num_pages, ps, kh, d)), jnp.float32)
    pages = g.permutation(num_pages)[:b * mp].reshape(b, mp).astype(np.int32)
    need = -(-w // ps) + 1                       # pages the window spans
    occ = g.integers(need, mp + 1, size=b)
    bt = np.where(np.arange(mp)[None, :] < occ[:, None], pages, num_pages)
    base = np.stack([g.integers(0, occ[i] * ps - w + 1) for i in range(b)])
    lengths = np.broadcast_to((base + w)[:, None], (b, t)).astype(np.int32)
    anc = np.broadcast_to(tpl.anc[None, :], (b, t)).astype(np.int32)
    ksc = vsc = None
    if int8:
        kp, ksc = quantize_kv(kp.astype(jnp.float32))
        vp, vsc = quantize_kv(vp.astype(jnp.float32))
    return (q, kp, vp, jnp.asarray(lengths), jnp.asarray(bt),
            jnp.asarray(anc), jnp.asarray(base.astype(np.int32)), w,
            ksc, vsc)


@pytest.mark.parametrize("fanout", [(1,), (2,), (2, 2), (4, 2), (1, 3, 2)])
@pytest.mark.parametrize("int8", [False, True])
def test_tree_kernel_matches_tree_oracle(fanout, int8):
    q, kp, vp, lengths, bt, anc, base, w, ksc, vsc = _tree_case(
        sum(fanout) + int8, b=3, fanout=fanout, kh=2, r=2, d=32, ps=4,
        mp=6, num_pages=20, int8=int8)
    o_ref = kref.tree_attention_ref(q, kp, vp, lengths, bt, anc, base, w,
                                    ksc, vsc)
    o_ker = ops.paged_decode_attention(q, kp, vp, lengths, bt, ksc, vsc,
                                       anc=anc, anc_base=base, anc_window=w,
                                       use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("kh,r", [(1, 8), (4, 1)])
def test_tree_kernel_gqa_ratios(kh, r):
    q, kp, vp, lengths, bt, anc, base, w, ksc, vsc = _tree_case(
        13 * kh + r, b=2, fanout=(2, 2), kh=kh, r=r, d=64, ps=8, mp=4,
        num_pages=10)
    o_ref = kref.tree_attention_ref(q, kp, vp, lengths, bt, anc, base, w)
    o_ker = ops.paged_decode_attention(q, kp, vp, lengths, bt,
                                       anc=anc, anc_base=base, anc_window=w,
                                       use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)


def test_tree_kernel_all_sentinel_slot_is_finite():
    q, kp, vp, lengths, bt, anc, base, w, _, _ = _tree_case(
        29, b=2, fanout=(2, 2), kh=2, r=2, d=32, ps=4, mp=6, num_pages=20)
    bt = bt.at[1].set(kp.shape[0])                # slot 1: no pages
    o_ref = kref.tree_attention_ref(q, kp, vp, lengths, bt, anc, base, w)
    o_ker = ops.paged_decode_attention(q, kp, vp, lengths, bt,
                                       anc=anc, anc_base=base, anc_window=w,
                                       use_pallas=True, interpret=True)
    assert np.isfinite(np.asarray(o_ker)).all()
    np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)


def test_tree_kernel_chain_bitmaps_bit_identical_to_staircase():
    """The staircase IS the chain special case: running the kernel with
    prefix-of-ones ancestor bitmaps produces BIT-IDENTICAL output to the
    plain lengths-only kernel (same mask booleans, same float pipeline)."""
    q, kp, vp, lengths, bt, ksc, vsc = _case(41, b=2, t=4, kh=2, r=2,
                                             d=32, ps=8, mp=4, num_pages=16)
    base = jnp.min(lengths, axis=1) - 1           # first fed position
    w = 4
    chain_anc = jnp.broadcast_to(
        jnp.asarray([(1 << (i + 1)) - 1 for i in range(w)],
                    jnp.int32)[None, :], (2, w))
    # staircase lengths equivalent to base + bitmap windowing
    stair = (base[:, None] + 1 + jnp.arange(w)[None, :]).astype(jnp.int32)
    o_plain = ops.paged_decode_attention(q, kp, vp, stair, bt,
                                         use_pallas=True, interpret=True)
    o_tree = ops.paged_decode_attention(
        q, kp, vp, jnp.broadcast_to((base + w)[:, None], (2, w)), bt,
        anc=chain_anc, anc_base=base, anc_window=w,
        use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(o_tree), np.asarray(o_plain))


def test_spec_tree_greedy_lossless_with_kernel_path(tiny):
    """Acceptance pin: greedy TREE-spec decode == greedy non-spec, token
    for token, with the Pallas paged-attention path enabled in BOTH
    (ancestor-mask kernel on the draft + verify calls)."""
    from repro.core.model_compress import compress_draft, draft_layers
    from repro.engine import EngineConfig, InferenceEngine, SamplingParams
    cfg, api, params = tiny
    draft = compress_draft(params, cfg, profile="w4l50")
    prompts = [np.random.default_rng(s).integers(
        0, cfg.vocab, size=4 + s).astype(np.int32) for s in range(3)]

    def run(fanout):
        eng = InferenceEngine(
            cfg, params,
            EngineConfig(num_slots=2, max_seq=24, page_size=4,
                         use_pallas=True, spec_fanout=fanout,
                         spec_draft_layers=(draft_layers(cfg, "w4l50")
                                            if fanout else None)),
            SamplingParams(),
            draft_params=draft if fanout else None)
        rids = [eng.submit(p, 5) for p in prompts]
        res = eng.run()
        return {r["rid"]: list(r["tokens"]) for r in res["results"]}, rids

    out0, rids0 = run(None)
    out1, rids1 = run((2, 2))
    for r0, r1 in zip(rids0, rids1):
        assert out0[r0] == out1[r1]


def test_staircase_mask_shared_semantics():
    """The shared helper IS the masking of both jnp attentions: scalar,
    [B] and [B, T] length specs broadcast identically."""
    m_scalar = staircase_mask(jnp.int32(3), 2, 1, 5)
    m_vec = staircase_mask(jnp.asarray([3, 3]), 2, 1, 5)
    np.testing.assert_array_equal(np.asarray(m_scalar), np.asarray(m_vec))
    m_stair = np.asarray(staircase_mask(jnp.asarray([[1, 3]]), 1, 2, 4))
    assert m_stair[0, 0].tolist() == [True, False, False, False]
    assert m_stair[0, 1].tolist() == [True, True, True, False]
