"""Bench-regression gate: tolerance classes, direction awareness, the
self-test mechanism, and CLI exit codes — the gate must fail on an
injected regression and pass at baseline, or CI's BENCH_serve.json
gating is theater."""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.check_bench import (classify, compare, inject_regression,
                                    main, self_test)

BASELINE = {
    "serve_engine_gqsa": {
        "name": "serve_engine_gqsa", "schema": "repro-bench-record/v1",
        "us_per_call": 4000.0, "derived": "80 tok/s",
        "tok_per_s": 80.0, "ttft_ms_p50": 120.0, "speedup_vs_seed": 3.0},
    "serve_load_poisson_r8": {
        "name": "serve_load_poisson_r8", "schema": "repro-bench-record/v1",
        "us_per_call": 9000.0, "derived": "load point",
        "offered_req_per_s": 8.0, "tok_per_s": 70.0, "ttft_ms_p99": 40.0,
        "attainment": 1.0, "goodput_tok_per_s": 70.0},
    "spec_ladder": {
        "name": "spec_ladder", "schema": "repro-bench-record/v1",
        "timed": False, "derived": "acceptance",
        "acceptance_rate": 0.8, "accepted_len_mean": 2.4},
}


def _mutate(name, key, value):
    cur = json.loads(json.dumps(BASELINE))
    cur[name][key] = value
    return cur


def test_classify_direction_and_class():
    assert classify("us_per_call") == (-1, "timing")
    assert classify("ttft_ms_p99") == (-1, "timing")
    assert classify("goodput_tok_per_s") == (+1, "timing")
    assert classify("attainment") == (+1, "timing")
    assert classify("acceptance_rate") == (+1, "quality")
    assert classify("bytes_per_token") == (-1, "quality")
    assert classify("derived") is None
    assert classify("schema") is None
    assert classify("offered_req_per_s") is None   # workload constant


def test_baseline_vs_itself_is_clean():
    assert compare(BASELINE, BASELINE) == []


def test_catches_lower_better_regression_not_improvement():
    # us_per_call 9000 -> 20000 (+122%) beyond the 50% timing tolerance
    regs = compare(BASELINE,
                   _mutate("serve_load_poisson_r8", "us_per_call", 20000.0))
    assert [(r.record, r.key) for r in regs] == \
        [("serve_load_poisson_r8", "us_per_call")]
    # dropping is an improvement, never flagged
    assert compare(BASELINE,
                   _mutate("serve_load_poisson_r8", "us_per_call",
                           100.0)) == []


def test_catches_higher_better_regression_not_improvement():
    regs = compare(BASELINE,
                   _mutate("serve_engine_gqsa", "tok_per_s", 10.0))
    assert [(r.record, r.key) for r in regs] == \
        [("serve_engine_gqsa", "tok_per_s")]
    assert compare(BASELINE,
                   _mutate("serve_engine_gqsa", "tok_per_s", 500.0)) == []


def test_quality_tolerance_is_tighter_than_timing():
    # -10%: inside the 50% timing tolerance...
    assert compare(BASELINE,
                   _mutate("serve_engine_gqsa", "tok_per_s", 72.0)) == []
    # ...but beyond the 5% quality tolerance on a seeded statistic
    regs = compare(BASELINE,
                   _mutate("spec_ladder", "acceptance_rate", 0.72))
    assert [(r.record, r.key) for r in regs] == \
        [("spec_ladder", "acceptance_rate")]
    # within quality tolerance: clean
    assert compare(BASELINE,
                   _mutate("spec_ladder", "acceptance_rate", 0.78)) == []


def test_tolerances_are_configurable():
    cur = _mutate("serve_engine_gqsa", "tok_per_s", 72.0)   # -10%
    assert compare(BASELINE, cur, tol_timing=0.05) != []
    cur = _mutate("spec_ladder", "acceptance_rate", 0.72)   # -10%
    assert compare(BASELINE, cur, tol_quality=0.2) == []


def test_missing_record_and_require_all():
    cur = json.loads(json.dumps(BASELINE))
    del cur["spec_ladder"]
    assert compare(BASELINE, cur) == []
    regs = compare(BASELINE, cur, require_all=True)
    assert [(r.record, r.key) for r in regs] == \
        [("spec_ladder", "<record>")]
    # new records in the current snapshot are always fine
    cur = json.loads(json.dumps(BASELINE))
    cur["brand_new"] = {"us_per_call": 1.0, "derived": "x"}
    assert compare(BASELINE, cur) == []


def test_ungated_and_non_numeric_keys_ignored():
    cur = _mutate("serve_load_poisson_r8", "offered_req_per_s", 9999.0)
    cur["serve_engine_gqsa"]["derived"] = "totally different prose"
    cur["serve_engine_gqsa"]["tok_per_s"] = "not-a-number"
    assert compare(BASELINE, cur) == []


def test_inject_regression_is_caught():
    bad, name, key = inject_regression(BASELINE)
    regs = compare(BASELINE, bad)
    assert any(r.record == name and r.key == key for r in regs)
    # targeting a specific key works too
    bad, name, key = inject_regression(BASELINE, key="goodput_tok_per_s")
    assert key == "goodput_tok_per_s"
    assert bad[name][key] == pytest.approx(7.0)      # higher-better: /10
    assert any(r.key == key for r in compare(BASELINE, bad))


def test_self_test_roundtrip(tmp_path):
    path = tmp_path / "bench.json"
    path.write_text(json.dumps(BASELINE))
    assert self_test(str(path)) == 0


def test_cli_exit_codes(tmp_path):
    base = tmp_path / "base.json"
    base.write_text(json.dumps(BASELINE))
    cur = tmp_path / "cur.json"
    cur.write_text(json.dumps(
        _mutate("serve_engine_gqsa", "tok_per_s", 10.0)))
    ok = tmp_path / "ok.json"
    ok.write_text(json.dumps(BASELINE))
    assert main(["--baseline", str(base), "--current", str(ok)]) == 0
    assert main(["--baseline", str(base), "--current", str(cur)]) == 1
    # a loose enough tolerance waves the same diff through
    assert main(["--baseline", str(base), "--current", str(cur),
                 "--tol-timing", "10.0"]) == 0
    assert main(["--baseline", str(base), "--current",
                 str(tmp_path / "missing.json")]) == 2
    assert main(["--baseline", str(base), "--self-test"]) == 0


def test_committed_baseline_passes_its_own_gate():
    """The tracked BENCH_serve.json must satisfy the gate's self-test —
    otherwise the CI steps are wired to a broken baseline."""
    repo = Path(__file__).resolve().parent.parent / "BENCH_serve.json"
    assert repo.is_file()
    assert self_test(str(repo)) == 0
    records = json.loads(repo.read_text())
    for name, rec in records.items():
        assert rec.get("name") == name                # self-describing
        assert "schema" in rec
        assert ("us_per_call" in rec) != (rec.get("timed") is False)
