"""System-level behaviour: training converges, serving decodes, the
launchers run, the dry-run machinery produces roofline terms."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.launch.steps import build_train_step, make_dist
from repro.models.registry import get_model, lm_loss
from repro.optim import adamw


def test_training_reduces_loss():
    cfg = get_config("llama2_7b", reduced=True)
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_state(params)
    step = jax.jit(build_train_step(cfg, make_dist(cfg, None),
                                    adamw.AdamWConfig(lr=2e-3)))
    data = SyntheticLM(cfg.vocab, 64, 8, seed=0)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in data.host_batch(i).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[::6]


def test_train_launcher_with_checkpoint_restart(tmp_path):
    from repro.launch.train import main
    log1 = main(["--arch", "llama2_7b", "--reduced", "--steps", "10",
                 "--batch", "4", "--seq", "32", "--ckpt-dir",
                 str(tmp_path), "--save-every", "5", "--log-every", "2"])
    # relaunch: restores and continues
    log2 = main(["--arch", "llama2_7b", "--reduced", "--steps", "14",
                 "--batch", "4", "--seq", "32", "--ckpt-dir",
                 str(tmp_path), "--save-every", "5", "--log-every", "2"])
    assert log2[0]["step"] >= 10


def test_serve_launcher_gqsa():
    from repro.launch.serve import main
    res = main(["--arch", "llama2_7b", "--reduced", "--compress", "gqsa",
                "--requests", "2", "--slots", "2", "--max-new", "4"])
    assert res["requests"] == 2
    assert res["tokens"] == 8


def test_ddp_grad_compress_converges():
    cfg = get_config("llama2_7b", reduced=True)
    api = get_model(cfg)
    from repro.launch.steps import build_train_step_ddp
    from repro.optim.grad_compress import init_error_state
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_state(params)
    err = init_error_state(params)
    step = build_train_step_ddp(cfg, make_dist(cfg, None),
                                adamw.AdamWConfig(lr=2e-3))
    data = SyntheticLM(cfg.vocab, 64, 8, seed=0)
    losses = []
    for i in range(20):
        batch = {k: jnp.asarray(v) for k, v in data.host_batch(i).items()}
        params, opt, err, m = step(params, opt, err, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2


def test_collective_bytes_parser():
    from repro.launch.hlo_analysis import collective_bytes_from_hlo
    hlo = """
      %ar = bf16[8,128]{1,0} all-reduce(%x), replica_groups={}
      %ag.1 = (f32[16,16]{1,0}, f32[16,16]{1,0}) all-gather-start(%y)
      %ag.2 = f32[16,16]{1,0} all-gather-done(%ag.1)
      %cp = u8[1024]{0} collective-permute(%z)
    """
    out = collective_bytes_from_hlo(hlo)
    assert out["all-reduce"] == 8 * 128 * 2
    assert out["all-gather"] == 2 * 16 * 16 * 4   # start counted once
    assert out["collective-permute"] == 1024
    assert out["count"] == 3


def test_roofline_terms_math():
    from repro.launch.hlo_analysis import roofline_terms
    r = roofline_terms({"flops": 197e12, "bytes accessed": 819e9},
                       {"total": 50e9}, chips=4, model_flops=197e12 * 2)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(1.0)
    assert r.collective_s == pytest.approx(1.0)
    assert r.useful_ratio == pytest.approx(0.5)


def test_dryrun_artifacts_exist_and_valid():
    """The sweep writes per-cell JSONs; validate any present artifacts."""
    d = os.path.join(os.path.dirname(__file__), "..", "experiments",
                     "dryrun")
    if not os.path.isdir(d):
        pytest.skip("dry-run sweep not executed yet")
    files = [f for f in os.listdir(d) if f.endswith(".json")]
    if not files:
        pytest.skip("no artifacts yet")
    ok = 0
    for f in files:
        rec = json.load(open(os.path.join(d, f)))
        if rec.get("status") == "ok":
            ok += 1
            assert "roofline" in rec
            assert rec["roofline"]["dominant"] in ("compute", "memory",
                                                   "collective")
    assert ok > 0
