"""End-to-end GQSA compression pipeline on a tiny LM (paper Figure 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.bqpo import BQPOConfig
from repro.core.e2e_oqp import E2EConfig, freeze_int
from repro.core.gqs_layer import GQSAConfig, apply_linear, dequant_dense
from repro.core.model_compress import (compress_params, compress_params_w4,
                                       compression_report)
from repro.core.pipeline import gqsa_compress, oneshot, pack_frozen
from repro.core.pruning import PruneConfig
from repro.core.quant import QuantConfig
from repro.data.pipeline import SyntheticLM
from repro.models.registry import get_model, lm_loss


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("llama2_7b", reduced=True)
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=0)
    batches = [{k: jnp.asarray(v) for k, v in data.host_batch(i).items()}
               for i in range(3)]
    return cfg, api, params, batches


def test_two_stage_beats_oneshot(tiny):
    cfg, api, params, batches = tiny
    held_out = {k: jnp.asarray(v) for k, v in
                SyntheticLM(cfg.vocab, 32, 4, seed=99).host_batch(0).items()}
    l_oneshot = float(lm_loss(api.forward(
        oneshot(params, batches, cfg), held_out, cfg)[0],
        held_out["labels"]))
    packed, report = gqsa_compress(
        params, batches, cfg, bqpo_cfg=BQPOConfig(steps=25, lr=1e-3),
        e2e_cfg=E2EConfig(steps=25, lr=1e-3))
    l_two = float(lm_loss(api.forward(packed, held_out, cfg)[0],
                          held_out["labels"]))
    assert l_two < l_oneshot + 0.05
    assert report["e2e_loss"][-1] < report["e2e_loss"][0]


def test_packed_equals_frozen_int_forward(tiny):
    """Packing must preserve the E2E-tuned model bit-for-bit (the paper's
    'no masks needed after packing' claim)."""
    cfg, api, params, batches = tiny
    gqsa = GQSAConfig()
    from repro.core.bqpo import bqpo
    fq, _ = bqpo(params, [b["tokens"] for b in batches], cfg, gqsa,
                 BQPOConfig(steps=3, lr=1e-3))
    frozen = freeze_int(fq, gqsa)
    packed = pack_frozen(frozen)
    lf, _ = api.forward(frozen, batches[0], cfg)
    lp, _ = api.forward(packed, batches[0], cfg)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lf),
                               rtol=1e-3, atol=1e-3)


def test_compress_params_sparsity_observed(tiny):
    cfg, api, params, batches = tiny
    for s in (0.3, 0.5):
        gqsa = GQSAConfig(prune=PruneConfig(sparsity=s, group_size=16))
        packed = compress_params(params, cfg, gqsa)
        # check one layer's BSR: kept groups per row ~= (1-s) * groups
        bsr = packed["layers"]["attn"]["wq"]["bsr"]
        k = bsr.shape[1]
        m = bsr.idx.shape[-1]
        frac = m / (k // 16)
        assert abs(frac - (1 - s)) < 0.1


def test_compression_report_ratio(tiny):
    cfg, api, params, batches = tiny
    packed = compress_params(params, cfg, GQSAConfig())
    rep = compression_report(params["layers"], packed["layers"])
    # padded in-memory ratio is conservative; must still be > 1.5x vs fp16
    assert rep["ratio_vs_fp16"] > 1.5


def test_w4_baseline_forward(tiny):
    cfg, api, params, batches = tiny
    packed = compress_params_w4(params, cfg, QuantConfig(group_size=16))
    logits, _ = api.forward(packed, batches[0], cfg)
    fp_logits, _ = api.forward(params, batches[0], cfg)
    assert bool(jnp.isfinite(logits).all())
    # W4 is a good approximation of FP
    cos = np.corrcoef(np.asarray(logits).ravel(),
                      np.asarray(fp_logits).ravel())[0, 1]
    assert cos > 0.95


def test_gqsa_loss_ordering_w4_vs_w4s50(tiny):
    """More compression => no better loss (sanity on a fixed model)."""
    cfg, api, params, batches = tiny
    b = batches[0]
    fp = float(lm_loss(api.forward(params, b, cfg)[0], b["labels"]))
    w4 = float(lm_loss(api.forward(
        compress_params_w4(params, cfg, QuantConfig(group_size=16)),
        b, cfg)[0], b["labels"]))
    s50 = float(lm_loss(api.forward(
        compress_params(params, cfg, GQSAConfig()), b, cfg)[0],
        b["labels"]))
    assert w4 >= fp - 0.02
    assert s50 >= w4 - 0.05


def test_gqs_layer_representations_agree():
    """fp / fake-quant / frozen-int / packed paths of one linear agree."""
    rng = np.random.default_rng(0)
    n, k, g = 32, 128, 16
    w = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(4, k)), jnp.float32)
    gqsa = GQSAConfig()
    from repro.core.saliency import HessianStats
    from repro.core.gqs_layer import make_fake_quant, pack_gqsa
    stats = HessianStats.init(k, diag_only=True).update(x)
    fq = make_fake_quant(w, stats, gqsa)
    y_fq = apply_linear(fq, x)
    frozen = freeze_int({"lin": fq}, gqsa)["lin"]
    y_frozen = apply_linear(frozen, x)
    packed = pack_gqsa(fq, gqsa)
    y_packed = apply_linear(packed, x)
    np.testing.assert_allclose(np.asarray(y_fq), np.asarray(y_frozen),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y_packed), np.asarray(y_frozen),
                               rtol=1e-4, atol=1e-4)
    # dense reconstruction matches too
    np.testing.assert_allclose(np.asarray(dequant_dense(packed)),
                               np.asarray(dequant_dense(fq, gqsa.quant)),
                               rtol=1e-4, atol=1e-4)
