"""Token-tree self-speculative decoding (engine/spec/tree.py, DESIGN.md
§8): tree template geometry, sibling-set rejection sampling, the
accepted-path KV compaction, and the two pinned engine properties — a
degenerate (fanout-1) tree is BIT-IDENTICAL to the PR 2 chain spec path,
and random accept/reject tree traffic never leaks a page."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # pragma: no cover
    from _hyp import given, settings, st

from repro.configs import get_config
from repro.core.model_compress import compress_draft, draft_layers
from repro.engine import EngineConfig, InferenceEngine, SamplingParams
from repro.engine.sampling import tree_verify
from repro.engine.spec import TreeTemplate, compact_accepted
from repro.models.registry import get_model

GREEDY = SamplingParams()


@functools.lru_cache(maxsize=2)
def _tiny():
    cfg = get_config("llama2_7b", reduced=True)
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, api, params


@functools.lru_cache(maxsize=8)
def _draft(profile):
    cfg, api, params = _tiny()
    return compress_draft(params, cfg, profile=profile)


def _prompts(vocab, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=l).astype(np.int32) for l in lens]


# ---------------------------------------------------------------------------
# TreeTemplate geometry
# ---------------------------------------------------------------------------

def test_tree_template_structure():
    tpl = TreeTemplate((2, 2))
    assert tpl.n_nodes == 6 and tpl.depth == 2
    assert tpl.level_starts == (0, 1, 3)
    # BFS: 0=root; 1,2 = level 1; 3,4 children of 1; 5,6 children of 2
    assert list(tpl.parents) == [-1, 0, 0, 1, 1, 2, 2]
    assert list(tpl.depths) == [0, 1, 1, 2, 2, 2, 2]
    assert list(tpl.child_start) == [1, 3, 5, -1, -1, -1, -1]
    # ancestor bitmaps: root path only (node 5 = {0, 2, 5})
    assert tpl.anc[0] == 0b1
    assert tpl.anc[2] == 0b101
    assert tpl.anc[5] == 0b100101
    # chain degenerates to prefix-of-ones bitmaps (the staircase)
    ch = TreeTemplate((1, 1, 1))
    assert ch.n_nodes == 3
    assert [int(a) for a in ch.anc] == [0b1, 0b11, 0b111, 0b1111]


def test_tree_template_rejects_oversized_and_invalid():
    with pytest.raises(ValueError):
        TreeTemplate((8, 4))              # 40 nodes > int32 bitmap lanes
    with pytest.raises(ValueError):
        TreeTemplate(())
    with pytest.raises(ValueError):
        TreeTemplate((2, 0))


# ---------------------------------------------------------------------------
# tree_verify: greedy path == sequential reference walk
# ---------------------------------------------------------------------------

def _ref_tree_walk(logits, feed, fanout, child_start):
    """Reference: walk the tree greedily, one row. Returns (n_acc, the
    n_acc + 1 emitted tokens)."""
    tgt = logits.argmax(-1)
    cur, toks = 0, []
    for f in fanout:
        t = int(tgt[cur])
        toks.append(t)
        nxt = next((child_start[cur] + j for j in range(f)
                    if feed[child_start[cur] + j] == t), None)
        if nxt is None:
            return len(toks) - 1, toks
        cur = nxt
    toks.append(int(tgt[cur]))
    return len(fanout), toks


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**31 - 1),
       st.lists(st.integers(1, 3), min_size=1, max_size=3),
       st.integers(4, 17))
def test_tree_verify_greedy_property(seed, fanout, v):
    """For ANY logits/tree, greedy tree_verify emits exactly the
    sequential-greedy walk: longest root-to-leaf path of argmax matches,
    then the argmax correction/bonus — and the path indices are real
    tree slots consistent with the emitted tokens."""
    fanout = tuple(fanout)
    tpl = TreeTemplate(fanout)
    g = np.random.default_rng(seed)
    b = 3
    logits = g.normal(size=(b, tpl.n_nodes + 1, v)).astype(np.float32)
    feed = g.integers(0, v, size=(b, tpl.n_nodes + 1)).astype(np.int32)
    # row 0 adversarial: plant the argmax path so deep walks happen
    tgt0 = logits[0].argmax(-1)
    cur = 0
    for f in fanout:
        cb = tpl.child_start[cur]
        j = g.integers(0, f)
        feed[0, cb + j] = tgt0[cur]
        cur = cb + j
    n_acc, out, path = tree_verify(jnp.asarray(logits), jnp.asarray(feed),
                                   fanout, tpl.child_start,
                                   jax.random.PRNGKey(seed), GREEDY)
    n_acc, out, path = np.asarray(n_acc), np.asarray(out), np.asarray(path)
    for i in range(b):
        n_ref, toks_ref = _ref_tree_walk(logits[i], feed[i], fanout,
                                         tpl.child_start)
        assert n_acc[i] == n_ref
        assert list(out[i, :n_ref + 1]) == toks_ref
        for d in range(n_ref):            # path slots carry the tokens
            assert tpl.depths[path[i, d]] == d + 1
            assert feed[i, path[i, d]] == toks_ref[d]


def test_tree_verify_chain_matches_spec_verify():
    """Fanout-1 tree_verify == chain spec_verify (greedy): same accepted
    length, same emitted tokens, for every accept/reject shape."""
    from repro.engine.sampling import spec_verify
    g = np.random.default_rng(7)
    B, K, V = 4, 3, 16
    tpl = TreeTemplate((1,) * K)
    logits = g.normal(size=(B, K + 1, V)).astype(np.float32)
    tgt = logits.argmax(-1)
    draft = np.stack([
        tgt[0, :K],                               # full accept
        (tgt[1, :K] + 1) % V,                     # reject at 0
        np.concatenate([tgt[2, :1], (tgt[2, 1:K] + 1) % V]),
        g.integers(0, V, size=K),
    ]).astype(np.int32)
    feed = np.concatenate([np.zeros((B, 1), np.int32), draft], axis=1)
    n_c, out_c = spec_verify(jnp.asarray(logits), jnp.asarray(draft),
                             jax.random.PRNGKey(0), GREEDY)
    n_t, out_t, _ = tree_verify(jnp.asarray(logits), jnp.asarray(feed),
                                tpl.fanout, tpl.child_start,
                                jax.random.PRNGKey(0), GREEDY)
    np.testing.assert_array_equal(np.asarray(n_t), np.asarray(n_c))
    for i in range(B):
        n = int(np.asarray(n_c)[i])
        np.testing.assert_array_equal(np.asarray(out_t)[i, :n + 1],
                                      np.asarray(out_c)[i, :n + 1])


# ---------------------------------------------------------------------------
# tree_verify: sibling-set rejection sampling preserves the target
# ---------------------------------------------------------------------------

def test_tree_verify_first_token_distribution_preserved():
    """The first emitted token must be distributed exactly as the target
    p — whatever the sibling candidates propose (the tree analogue of
    the chain distribution-preservation test)."""
    V = 5
    sp = SamplingParams(temperature=1.0)
    tpl = TreeTemplate((2, 2))
    logits0 = np.array([2.0, 1.0, 0.5, 0.0, -1.0], np.float32)
    target = np.exp(logits0) / np.exp(logits0).sum()
    logits = jnp.asarray(np.tile(logits0, (1, tpl.n_nodes + 1, 1)))
    n = 4000
    keys = jax.random.split(jax.random.PRNGKey(0), n)
    walk = jax.vmap(lambda key, fd: tree_verify(
        logits, fd, tpl.fanout, tpl.child_start, key, sp)[1],
        in_axes=(0, None))
    for sibs in ((0, 1), (4, 3)):         # likely and unlikely candidates
        feed = np.zeros((1, tpl.n_nodes + 1), np.int32)
        feed[0, 1], feed[0, 2] = sibs     # root's children
        out = np.asarray(walk(keys, jnp.asarray(feed)))     # [n, 1, D+1]
        freq = np.bincount(out[:, 0, 0], minlength=V) / n
        np.testing.assert_allclose(freq, target, atol=0.05)


def test_tree_verify_rejection_excludes_rejected_siblings():
    """When every sibling has ~zero target mass, the walk stops at depth
    0 and the correction can never be one of the rejected siblings."""
    V = 4
    sp = SamplingParams(temperature=1.0)
    tpl = TreeTemplate((2,))
    logits0 = np.array([10.0, 0.0, -30.0, -30.0], np.float32)
    logits = jnp.asarray(np.tile(logits0, (1, tpl.n_nodes + 1, 1)))
    feed = np.zeros((1, tpl.n_nodes + 1), np.int32)
    feed[0, 1], feed[0, 2] = 2, 3         # both ~impossible
    keys = jax.random.split(jax.random.PRNGKey(1), 400)
    n_acc, out, _ = jax.vmap(lambda k: tree_verify(
        logits, jnp.asarray(feed), tpl.fanout, tpl.child_start, k, sp))(keys)
    assert (np.asarray(n_acc) == 0).all()
    assert not np.isin(np.asarray(out)[:, 0, 0], (2, 3)).any()


# ---------------------------------------------------------------------------
# accepted-path KV compaction
# ---------------------------------------------------------------------------

def test_compact_accepted_moves_path_and_drops_rest():
    """Distinguishable per-position values: the accepted path's slots
    move into the leading positions, other slots' pages and positions
    outside the tree block stay untouched, and invalid rows write
    nothing (sentinel drop)."""
    L, P, ps, KH, D = 2, 6, 4, 1, 2
    pool = jnp.arange(L * P * ps * KH * D, dtype=jnp.float32).reshape(
        L, P, ps, KH, D)
    cache = {"k_pages": pool, "v_pages": pool * 10.0}
    bt = jnp.asarray([[0, 1, 2], [3, 4, 5]], jnp.int32)
    positions = jnp.asarray([2, 5], jnp.int32)
    # slot 0 accepted path = tree slots (2, 5); slot 1 produced nothing
    path = jnp.asarray([[2, 5], [1, 3]], jnp.int32)
    n_new = jnp.asarray([3, 0], jnp.int32)
    out = compact_accepted(cache, bt, positions, path, n_new, ps)
    ref = np.asarray(pool).copy()

    def flat(slot, pos):                  # (page, offset) of a position
        return np.asarray(bt)[slot][pos // ps], pos % ps

    for layer in range(L):
        for i, src in enumerate((2, 5)):  # path -> pos+1+i
            sp_, so = flat(0, 2 + src)
            dp, do = flat(0, 2 + 1 + i)
            ref[layer, dp, do] = np.asarray(pool)[layer, sp_, so]
    np.testing.assert_array_equal(np.asarray(out["k_pages"]), ref)
    np.testing.assert_array_equal(np.asarray(out["v_pages"]), ref * 10.0)


# ---------------------------------------------------------------------------
# engine property 1: degenerate tree == chain, bit for bit
# ---------------------------------------------------------------------------

def _run_spec_engine(seed, max_new, profile, *, spec_k=0, spec_fanout=None,
                     adaptive=False, use_pallas=False):
    cfg, api, params = _tiny()
    eng = InferenceEngine(
        cfg, params,
        EngineConfig(num_slots=2, max_seq=24, page_size=4,
                     spec_k=spec_k, spec_fanout=spec_fanout,
                     spec_adaptive=adaptive, use_pallas=use_pallas,
                     spec_draft_layers=draft_layers(cfg, profile)),
        GREEDY, draft_params=_draft(profile))
    prompts = _prompts(cfg.vocab, (5, 9, 4), seed=seed)
    rids = [eng.submit(p, max_new) for p in prompts]
    res = eng.run()
    out = {r["rid"]: list(r["tokens"]) for r in res["results"]}
    return eng, [out[r] for r in rids], res["metrics"]


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 3),
       st.sampled_from(["w4", "w4s75", "w4l50"]))
def test_degenerate_tree_bit_identical_to_chain(seed, k, profile):
    """A fanout-1 tree IS the chain: generated tokens, the entire paged
    KV pool, and the per-slot position counters end bit-identical to the
    PR 2 chain spec path for any seed/K/draft profile."""
    eng_c, toks_c, _ = _run_spec_engine(seed, 6, profile, spec_k=k)
    eng_t, toks_t, _ = _run_spec_engine(seed, 6, profile,
                                        spec_fanout=(1,) * k)
    assert toks_c == toks_t
    for lc, lt in zip(jax.tree_util.tree_leaves(eng_c.kv.data),
                      jax.tree_util.tree_leaves(eng_t.kv.data)):
        np.testing.assert_array_equal(np.asarray(lc), np.asarray(lt))
    np.testing.assert_array_equal(np.asarray(eng_c._positions),
                                  np.asarray(eng_t._positions))


# ---------------------------------------------------------------------------
# engine property 2: tree accept/reject traffic never leaks a page
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1),
       st.sampled_from([(2,), (2, 2), (1, 2), (3, 1)]),
       st.sampled_from(["w4s75", "w4l50"]))
def test_tree_allocator_leak_free(seed, fanout, profile):
    """Random accept/reject tree rounds interleaved with slot admission
    and eviction (the pool only fits ~one resident request, so requests
    stream through) drain the free list back to its initial state — tree
    reserve/compact/rewind never touches the allocator mid-request."""
    cfg, api, params = _tiny()
    lookahead = TreeTemplate(fanout).n_nodes
    pages_per_req = -(-(16 + lookahead) // 4)
    eng = InferenceEngine(
        cfg, params,
        EngineConfig(num_slots=2, max_seq=16, page_size=4,
                     num_pages=pages_per_req + 1, spec_fanout=fanout,
                     spec_draft_layers=draft_layers(cfg, profile)),
        GREEDY, draft_params=_draft(profile))
    initial_free = eng.kv.allocator.num_free
    lens = np.random.default_rng(seed).integers(3, 8, size=4)
    for p in _prompts(cfg.vocab, tuple(lens), seed=seed):
        eng.submit(p, 4)
    res = eng.run()
    assert len(res["results"]) == 4
    assert all(r["n_generated"] == 4 for r in res["results"])
    assert eng.kv.allocator.num_free == initial_free


# ---------------------------------------------------------------------------
# greedy losslessness at a real branching fanout + adaptive controller
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fanout", [(2, 2), (3, 2, 1)])
def test_tree_spec_greedy_lossless(fanout):
    """Greedy tree-speculative output is token-for-token identical to
    greedy non-speculative output at branching fanouts (losslessness
    cannot depend on tree shape or draft quality)."""
    cfg, api, params = _tiny()
    prompts = _prompts(cfg.vocab, (5, 9, 4), seed=3)
    eng0 = InferenceEngine(cfg, params,
                           EngineConfig(num_slots=2, max_seq=24,
                                        page_size=4), GREEDY)
    rids0 = [eng0.submit(p, 6) for p in prompts]
    by0 = {r["rid"]: list(r["tokens"]) for r in eng0.run()["results"]}
    eng1, toks1, m = _run_spec_engine(3, 6, "w4s75", spec_fanout=fanout)
    assert [by0[r] for r in rids0] == toks1
    assert m["spec_rounds"] > 0
    assert np.isfinite(m["accepted_len_mean"])
    assert m["verify_tokens"] > 0


def test_tree_spec_temperature_sampling_runs():
    """Sampled path at a branching fanout: budgets exact, tokens valid,
    acceptance accounting sane, pool drained."""
    cfg, api, params = _tiny()
    eng = InferenceEngine(
        cfg, params,
        EngineConfig(num_slots=2, max_seq=24, page_size=4,
                     spec_fanout=(2, 2),
                     spec_draft_layers=draft_layers(cfg, "w4")),
        SamplingParams(temperature=0.8, top_k=16),
        draft_params=_draft("w4"))
    for p in _prompts(cfg.vocab, (4, 6, 5), seed=11):
        eng.submit(p, 5)
    res = eng.run()
    assert len(res["results"]) == 3
    for r in res["results"]:
        assert r["tokens"].shape == (5,)
        assert (r["tokens"] >= 0).all() and (r["tokens"] < cfg.vocab).all()
    m = res["metrics"]
    assert m["draft_accepted"] <= m["draft_proposed"]
    assert eng.kv.allocator.num_free == eng.kv.num_pages


def test_adaptive_ladder_controller():
    """The adaptive controller maps the active-slot EWMA floor onto the
    ladder: thrash -> chain K=1, mid -> depth-equal chain, high -> the
    full tree; and an adaptive run stays lossless."""
    cfg, api, params = _tiny()
    eng = InferenceEngine(
        cfg, params,
        EngineConfig(num_slots=2, max_seq=24, page_size=4,
                     spec_fanout=(2, 2), spec_adaptive=True,
                     spec_draft_layers=draft_layers(cfg, "w4s75")),
        GREEDY, draft_params=_draft("w4s75"))
    assert eng._fanout_ladder == [(1,), (1, 1), (2, 2)]
    from repro.engine.scheduler import DECODE
    eng.submit(np.arange(4, dtype=np.int32), 2)
    for r in eng.scheduler.admit():       # occupy a slot so min() is real
        r.state = DECODE
    eng._accept_ewma[:] = 0.1
    assert eng._segment_fanout() == (1,)
    eng._accept_ewma[:] = 0.5
    assert eng._segment_fanout() == (1, 1)
    eng._accept_ewma[0] = 0.9             # min over ACTIVE slots decides
    eng._accept_ewma[1] = 0.9
    assert eng._segment_fanout() == (2, 2)
    # end-to-end adaptive run == non-spec greedy
    _, toks_a, _ = _run_spec_engine(5, 6, "w4s75", spec_fanout=(2, 2),
                                    adaptive=True)
    eng0 = InferenceEngine(cfg, params,
                           EngineConfig(num_slots=2, max_seq=24,
                                        page_size=4), GREEDY)
    rids0 = [eng0.submit(p, 6) for p in _prompts(cfg.vocab, (5, 9, 4),
                                                 seed=5)]
    by0 = {r["rid"]: list(r["tokens"]) for r in eng0.run()["results"]}
    assert [by0[r] for r in rids0] == toks_a
