import os
import sys

# tests must see ONE cpu device (the dry-run alone uses 512 host devices)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
