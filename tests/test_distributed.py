"""Distributed correctness on 8 fake host devices.

These run in SUBPROCESSES with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the rest of the suite keeps seeing exactly one CPU device.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_sub(body: str) -> dict:
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys, json
        sys.path.insert(0, {src!r})
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        out = {{}}
    """).format(src=SRC) + textwrap.dedent(body) + \
        "\nprint('RESULT:' + json.dumps(out))\n"
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=560)
    if r.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{r.stderr[-4000:]}")
    for line in r.stdout.splitlines():
        if line.startswith("RESULT:"):
            return json.loads(line[len("RESULT:"):])
    raise AssertionError(f"no RESULT in output:\n{r.stdout[-2000:]}")


def test_sharded_train_step_matches_single_device():
    out = run_sub("""
        from repro.configs import get_config
        from repro.launch.steps import build_train_step, make_dist
        from repro.models.registry import get_model
        from repro.optim import adamw
        from repro.dist.sharding import param_shardings
        from repro.dist.elastic import plan_mesh, build_mesh

        cfg = get_config("llama2_7b", reduced=True)
        api = get_model(cfg)
        rng = jax.random.PRNGKey(0)
        params = api.init_params(rng, cfg)
        opt = adamw.init_state(params)
        batch = {"tokens": jax.random.randint(rng, (8, 32), 0, cfg.vocab),
                 "labels": jax.random.randint(rng, (8, 32), 0, cfg.vocab)}

        # single device
        dist1 = make_dist(cfg, None)
        step1 = jax.jit(build_train_step(cfg, dist1, adamw.AdamWConfig()))
        p1, o1, m1 = step1(params, opt, batch)

        # 4x2 mesh (DP x TP)
        mesh = build_mesh(plan_mesh(8, model_parallel=2))
        dist = make_dist(cfg, mesh)
        with mesh:
            p_sh = param_shardings(params, dist)
            params_d = jax.device_put(params, p_sh)
            opt_d = adamw.init_state(params_d)
            step = jax.jit(build_train_step(cfg, dist, adamw.AdamWConfig()))
            p2, o2, m2 = step(params_d, opt_d, batch)
        out["loss1"] = float(m1["loss"]); out["loss2"] = float(m2["loss"])
        d = jax.tree_util.tree_map(
            lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                               - b.astype(jnp.float32)))),
            p1, p2)
        out["max_param_diff"] = max(jax.tree_util.tree_leaves(d))
    """)
    assert abs(out["loss1"] - out["loss2"]) < 1e-2
    assert out["max_param_diff"] < 5e-2


def test_compressed_psum_error_feedback():
    out = run_sub("""
        from jax.experimental.shard_map import shard_map
        from repro.dist.collectives import compressed_psum_leaf

        mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
        err0 = jnp.zeros((64,))

        def f(gl, e):
            m, e2 = compressed_psum_leaf(gl[0], e, "data")
            return m[None], e2[None]

        mean_c, err = shard_map(f, mesh=mesh,
                                in_specs=(P("data", None), P(None)),
                                out_specs=(P(None), P("data")),
                                check_rep=False)(g, err0)
        exact = jnp.mean(g, axis=0)
        out["rel_err"] = float(jnp.linalg.norm(mean_c[0] - exact)
                               / jnp.linalg.norm(exact))
        # error feedback: applying again with the carried error reduces bias
        out["err_norm"] = float(jnp.linalg.norm(err))
    """)
    assert out["rel_err"] < 0.05
    assert out["err_norm"] > 0  # feedback is being carried


def test_distributed_decode_attention_matches_dense():
    out = run_sub("""
        from repro.dist.collectives import (sharded_decode_attention,
                                            update_sharded_cache)
        from repro.models.layers import decode_attention

        mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
        B, S, KH, D, H = 2, 64, 2, 16, 4
        rng = jax.random.PRNGKey(0)
        q = jax.random.normal(rng, (B, 1, H, D))
        k = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, KH, D))
        v = jax.random.normal(jax.random.fold_in(rng, 2), (B, S, KH, D))
        length = jnp.int32(40)
        o_dense = decode_attention(q, k, v, length)
        with mesh:
            o_dist = sharded_decode_attention(q, k, v, length, mesh, "data")
        out["max_diff"] = float(jnp.max(jnp.abs(o_dense - o_dist)))

        # sharded cache update writes exactly one position
        cache = jnp.zeros((B, S, KH, D))
        new = jnp.ones((B, 1, KH, D))
        with mesh:
            c2 = update_sharded_cache(cache, new, jnp.int32(17), mesh,
                                      "data")
        out["written"] = float(jnp.sum(c2))
        out["at17"] = float(jnp.sum(c2[:, 17]))
    """)
    assert out["max_diff"] < 1e-4
    assert out["at17"] == out["written"] == 2 * 2 * 16


def test_moe_ep_matches_local():
    out = run_sub("""
        from repro.configs import get_config
        from repro.models import moe as MOE
        from repro.dist.sharding import DistContext
        from repro.dist.elastic import plan_mesh, build_mesh

        import dataclasses
        cfg = get_config("deepseek_moe_16b", reduced=True)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
        rng = jax.random.PRNGKey(0)
        p = MOE.moe_init(rng, cfg)
        x = jax.random.normal(jax.random.fold_in(rng, 1), (2, 16,
                                                           cfg.d_model)) * .3
        y_local, aux_local = MOE.moe_block(p, x, cfg, None)
        mesh = build_mesh(plan_mesh(8, model_parallel=4))
        dist = DistContext(mesh=mesh, batch_axes=("data",))
        with mesh:
            y_ep, aux_ep = MOE.moe_block(p, x, cfg, dist)
        out["max_diff"] = float(jnp.max(jnp.abs(y_local - y_ep)))
        out["aux_local"] = float(aux_local); out["aux_ep"] = float(aux_ep)
    """)
    # capacity truncation order may differ slightly between 1-device and EP
    assert out["max_diff"] < 0.05
    assert abs(out["aux_local"] - out["aux_ep"]) < 0.2


def test_elastic_restore_onto_smaller_mesh(tmp_path):
    out = run_sub(f"""
        from repro.configs import get_config
        from repro.models.registry import get_model
        from repro.checkpoint.manager import CheckpointManager
        from repro.dist.sharding import DistContext, param_shardings
        from repro.dist.elastic import plan_mesh, build_mesh

        cfg = get_config("llama2_7b", reduced=True)
        api = get_model(cfg)
        params = api.init_params(jax.random.PRNGKey(0), cfg)
        ck = CheckpointManager({str(tmp_path)!r}, async_save=False)

        mesh8 = build_mesh(plan_mesh(8, model_parallel=4))
        dist8 = DistContext(mesh=mesh8, batch_axes=("data",))
        p8 = jax.device_put(params, param_shardings(params, dist8))
        ck.save(1, p8)

        # "lose" half the devices -> restore onto 4-device mesh
        mesh4 = build_mesh(plan_mesh(4, model_parallel=2))
        dist4 = DistContext(mesh=mesh4, batch_axes=("data",))
        p4 = ck.restore(params, shardings=param_shardings(params, dist4))
        d = jax.tree_util.tree_map(
            lambda a, b: float(jnp.max(jnp.abs(
                a.astype(jnp.float32) - b.astype(jnp.float32)))),
            params, p4)
        out["max_diff"] = max(jax.tree_util.tree_leaves(d))
        out["n_shards"] = len(jax.tree_util.tree_leaves(p4)[1]
                              .sharding.device_set)
    """)
    assert out["max_diff"] == 0.0
