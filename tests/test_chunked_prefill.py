"""Chunked prefill + two-deep dispatch (DESIGN.md §14): the cross-mode
differential conformance suite.

Chunking splits admitted prompts into token-budget chunks fed between
decode steps — pure scheduling, so greedy outputs must be BIT-IDENTICAL
chunked-on vs chunked-off across every serving mode (plain, chain-spec,
tree-spec, prefix-cache, mla_moe). On top of the digest grid: the chunk
planner's coverage property, leak-free paging under chunked admission,
mid-prefill preemption fold/resume losslessness, the strictly-fewer-
host-syncs pin for the two-deep loop, and the SLO ledger's TPOT-miss
prefill-interference attribution dropping to zero with chunking (the
ROADMAP's stated success metric, as a test).
"""
import dataclasses
import functools

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # pragma: no cover
    from _hyp import given, settings, st

from repro.configs import get_config
from repro.engine import (EngineConfig, InferenceEngine, SamplingParams,
                          Telemetry, plan_chunks)
from repro.engine.loadgen import SLO, SLOLedger, generate, make_source
from repro.engine.loadgen import WorkloadSpec
from repro.models.registry import get_model

from _engine_utils import ScriptedSource, by_rid, make_prompts, \
    shared_prompts

GREEDY = SamplingParams()


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("llama2_7b", reduced=True)
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, api, params


@functools.lru_cache(maxsize=2)
def _tiny_mla():
    """Reduced mla_moe cell, dropless routing (the repo's equivalence-
    check convention, test_models.py): capacity truncation depends on
    the flattened token count, which differs between a chunk feed and a
    monolithic prefill — dropless is what makes the pin exact."""
    cfg = get_config("deepseek_v2_236b", reduced=True)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@functools.lru_cache(maxsize=2)
def _draft(arch):
    from repro.core.model_compress import compress_draft
    if arch == "mla":
        cfg, params = _tiny_mla()
    else:
        cfg = get_config("llama2_7b", reduced=True)
        params = get_model(cfg).init_params(jax.random.PRNGKey(0), cfg)
    return compress_draft(params, cfg, profile="w4s75")


# ---------------------------------------------------------------------------
# the differential digest grid
# ---------------------------------------------------------------------------

def _mode_setup(mode, tiny):
    from repro.core.model_compress import draft_layers
    cfg, api, params = tiny
    ekw, dp = {}, None
    prompts = make_prompts(cfg.vocab, (9, 3, 13, 6, 11), seed=21)
    if mode == "mla":
        cfg, params = _tiny_mla()
        prompts = make_prompts(cfg.vocab, (9, 3, 13, 6), seed=21)
    elif mode in ("chain", "tree"):
        dp = _draft("plain")
        ekw["spec_draft_layers"] = draft_layers(cfg, "w4s75")
        if mode == "chain":
            ekw["spec_k"] = 2
        else:
            ekw["spec_fanout"] = (2, 2)
    elif mode == "prefix":
        ekw["prefix_cache"] = True
        # tails longer than the budget so the chunked run chunks TAILS
        # (first chunk starts at the shared boundary, DESIGN.md §14)
        prompts = shared_prompts(cfg.vocab, 8, [7, 0, 11], seed=22) \
            + make_prompts(cfg.vocab, (6,), seed=23)
    return cfg, params, prompts, ekw, dp


def _run_grid(mode, tiny, chunk):
    cfg, params, prompts, ekw, dp = _mode_setup(mode, tiny)
    eng = InferenceEngine(
        cfg, params,
        EngineConfig(num_slots=2, max_seq=32, page_size=4,
                     prefill_chunk_tokens=chunk, **ekw),
        GREEDY, draft_params=dp)
    for p in prompts:
        eng.submit(p.copy(), 6)
    out = eng.run()
    alc = eng.kv.allocator
    assert alc.num_free + alc.num_outstanding == eng.kv.num_pages
    return eng, out


@pytest.mark.parametrize("mode", ["plain", "chain", "tree", "prefix",
                                  "mla"])
def test_chunked_bit_identical(mode, tiny):
    """The tentpole pin: greedy token streams are bit-identical with
    chunked prefill on (budget 5) vs off, in every serving mode."""
    _, off = _run_grid(mode, tiny, 0)
    eng, on = _run_grid(mode, tiny, 5)
    assert by_rid(on) == by_rid(off)
    assert len(on["results"]) == len(off["results"]) >= 4
    # the chunked run must actually have chunked (multi-chunk prompts
    # exist in every mode's prompt set)
    reg = eng.tel.registry
    assert reg.counter("engine.prefill_chunks").value > 0
    assert reg.counter("engine.prefill_chunk_tokens").value > 0


def test_chunk_budget_one_token(tiny):
    """Degenerate budget 1 = one-token-per-boundary prompt feeding —
    the most interleavings possible, still bit-identical."""
    _, off = _run_grid("plain", tiny, 0)
    _, on = _run_grid("plain", tiny, 1)
    assert by_rid(on) == by_rid(off)


# ---------------------------------------------------------------------------
# chunk planner property (hypothesis)
# ---------------------------------------------------------------------------

@settings(max_examples=200, deadline=None)
@given(st.integers(1, 256), st.integers(0, 255), st.integers(-1, 64))
def test_plan_chunks_covers_exactly_once(prompt_len, start, budget):
    """For arbitrary (start, prompt_len, budget): chunks are contiguous,
    cover [start, prompt_len) exactly once, never exceed a positive
    budget, and the final chunk ends exactly at prompt_len."""
    start = start % prompt_len
    chunks = plan_chunks(start, prompt_len, budget)
    p = start
    for cs, cn in chunks:
        assert cs == p
        assert cn >= 1
        if budget > 0:
            assert cn <= budget
        p = cs + cn
    assert p == prompt_len
    if budget > 0:
        assert len(chunks) == -(-(prompt_len - start) // budget)
    else:
        assert len(chunks) == 1


# ---------------------------------------------------------------------------
# leak-free paging under chunked admission (PR 9 storm idiom)
# ---------------------------------------------------------------------------

@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 6))
def test_chunked_admission_leak_free(tiny, seed, budget):
    """Waves of mixed-length prompts churn through a pool sized for ~2
    resident requests with chunking on: refcount-weighted conservation
    holds at the end, every request drains fully, no page leaks."""
    cfg, api, params = tiny
    rng = np.random.default_rng(seed)
    lens = rng.integers(2, 14, size=8)
    prompts = make_prompts(cfg.vocab, lens, seed=seed % 997)
    eng = InferenceEngine(
        cfg, params,
        EngineConfig(num_slots=2, max_seq=32, page_size=4, num_pages=12,
                     prefill_chunk_tokens=budget),
        GREEDY)
    for p in prompts:
        eng.submit(p, 4)
    out = eng.run()
    assert len(out["results"]) == len(prompts)
    assert all(r["n_generated"] == 4 for r in out["results"])
    alc = eng.kv.allocator
    assert alc.num_free + alc.num_outstanding == eng.kv.num_pages
    assert alc.num_outstanding == 0


# ---------------------------------------------------------------------------
# mid-prefill preemption folds and resumes bit-identically
# ---------------------------------------------------------------------------

def test_midprefill_preemption_lossless(tiny):
    """A high-priority arrival lands while a low-priority prompt is
    mid-chunk: the PREFILLING victim (full remaining budget) is
    preempted first, its empty fold re-queues the original prompt, and
    the re-admission replays the chunk ladder — outputs bit-identical
    to an ample-pool run that never preempts."""
    cfg, api, params = tiny
    low_long = make_prompts(cfg.vocab, (12,), seed=31)[0]
    low_short = make_prompts(cfg.vocab, (4,), seed=32)[0]
    big = make_prompts(cfg.vocab, (10,), seed=33)[0]
    # short gets a SMALLER budget so the PREFILLING long prompt (full
    # remaining) is the strict choose_victims front-runner at poll 2
    sched = [(1, low_long, 6, 0), (1, low_short, 3, 0), (2, big, 16, 1)]

    def run(num_pages):
        eng = InferenceEngine(
            cfg, params,
            EngineConfig(num_slots=2, max_seq=32, page_size=4,
                         num_pages=num_pages, prefill_chunk_tokens=4),
            GREEDY)
        out = eng.run(source=ScriptedSource(sched))
        alc = eng.kv.allocator
        assert alc.num_free + alc.num_outstanding == eng.kv.num_pages
        return eng, out

    eng_amp, ample = run(16)             # everything fits, no pressure
    eng_prs, pressured = run(9)          # big can only fit by eviction
    assert eng_amp.metrics.summary()["preemptions"] == 0
    assert eng_prs.metrics.summary()["preemptions"] > 0
    # the victim was taken MID-CHUNK (the point of this test): the
    # 12-token prompt at budget 4 is still PREFILLING at poll 2
    reg = eng_prs.tel.registry
    assert reg.counter("resil.midprefill_preemptions").value > 0
    assert by_rid(pressured) == by_rid(ample)
    assert len(pressured["results"]) == 3


# ---------------------------------------------------------------------------
# two-deep dispatch: strictly fewer host syncs than segments
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [0, 4])
def test_two_deep_strictly_fewer_syncs(tiny, monkeypatch, chunk):
    """The old loop blocked once per decode segment plus once per
    prefill dispatch. The two-deep loop retires the trailing segment
    only, so its ``jax.block_until_ready`` count must be STRICTLY
    below that old-loop floor (counted from the tracer's spans)."""
    cfg, api, params = tiny
    counts = [0]
    real = jax.block_until_ready

    def counted(x):
        counts[0] += 1
        return real(x)

    tel = Telemetry(trace=True)
    eng = InferenceEngine(
        cfg, params,
        EngineConfig(num_slots=2, max_seq=32, page_size=4,
                     prefill_chunk_tokens=chunk),
        GREEDY, telemetry=tel)
    # two admission waves -> at least two decode segments
    for p in make_prompts(cfg.vocab, (9, 5, 11, 7), seed=41):
        eng.submit(p, 6)
    monkeypatch.setattr(jax, "block_until_ready", counted)
    eng.run()
    monkeypatch.setattr(jax, "block_until_ready", real)
    totals = tel.tracer.phase_totals()
    segments = totals.get("decode_segment", {}).get("count", 0)
    prefills = sum(totals.get(n, {}).get("count", 0)
                   for n in ("prefill", "prefill_tail", "prefill_chunk"))
    assert segments >= 2
    old_loop_floor = segments + prefills
    assert 0 < counts[0] < old_loop_floor


# ---------------------------------------------------------------------------
# SLO interference regression: the ROADMAP metric as a test
# ---------------------------------------------------------------------------

def _traced_workload_run(tiny, chunk):
    cfg, api, params = tiny
    # mixed prompt lengths, staggered decode budgets: slots free one at
    # a time, so each admission prefill lands inside a live co-resident
    # decode window (the interference being measured)
    spec = WorkloadSpec(process="poisson", rate=100.0, requests=8,
                        prompt_min=24, prompt_max=64, max_new_min=4,
                        max_new_max=16, seed=13)
    tel = Telemetry(trace=True)
    eng = InferenceEngine(
        cfg, params,
        EngineConfig(num_slots=2, max_seq=128,
                     prefill_chunk_tokens=chunk),
        GREEDY, telemetry=tel)
    out = eng.run(source=make_source(generate(spec, cfg.vocab)))
    return eng, tel, out


def test_chunking_zeroes_prefill_interference_attribution(tiny):
    """Seeded Poisson mixed-prompt-length workload: a monolithic
    admission prefill stalls co-resident decodes for its full duration
    in ONE inter-token gap; chunking at budget 8 bounds the longest
    stall to one chunk. Judged at a stall limit every chunked request
    meets (chunked max stall x1.5), the chunked run has ZERO prefill-
    attributed misses and the monolithic run at least one (DESIGN.md
    §11's interference attribution, driven to zero — the ROADMAP's
    stated success metric as a test). Outputs stay bit-identical
    between the two runs under load."""
    # warm the jit caches so compile time doesn't land inside spans
    _traced_workload_run(tiny, 8)
    _traced_workload_run(tiny, 0)
    eng_c, tel_c, out_c = _traced_workload_run(tiny, 8)
    eng_m, tel_m, out_m = _traced_workload_run(tiny, 0)
    assert by_rid(out_c) == by_rid(out_m)
    assert any(e.get("name") == "prefill_chunk"
               for e in tel_c.tracer.events)
    # derive the limit from the chunked run itself: every chunked
    # request meets it by construction, so its prefill-attributed miss
    # count is 0 by measure — the regression bites iff monolithic
    # serving stalls some decode past that bound (a 24..64-token
    # monolithic prefill span vs an 8-token chunk span leaves x1.5
    # plenty of separation)
    stalls = [v.stall_ms for v in SLOLedger(SLO(stall_ms=1e9)).judge(
        eng_c.metrics, tel_c.tracer) if v.stall_ms == v.stall_ms]
    lim = max(max(stalls) * 1.5, 0.05)
    led_c = SLOLedger(SLO(stall_ms=lim))
    led_c.judge(eng_c.metrics, tel_c.tracer)
    led_m = SLOLedger(SLO(stall_ms=lim))
    led_m.judge(eng_m.metrics, tel_m.tracer)
    assert led_c.summary().get("miss_phase_prefill", 0) == 0
    assert led_m.summary()["missed_stall"] > 0
    assert led_m.summary()["miss_phase_prefill"] > 0


# ---------------------------------------------------------------------------
# chunk spans + flow events land in the trace
# ---------------------------------------------------------------------------

def test_chunk_spans_in_trace(tiny):
    cfg, api, params = tiny
    tel = Telemetry(trace=True)
    eng = InferenceEngine(
        cfg, params,
        EngineConfig(num_slots=2, max_seq=32, page_size=4,
                     prefill_chunk_tokens=3),
        GREEDY, telemetry=tel)
    for p in make_prompts(cfg.vocab, (11, 8), seed=51):
        eng.submit(p, 4)
    eng.run()
    spans = [e for e in tel.tracer.events
             if e.get("ph") == "X" and e.get("name") == "prefill_chunk"]
    # 11 tokens at budget 3 -> 4 chunks; 8 -> 3 chunks; slots co-feed
    assert len(spans) >= 4
    done = sum(e["args"].get("completed", 0) for e in spans)
    assert done == 2                      # each prompt completes once
