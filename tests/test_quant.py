"""Property tests for per-group quantization (paper §3.1)."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # property tests skip; the rest of the file runs
    from _hyp import given, settings, st

from repro.core.quant import (QuantConfig, dequantize, fake_quant,
                              group_minmax_params, int8_symmetric_dequant,
                              int8_symmetric_quant, pack_int4, quantize,
                              unpack_int4)

S = settings(max_examples=20, deadline=None)


@S
@given(st.integers(1, 6).map(lambda i: 16 * i),
       st.integers(1, 4).map(lambda i: 8 * i),
       st.sampled_from([4, 8, 16, 32]),
       st.integers(0, 2 ** 31 - 1))
def test_quant_error_bounded_by_half_scale(k, n, g, seed):
    if k % g:
        k = (k // g + 1) * g
    w = jnp.asarray(np.random.default_rng(seed).normal(size=(n, k)),
                    jnp.float32)
    cfg = QuantConfig(bits=4, group_size=g)
    s, z = group_minmax_params(w, cfg)
    q = quantize(w, s, z, cfg)
    wd = dequantize(q, s, z, cfg)
    err = jnp.abs(w - wd).reshape(n, k // g, g)
    bound = (s / 2 + 1e-6)[..., None]
    assert bool((err <= bound).all())


@S
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([2, 3, 4]))
def test_quant_codes_in_range(seed, bits):
    w = jnp.asarray(np.random.default_rng(seed).normal(size=(8, 32)) * 10,
                    jnp.float32)
    cfg = QuantConfig(bits=bits, group_size=16)
    s, z = group_minmax_params(w, cfg)
    q = quantize(w, s, z, cfg)
    assert int(q.max()) <= (1 << bits) - 1
    assert int(q.min()) >= 0


@S
@given(st.integers(0, 2 ** 31 - 1))
def test_int4_pack_roundtrip_exact(seed):
    q = jnp.asarray(np.random.default_rng(seed).integers(
        0, 16, size=(5, 7, 10)), jnp.uint8)
    assert (unpack_int4(pack_int4(q)) == q).all()


def test_pack_halves_bytes():
    q = jnp.zeros((8, 64), jnp.uint8)
    assert pack_int4(q).nbytes == q.nbytes // 2


@S
@given(st.integers(0, 2 ** 31 - 1))
def test_fake_quant_idempotent(seed):
    """quantizing an already-dequantized tensor is exact (fixed point)."""
    w = jnp.asarray(np.random.default_rng(seed).normal(size=(4, 32)),
                    jnp.float32)
    cfg = QuantConfig(bits=4, group_size=16)
    w1 = fake_quant(w, cfg)
    w2 = fake_quant(w1, cfg)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), atol=1e-5)


def test_fake_quant_ste_gradient_close_to_identity():
    w = jnp.asarray(np.random.default_rng(1).normal(size=(4, 32)),
                    jnp.float32)
    cfg = QuantConfig(bits=4, group_size=16)
    g = jax.grad(lambda x: jnp.sum(fake_quant(x, cfg)))(w)
    # STE through round: gradient ~= 1 everywhere in-range
    assert float(jnp.mean(jnp.abs(g - 1.0))) < 0.2


@S
@given(st.integers(0, 2 ** 31 - 1))
def test_int8_symmetric_roundtrip(seed):
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(100,)) * 5,
                    jnp.float32)
    q, s = int8_symmetric_quant(x)
    xd = int8_symmetric_dequant(q, s)
    assert float(jnp.max(jnp.abs(x - xd))) <= float(s) / 2 + 1e-6
