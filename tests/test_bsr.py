"""BSR format tests: pack/unpack exactness, paper-format equivalence,
compression accounting, work-list coverage (paper §3.2, §3.5)."""
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # property tests skip; the rest of the file runs
    from _hyp import given, settings, st

from repro.core.bsr import (BSRMatrix, build_work_list, pack_dense,
                            pack_quantized, paper_bsr_nbytes, to_dense,
                            to_paper_bsr)
from repro.core.pruning import PruneConfig, group_mask
from repro.core.quant import QuantConfig, group_minmax_params, quantize
from repro.core.saliency import group_saliency

S = settings(max_examples=15, deadline=None)


def _random_case(seed, n=32, k=128, g=16, sparsity=0.5, balanced=True):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    gsal = group_saliency(jnp.square(w), g)
    gm = group_mask(gsal, PruneConfig(sparsity=sparsity, group_size=g,
                                      row_balanced=balanced))
    return w, gm


@S
@given(st.integers(0, 2**31 - 1), st.sampled_from([0.25, 0.5, 0.75]),
       st.booleans())
def test_pack_dense_matches_masked_quantized(seed, sparsity, balanced):
    g = 16
    w, gm = _random_case(seed, sparsity=sparsity, balanced=balanced)
    qcfg = QuantConfig(bits=4, group_size=g)
    bsr = pack_dense(w, gm, qcfg)
    dense = to_dense(bsr)
    # kept positions: quant error bounded; pruned positions: exactly zero
    mask = np.repeat(np.asarray(gm), g, axis=1)
    assert (np.asarray(dense)[~mask] == 0).all()
    err = np.abs(np.asarray(dense) - np.asarray(w))[mask]
    assert err.max() <= float(np.abs(np.asarray(w)).max()) / 15 + 1e-5


@S
@given(st.integers(0, 2**31 - 1))
def test_paper_bsr_roundtrip_counts(seed):
    w, gm = _random_case(seed, balanced=False)
    bsr = pack_dense(w, gm, QuantConfig(group_size=16))
    row_index, groups, values, scales, zeros = to_paper_bsr(bsr)
    gm_np = np.asarray(gm)
    # rowIndex prefix property (paper §3.2)
    assert row_index[0] == 0
    assert row_index[-1] == gm_np.sum()
    counts = np.diff(row_index)
    np.testing.assert_array_equal(counts, gm_np.sum(axis=1))
    # group columns are the kept columns, sorted per row
    for i in range(gm_np.shape[0]):
        cols = groups[row_index[i]:row_index[i + 1]]
        np.testing.assert_array_equal(np.sort(np.nonzero(gm_np[i])[0]), cols)


def test_compression_ratio_formula():
    """W4 S50 G16 paper-format compression vs fp16 ~= 16/(4+overhead)x."""
    w, gm = _random_case(0, n=64, k=256, sparsity=0.5)
    bsr = pack_dense(w, gm, QuantConfig(group_size=16))
    nbytes = paper_bsr_nbytes(*to_paper_bsr(bsr))
    fp16 = 2 * 64 * 256
    ratio = fp16 / nbytes
    # 4 bits + (2B scale + 1B zero + 2B idx)/16 elems = 6.5 bits/elem kept,
    # x2 from sparsity => ~4.9x vs fp16
    assert 4.0 < ratio < 6.0


@S
@given(st.integers(0, 2**31 - 1), st.booleans(),
       st.sampled_from([(8, 2), (16, 4), (8, 8)]))
def test_work_list_covers_every_group_once(seed, balanced, blocks):
    bn, bm = blocks
    w, gm = _random_case(seed, n=64, k=128, balanced=balanced)
    bsr = pack_dense(w, gm, QuantConfig(group_size=16))
    idx = np.asarray(bsr.idx)
    n, m = idx.shape
    # pad like ops.gqsa_gemv does
    npad = (-n) % bn
    mpad = (-m) % bm
    idx_p = np.pad(idx, ((0, npad), (0, mpad)), constant_values=-1)
    wl = build_work_list(jnp.asarray(idx_p), bn, bm)
    # every (row_block, chunk) with any useful slot appears exactly once
    seen = set(zip(np.asarray(wl.row_block).tolist(),
                   np.asarray(wl.chunk).tolist()))
    assert len(seen) == wl.n_items, "duplicate work items"
    nrb = idx_p.shape[0] // bn
    for r in range(nrb):
        blk = idx_p[r * bn:(r + 1) * bn]
        useful = int((blk >= 0).sum(axis=1).max())
        nch = max(1, -(-useful // bm))
        for c in range(nch):
            assert (r, c) in seen
    # first flags: exactly one per visited row block, on its first chunk
    rb = np.asarray(wl.row_block)
    fs = np.asarray(wl.first)
    for r in set(rb.tolist()):
        flags = fs[rb == r]
        assert flags[0] == 1 and flags[1:].sum() == 0


def test_pack_quantized_preserves_tuned_params():
    """E2E-OQP path: packing must keep trained (s, z) bit-exact."""
    rng = np.random.default_rng(3)
    n, k, g = 16, 64, 16
    w = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    qcfg = QuantConfig(group_size=g)
    s, z = group_minmax_params(w, qcfg)
    s = s * 1.1  # pretend these were fine-tuned
    q = quantize(w, s, z, qcfg)
    gm = jnp.asarray(rng.random((n, k // g)) < 0.5)
    gm = gm.at[:, 0].set(True)   # >=1 group per row
    bsr = pack_quantized(q, gm, s, z, group_size=g)
    # check kept groups' scale appear unchanged in the packed form
    idx = np.asarray(bsr.idx)
    sc = np.asarray(bsr.scale)
    s_np = np.asarray(s)
    for i in range(n):
        for j, c in enumerate(idx[i]):
            if c >= 0:
                assert sc[i, j] == s_np[i, c]
