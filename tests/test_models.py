"""Per-arch smoke tests (assignment requirement): instantiate the REDUCED
config of each family, run one forward + one train step + decode steps on
CPU; assert output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, list_archs
from repro.launch.steps import build_train_step, make_dist
from repro.models.registry import get_model, lm_loss
from repro.optim import adamw


def _batch(cfg, rng, b=2, s=32):
    batch = {"tokens": jax.random.randint(rng, (b, s), 0, cfg.vocab),
             "labels": jax.random.randint(rng, (b, s), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.ones((b, cfg.n_patches, cfg.d_model),
                                         jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.ones((b, cfg.n_frames, cfg.d_model),
                                   jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, reduced=True)
    api = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = api.init_params(rng, cfg)
    b, s = 2, 32
    batch = _batch(cfg, rng, b, s)

    logits, aux = api.forward(params, batch, cfg)
    exp_s = s + (cfg.n_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (b, exp_s, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"

    dist = make_dist(cfg, None)
    step = build_train_step(cfg, dist, adamw.AdamWConfig(lr=1e-3))
    opt = adamw.init_state(params)
    p2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    l0 = jax.tree_util.tree_leaves(params)[0]
    l1 = jax.tree_util.tree_leaves(p2)[0]
    assert not np.allclose(np.asarray(l0), np.asarray(l1))


@pytest.mark.parametrize("arch", ["llama2_7b", "deepseek_moe_16b",
                                  "deepseek_v2_236b", "zamba2_7b",
                                  "mamba2_130m", "seamless_m4t_large_v2",
                                  "llava_next_mistral_7b"])
def test_arch_decode_matches_forward(arch):
    """Teacher-forced decode logits must match full-forward logits."""
    import dataclasses
    cfg = get_config(arch, reduced=True)
    if cfg.moe is not None:
        # capacity-based dropping differs between full-seq routing and
        # 1-token decode; disable drops for the equivalence check
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    api = get_model(cfg)
    rng = jax.random.PRNGKey(1)
    params = api.init_params(rng, cfg)
    b, s = 2, 8
    batch = _batch(cfg, rng, b, s)
    logits_full, _ = api.forward(params, batch, cfg)
    if cfg.family == "vlm":
        pytest.skip("vlm decode starts after a patch prefix (covered by "
                    "smoke); positional alignment differs by n_patches")
    cache = api.init_cache(cfg, b, s + 1)
    if api.prime_cache:
        cache = api.prime_cache(params, batch["frames"], cache, cfg)
    outs = []
    for pos in range(s):
        tok = batch["tokens"][:, pos:pos + 1]
        lg, cache = api.decode_step(params, cache, tok, jnp.int32(pos), cfg)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_full),
                               rtol=5e-2, atol=5e-2)


def test_train_shapes_all_archs_listed():
    assert len(list_archs()) == 10
    assert len(ARCH_IDS) == 11  # + the paper's llama2-7b


def test_moe_aux_loss_nonzero_and_balanced_router():
    cfg = get_config("deepseek_moe_16b", reduced=True)
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    _, aux = api.forward(params, batch, cfg)
    # balanced-ish random routing gives aux ~= E * sum(f*P) ~= 1..E
    assert 0.5 < float(aux) < cfg.moe.n_experts


def test_mamba_chunked_equals_decode_recurrence():
    """SSD chunked scan == step-by-step recurrence (state-space duality)."""
    from repro.models import ssm as S
    cfg = get_config("mamba2_130m", reduced=True)
    rng = jax.random.PRNGKey(0)
    p = S.mamba_init(rng, cfg)
    b, s = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)) * 0.3
    y_full = S.mamba_block(p, x, cfg)
    cache = S.mamba_cache_init(cfg, b)
    ys = []
    for t in range(s):
        y, cache = S.mamba_decode(p, x[:, t:t + 1], cache, cfg)
        ys.append(y[:, 0])
    y_steps = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_steps), np.asarray(y_full),
                               rtol=2e-2, atol=2e-2)
