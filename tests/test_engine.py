"""Continuous-batching engine: paged-cache equivalence, page reuse,
backpressure, FIFO admission, sampling, metrics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.engine import (EngineConfig, InferenceEngine, PageAllocator,
                          PagedKVCache, SamplingParams, Scheduler, sample)
from repro.models import transformer as T
from repro.models.registry import get_model


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("llama2_7b", reduced=True)
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, api, params


def _prompts(vocab, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=l).astype(np.int32) for l in lens]


# ---------------------------------------------------------------------------
# paged KV cache vs contiguous cache
# ---------------------------------------------------------------------------

def test_paged_decode_logits_match_contiguous(tiny):
    """Same tokens, same positions: paged view and contiguous cache must
    produce identical decode logits."""
    cfg, api, params = tiny
    B, PS, MAXSEQ = 2, 4, 24
    MP = MAXSEQ // PS
    prompts = _prompts(cfg.vocab, (5, 9))
    S = max(len(p) for p in prompts)
    toks = np.zeros((B, S), np.int32)
    lens = np.asarray([len(p) for p in prompts], np.int32)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p

    # contiguous: feed prompts with per-slot positions
    cache = api.init_cache(cfg, B, MAXSEQ)
    for s in range(S):
        _, cache = T.decode_step(params, cache, jnp.asarray(toks[:, s:s+1]),
                                 jnp.full((B,), s, jnp.int32), cfg)
    # paged: one batched prefill
    pcache = T.init_paged_cache(cfg, B * MP, PS)
    bt = jnp.asarray(np.arange(B * MP, dtype=np.int32).reshape(B, MP))
    logits_pf, pcache = T.prefill(params, pcache, jnp.asarray(toks),
                                  jnp.asarray(lens), bt, cfg)

    # prefill last-token logits == full forward last-token logits
    logits_fwd, _ = T.forward(params, jnp.asarray(toks), cfg)
    ref = np.stack([np.asarray(logits_fwd)[i, lens[i] - 1]
                    for i in range(B)])
    np.testing.assert_allclose(np.asarray(logits_pf)[:, 0], ref,
                               rtol=1e-5, atol=1e-5)

    # one decode step at per-slot positions: paged == contiguous
    nxt = jnp.asarray(np.argmax(ref, -1)[:, None].astype(np.int32))
    lg_c, _ = T.decode_step(params, cache, nxt, jnp.asarray(lens), cfg)
    lg_p, _ = T.decode_step(params, pcache, nxt, jnp.asarray(lens), cfg,
                            block_tables=bt)
    np.testing.assert_allclose(np.asarray(lg_c), np.asarray(lg_p),
                               rtol=1e-5, atol=1e-5)


def test_engine_matches_naive_greedy_reference(tiny):
    """End-to-end: engine generations (through eviction/refill) equal a
    naive full-forward greedy loop, token for token."""
    cfg, api, params = tiny
    MAX_NEW = 4
    prompts = _prompts(cfg.vocab, (5, 9, 4, 7), seed=3)

    def ref_generate(prompt):
        toks = list(prompt)
        out = []
        for _ in range(MAX_NEW):
            logits, _ = api.forward(params,
                                    {"tokens": jnp.asarray([toks])}, cfg)
            nxt = int(jnp.argmax(logits[0, -1]))
            out.append(nxt)
            toks.append(nxt)
        return out

    eng = InferenceEngine(cfg, params,
                          EngineConfig(num_slots=2, max_seq=16, page_size=4))
    rids = [eng.submit(p, MAX_NEW) for p in prompts]
    res = eng.run()
    by_rid = {r["rid"]: list(r["tokens"]) for r in res["results"]}
    for rid, p in zip(rids, prompts):
        assert by_rid[rid] == ref_generate(p)


# ---------------------------------------------------------------------------
# allocator: reuse + backpressure
# ---------------------------------------------------------------------------

def test_page_allocator_reuse():
    a = PageAllocator(4)
    p1 = a.alloc(3)
    assert len(set(p1)) == 3
    assert a.num_free == 1 and not a.can_alloc(2)
    a.free(p1)
    assert a.num_free == 4
    # freed pages come back: a full drain hands out every page exactly once
    p2 = a.alloc(4)
    assert sorted(p2) == [0, 1, 2, 3]
    with pytest.raises(RuntimeError):
        a.alloc(1)


def test_pages_reused_across_requests(tiny):
    """Pool sized for ONE resident request; four requests stream through by
    reusing the freed pages; pool drains back to full."""
    cfg, api, params = tiny
    eng = InferenceEngine(
        cfg, params,
        EngineConfig(num_slots=2, max_seq=16, page_size=4, num_pages=4))
    for p in _prompts(cfg.vocab, (5, 6, 7, 5)):
        eng.submit(p, 4)   # 9-11 tokens -> 3 pages: only one fits at a time
    res = eng.run()
    assert len(res["results"]) == 4
    assert eng.kv.allocator.num_free == 4
    assert all(r["n_generated"] == 4 for r in res["results"])


def test_out_of_pages_backpressure(tiny):
    """Scheduler admits the head only while pages last, and never lets a
    later request bypass a blocked head."""
    cfg, api, params = tiny
    kv = PagedKVCache(cfg, api, num_slots=3, max_seq=16, page_size=4,
                      num_pages=3)
    sch = Scheduler(3, kv, max_seq=16)
    r0 = sch.submit(np.zeros(8, np.int32), 4)    # 12 tokens -> 3 pages
    r1 = sch.submit(np.zeros(4, np.int32), 4)    # 8 tokens  -> 2 pages
    admitted = sch.admit()
    assert [r.rid for r in admitted] == [r0]     # pool exhausted
    assert sch.admit() == []                     # r1 backpressured, queued
    assert sch.waiting[0].rid == r1
    sch.step_decoded()
    sch.finish(sch.slots[admitted[0].slot].request)
    admitted2 = sch.admit()                      # pages freed -> r1 admitted
    assert [r.rid for r in admitted2] == [r1]
    assert kv.allocator.num_free == 1


def test_oversized_request_rejected(tiny):
    cfg, api, params = tiny
    eng = InferenceEngine(cfg, params,
                          EngineConfig(num_slots=1, max_seq=16, page_size=4))
    with pytest.raises(ValueError):
        eng.submit(np.zeros(14, np.int32), 4)    # 18 > max_seq


# ---------------------------------------------------------------------------
# FIFO admission (regression: the seed loop served LIFO via queue.pop())
# ---------------------------------------------------------------------------

def test_fifo_admission_order(tiny):
    cfg, api, params = tiny
    eng = InferenceEngine(cfg, params,
                          EngineConfig(num_slots=1, max_seq=16, page_size=4))
    rids = [eng.submit(p, 2) for p in _prompts(cfg.vocab, (4, 5, 6, 4, 5))]
    eng.run()
    # one slot => service order IS admission order; must equal arrival order
    assert eng.scheduler.admission_order == rids


def test_fifo_under_backpressure(tiny):
    """Even when a later (smaller) request WOULD fit, the blocked head goes
    first once pages free up."""
    cfg, api, params = tiny
    kv = PagedKVCache(cfg, api, num_slots=2, max_seq=16, page_size=4,
                      num_pages=4)
    sch = Scheduler(2, kv, max_seq=16)
    r0 = sch.submit(np.zeros(8, np.int32), 4)    # 3 pages
    r1 = sch.submit(np.zeros(8, np.int32), 4)    # 3 pages (doesn't fit)
    r2 = sch.submit(np.zeros(2, np.int32), 2)    # 1 page (WOULD fit)
    assert [r.rid for r in sch.admit()] == [r0]
    assert sch.admit() == []                     # r2 must NOT bypass r1
    sch.finish(sch.slots[0].request)
    assert [r.rid for r in sch.admit()] == [r1, r2]


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------

def test_sampling_greedy_and_filters():
    rng = jax.random.PRNGKey(0)
    logits = jnp.asarray([[1.0, 3.0, 2.0, -1.0],
                          [0.0, 0.1, 5.0, 4.9]])
    g = sample(logits, rng, SamplingParams())
    np.testing.assert_array_equal(np.asarray(g), [1, 2])
    # top_k=1 == greedy regardless of temperature
    t1 = sample(logits, rng, SamplingParams(temperature=5.0, top_k=1))
    np.testing.assert_array_equal(np.asarray(t1), [1, 2])
    # tiny top_p keeps only the argmax
    tp = sample(logits, rng, SamplingParams(temperature=1.0, top_p=1e-6))
    np.testing.assert_array_equal(np.asarray(tp), [1, 2])
    # temperature sampling stays inside the top-k support
    draws = [int(sample(logits, jax.random.PRNGKey(i),
                        SamplingParams(temperature=1.0, top_k=2))[0])
             for i in range(20)]
    assert set(draws) <= {1, 2}


def test_engine_temperature_sampling_runs(tiny):
    cfg, api, params = tiny
    eng = InferenceEngine(
        cfg, params, EngineConfig(num_slots=2, max_seq=16, page_size=4),
        SamplingParams(temperature=0.8, top_k=16, top_p=0.95))
    for p in _prompts(cfg.vocab, (4, 6)):
        eng.submit(p, 4)
    res = eng.run()
    assert len(res["results"]) == 2
    for r in res["results"]:
        assert r["tokens"].shape == (4,)
        assert (r["tokens"] >= 0).all() and (r["tokens"] < cfg.vocab).all()


# ---------------------------------------------------------------------------
# speculative decoding (engine/spec/): losslessness + KV rollback hygiene
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def draft_sets(tiny):
    """Draft parameter sets off the same checkpoint: near-target (w4,
    high acceptance), aggressive (w4s75, frequent rejections), and
    depth-pruned (w4l50: half the layers — LayerSkip-style)."""
    from repro.core.model_compress import compress_draft
    cfg, api, params = tiny
    return {p: compress_draft(params, cfg, profile=p)
            for p in ("w4", "w4s75", "w4l50")}


def _run_engine(cfg, params, prompts, max_new, spec_k=0, draft=None,
                sampling=SamplingParams(), **ecfg):
    eng = InferenceEngine(
        cfg, params,
        EngineConfig(num_slots=2, max_seq=24, page_size=4, spec_k=spec_k,
                     **ecfg),
        sampling, draft_params=draft)
    rids = [eng.submit(p, max_new) for p in prompts]
    res = eng.run()
    return eng, rids, res


@pytest.mark.parametrize("profile", ["w4", "w4s75", "w4l50"])
def test_spec_greedy_lossless(tiny, draft_sets, profile):
    """Greedy speculative output is token-for-token identical to greedy
    non-speculative output — for a high-acceptance, a high-rejection and
    a depth-pruned draft (losslessness cannot depend on draft quality)."""
    from repro.core.model_compress import draft_layers
    cfg, api, params = tiny
    prompts = _prompts(cfg.vocab, (5, 9, 4, 7), seed=3)
    _, rids0, res0 = _run_engine(cfg, params, prompts, 6)
    eng, rids1, res1 = _run_engine(
        cfg, params, prompts, 6, spec_k=3, draft=draft_sets[profile],
        spec_draft_layers=draft_layers(cfg, profile))
    by0 = {r["rid"]: list(r["tokens"]) for r in res0["results"]}
    by1 = {r["rid"]: list(r["tokens"]) for r in res1["results"]}
    for r0, r1 in zip(rids0, rids1):
        assert by0[r0] == by1[r1]
    m = res1["metrics"]
    assert m["spec_rounds"] > 0 and m["draft_proposed"] > 0
    assert 0.0 <= m["acceptance_rate"] <= 1.0


def test_spec_kv_rollback_leak_free(tiny, draft_sets):
    """After any mix of accept/reject rounds and request completions
    (pool sized so requests stream through a single resident slot), every
    page returns to the allocator: rollback is positional only — no page
    churn on partial rejection, no leaks at completion."""
    cfg, api, params = tiny
    eng = InferenceEngine(
        cfg, params,
        EngineConfig(num_slots=2, max_seq=16, page_size=4, num_pages=5,
                     spec_k=3),
        SamplingParams(), draft_params=draft_sets["w4s75"])
    initial_free = eng.kv.allocator.num_free
    assert initial_free == 5
    for p in _prompts(cfg.vocab, (5, 6, 7, 5)):
        eng.submit(p, 4)   # 9-11 tokens + lookahead -> 4 pages: one resident
    res = eng.run()
    assert len(res["results"]) == 4
    assert all(r["n_generated"] == 4 for r in res["results"])
    assert eng.kv.allocator.num_free == initial_free


def test_spec_temperature_sampling_runs(tiny, draft_sets):
    """Rejection sampling path (temperature > 0): correct budgets, valid
    tokens, sane acceptance accounting."""
    cfg, api, params = tiny
    prompts = _prompts(cfg.vocab, (4, 6, 5), seed=11)
    eng, _, res = _run_engine(
        cfg, params, prompts, 5, spec_k=4, draft=draft_sets["w4"],
        sampling=SamplingParams(temperature=0.8, top_k=16))
    assert len(res["results"]) == 3
    for r in res["results"]:
        assert r["tokens"].shape == (5,)
        assert (r["tokens"] >= 0).all() and (r["tokens"] < cfg.vocab).all()
    m = res["metrics"]
    assert m["draft_accepted"] <= m["draft_proposed"]
    assert eng.kv.allocator.num_free == eng.kv.num_pages


def test_spec_requires_draft_params(tiny):
    cfg, api, params = tiny
    with pytest.raises(ValueError):
        InferenceEngine(cfg, params, EngineConfig(spec_k=2))


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_metrics_reported(tiny):
    cfg, api, params = tiny
    eng = InferenceEngine(cfg, params,
                          EngineConfig(num_slots=2, max_seq=16, page_size=4))
    for p in _prompts(cfg.vocab, (4, 6, 5)):
        eng.submit(p, 4)
    m = eng.run()["metrics"]
    assert m["requests"] == 3 and m["tokens"] == 12
    assert m["tok_per_s"] > 0
    for k in ("ttft_ms_p50", "ttft_ms_p99", "tpot_ms_p50",
              "latency_ms_p99"):
        assert np.isfinite(m[k]) and m[k] >= 0
    assert m["ttft_ms_p50"] <= m["ttft_ms_p99"] + 1e-9
