"""Engine observability layer (DESIGN.md §10): span tracer + Chrome
trace export, per-request flow events, streaming-histogram quantile
bounds, registry wiring, zero-overhead-when-off (no extra device syncs).
"""
import json

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # pragma: no cover
    from _hyp import given, settings, st

from repro.configs import get_config
from repro.engine import (EngineConfig, InferenceEngine, MetricsRegistry,
                          SpanTracer, StreamingHistogram, Telemetry)
from repro.engine.telemetry import NULL_SPAN, TID_ENGINE
from repro.models.registry import get_model

S = settings(max_examples=30, deadline=None)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("llama2_7b", reduced=True)
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, api, params


def _prompts(vocab, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=l).astype(np.int32) for l in lens]


def _run(cfg, params, tel, *, n_req=4, max_new=6, slots=2, max_seq=32,
         spec_k=0, draft=None, dlayers=None):
    eng = InferenceEngine(
        cfg, params,
        EngineConfig(num_slots=slots, max_seq=max_seq, spec_k=spec_k,
                     spec_draft_layers=dlayers),
        draft_params=draft, telemetry=tel)
    for p in _prompts(cfg.vocab, tuple(4 + i % 3 for i in range(n_req))):
        eng.submit(p, max_new)
    return eng, eng.run()


# ---------------------------------------------------------------------------
# registry primitives
# ---------------------------------------------------------------------------

def test_registry_get_or_create():
    reg = MetricsRegistry()
    c = reg.counter("a.b")
    c.inc()
    c.inc(3)
    assert reg.counter("a.b") is c and c.value == 4
    g = reg.gauge("g")
    g.set(2)
    assert reg.gauge("g") is g and g.value == 2.0
    h = reg.histogram("h")
    h.record(5.0)
    assert reg.histogram("h") is h and h.count == 1
    snap = reg.snapshot()
    assert snap["a.b"] == 4 and snap["g"] == 2.0
    assert snap["h.count"] == 1 and snap["h.p50"] == 5.0


def test_histogram_empty_and_single():
    h = StreamingHistogram()
    assert np.isnan(h.quantile(50)) and np.isnan(h.mean)
    h.record(7.25)
    # single sample: every quantile is that sample, exactly (clamped to
    # [min, max])
    for q in (0, 50, 99, 100):
        assert h.quantile(q) == 7.25
    assert h.mean == 7.25


def test_histogram_zero_bucket_exact():
    h = StreamingHistogram()
    for _ in range(10):
        h.record(0.0)
    h.record(100.0)
    assert h.quantile(50) == 0.0
    assert h.quantile(100) == 100.0


def test_histogram_monotone_in_q():
    h = StreamingHistogram()
    xs = np.random.default_rng(1).uniform(0.01, 1e4, 300)
    for v in xs:
        h.record(v)
    qs = [h.quantile(q) for q in range(0, 101, 5)]
    assert all(a <= b + 1e-12 for a, b in zip(qs, qs[1:]))


def _check_quantile_bound(xs, qs):
    h = StreamingHistogram()
    for v in xs:
        h.record(v)
    for q in qs:
        exact = float(np.percentile(xs, q, method="lower"))
        got = h.quantile(q)
        if exact == 0.0:
            assert got == 0.0
        else:
            assert abs(got - exact) / exact <= h.rel_error_bound, (
                f"q={q}: {got} vs exact {exact} "
                f"(bound {h.rel_error_bound})")


def test_histogram_quantile_bound_grid():
    """Deterministic version of the property test (runs even without
    hypothesis): quantiles stay within rel_error_bound of the exact
    order statistic across distributions spanning decades."""
    rng = np.random.default_rng(0)
    cases = [
        rng.lognormal(2, 1.5, 1000),
        rng.uniform(1e-3, 1e3, 500),
        np.full(100, 42.0),
        rng.exponential(250.0, 733),
        np.concatenate([np.zeros(50), rng.uniform(1, 100, 50)]),
    ]
    for xs in cases:
        _check_quantile_bound(xs, qs=(0, 10, 25, 50, 75, 90, 99, 100))


@S
@given(st.lists(st.floats(min_value=0.0, max_value=1e9,
                          allow_nan=False, allow_infinity=False),
                min_size=1, max_size=400),
       st.integers(min_value=0, max_value=100))
def test_histogram_quantile_bound_property(xs, q):
    _check_quantile_bound(np.asarray(xs, np.float64), qs=(q,))


# ---------------------------------------------------------------------------
# tracer mechanics
# ---------------------------------------------------------------------------

def test_disabled_tracer_is_null():
    tr = SpanTracer(enabled=False)
    assert tr.span("x") is NULL_SPAN
    assert tr.annotate("x") is NULL_SPAN
    with tr.span("x") as sp:
        sp.set(tokens=3)
    tr.instant("i")
    tr.flow_point(0, "enqueue")
    tr.async_begin("w", 0)
    tr.async_end("w", 0)
    assert tr.events == []


def test_tracer_records_spans_and_args():
    tr = SpanTracer(enabled=True)
    with tr.span("outer") as sp:
        sp.set(tokens=5)
        with tr.span("inner", cat="dispatch"):
            pass
    assert [e["name"] for e in tr.events] == ["inner", "outer"]
    outer = tr.events[1]
    assert outer["ph"] == "X" and outer["args"]["tokens"] == 5
    assert tr.events[0]["cat"] == "dispatch"
    totals = tr.phase_totals()
    assert totals["outer"]["count"] == 1 and totals["outer"]["ms"] >= 0


# ---------------------------------------------------------------------------
# end-to-end: traced engine runs
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def traced_plain(tiny):
    cfg, api, params = tiny
    tel = Telemetry(trace=True)
    eng, out = _run(cfg, params, tel)
    return tel, eng, out


def _export(tel, tmp_path):
    path = tel.tracer.export(tmp_path / "trace.json")
    return json.loads(path.read_text())


def test_trace_chrome_format(traced_plain, tmp_path):
    tel, eng, out = traced_plain
    doc = _export(tel, tmp_path)
    assert isinstance(doc["traceEvents"], list) and doc["traceEvents"]
    for ev in doc["traceEvents"]:
        assert "ph" in ev and "pid" in ev and "name" in ev
        if ev["ph"] == "X":
            assert ev["dur"] >= 0.0 and ev["ts"] >= 0.0
    # thread metadata present (Perfetto track names)
    assert any(e["ph"] == "M" and e["name"] == "thread_name"
               for e in doc["traceEvents"])
    names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert {"admit", "prefill", "decode_segment", "sync",
            "evict"} <= names


def test_trace_spans_monotonic_and_nested(traced_plain, tmp_path):
    """Complete events on one tid must form a proper nesting (a stack):
    sorted by start, each span ends before every enclosing one."""
    tel, eng, out = traced_plain
    doc = _export(tel, tmp_path)
    by_tid = {}
    for ev in doc["traceEvents"]:
        if ev["ph"] == "X":
            by_tid.setdefault(ev["tid"], []).append(ev)
    assert by_tid, "no complete events"
    for tid, evs in by_tid.items():
        evs.sort(key=lambda e: (e["ts"], -e["dur"]))
        stack = []
        for ev in evs:
            t0, t1 = ev["ts"], ev["ts"] + ev["dur"]
            while stack and t0 >= stack[-1] - 1e-6:
                stack.pop()
            for end in stack:
                assert t1 <= end + 1e-6, (
                    f"span {ev['name']} [{t0},{t1}] crosses an "
                    f"enclosing span ending at {end}")
            stack.append(t1)


def test_trace_flow_covers_lifecycle(traced_plain, tmp_path):
    """Every request's flow arrow runs s -> t... -> f, and every
    submitted rid has one."""
    tel, eng, out = traced_plain
    doc = _export(tel, tmp_path)
    flows = {}
    for ev in doc["traceEvents"]:
        if ev["ph"] in ("s", "t", "f"):
            flows.setdefault(ev["id"], []).append(ev)
    assert set(flows) == {r["rid"] for r in out["results"]}
    for rid, evs in flows.items():
        phs = [e["ph"] for e in evs]
        assert phs[0] == "s" and phs[-1] == "f"
        assert all(p == "t" for p in phs[1:-1])
        phases = [e["args"]["phase"] for e in evs]
        assert phases[0] == "enqueue" and phases[-1] == "finish"
        assert "prefill" in phases and "decode_segment" in phases
        ts = [e["ts"] for e in evs]
        assert ts == sorted(ts)


def test_trace_tokens_reconcile_with_metrics(traced_plain):
    """Span-attached token counts must sum to the metrics totals: the
    trace and the summary are two views of the same run."""
    tel, eng, out = traced_plain
    span_tokens = sum(e["args"].get("tokens", 0)
                      for e in tel.tracer.events if e["ph"] == "X"
                      and e["name"] in ("prefill", "decode_segment"))
    assert span_tokens == out["metrics"]["tokens"]


def test_trace_tokens_reconcile_spec(tiny):
    from repro.core.model_compress import compress_draft, draft_layers
    cfg, api, params = tiny
    draft = compress_draft(params, cfg, profile="w4l50")
    dl = draft_layers(cfg, "w4l50")
    tel = Telemetry(trace=True)
    eng, out = _run(cfg, params, tel, spec_k=3, draft=draft, dlayers=dl)
    span_tokens = sum(e["args"].get("tokens", 0)
                      for e in tel.tracer.events if e["ph"] == "X"
                      and e["name"] in ("prefill", "spec_segment"))
    assert span_tokens == out["metrics"]["tokens"]
    names = {e["name"] for e in tel.tracer.events if e["ph"] == "X"}
    assert {"draft", "verify", "spec_segment"} <= names
    # per-round draft/verify spans are dispatch-only by contract
    assert all(e["cat"] == "dispatch" for e in tel.tracer.events
               if e["ph"] == "X" and e["name"] in ("draft", "verify"))


# ---------------------------------------------------------------------------
# overhead-when-off: tracing must not change the sync structure
# ---------------------------------------------------------------------------

def test_tracing_adds_no_device_syncs(tiny, monkeypatch):
    """Pin the zero-extra-syncs guarantee: the engine calls
    ``jax.block_until_ready`` the same number of times with tracing on
    and off (the tracer only reads the host clock at existing sync
    points)."""
    cfg, api, params = tiny
    counts = {}
    real = jax.block_until_ready

    def counted(label):
        def wrapper(x):
            counts[label] += 1
            return real(x)
        return wrapper

    for label, trace in (("off", False), ("on", True)):
        counts[label] = 0
        monkeypatch.setattr(jax, "block_until_ready", counted(label))
        _run(cfg, params, Telemetry(trace=trace))
    assert counts["on"] == counts["off"] > 0


def test_disabled_telemetry_records_no_events(tiny):
    cfg, api, params = tiny
    tel = Telemetry()                      # defaults: everything off
    eng, out = _run(cfg, params, tel)
    assert tel.tracer.events == []
    # the registry still accumulates (counters/gauges are always cheap)
    snap = tel.registry.snapshot()
    assert snap["engine.requests_finished"] == out["metrics"]["requests"]
    assert snap["sched.queue_depth"] == 0
    assert snap["kv.pages_free"] == snap["kv.num_pages"]


# ---------------------------------------------------------------------------
# metrics summary stability + registry wiring through the engine
# ---------------------------------------------------------------------------

def test_summary_keys_and_queue_wait(tiny):
    cfg, api, params = tiny
    eng, out = _run(cfg, params, Telemetry())
    m = out["metrics"]
    for k in ("requests", "tokens", "seconds", "tok_per_s",
              "decode_steps", "ttft_ms_p50", "ttft_ms_p99",
              "tpot_ms_p50", "tpot_ms_p99", "latency_ms_p50",
              "latency_ms_p99", "itl_ms_mean", "spec_rounds",
              "draft_proposed", "draft_accepted", "acceptance_rate",
              "accepted_len_mean", "verify_tokens",
              "queue_wait_ms_p50", "queue_wait_ms_p99"):
        assert k in m, k
    assert np.isfinite(m["queue_wait_ms_p50"])
    assert m["queue_wait_ms_p50"] <= m["queue_wait_ms_p99"] + 1e-9
    assert "queue p50" in eng.metrics.format_summary()


def test_engine_registry_gauges_and_counters(tiny):
    cfg, api, params = tiny
    tel = Telemetry()
    eng, out = _run(cfg, params, tel, n_req=5)
    snap = tel.registry.snapshot()
    assert snap["sched.submitted"] == 5
    assert snap["sched.admissions"] == 5 == snap["sched.evictions"]
    assert snap["kv.page_allocs"] == snap["kv.page_frees"] > 0
    assert snap["kv.occupancy"] == 0.0
    assert snap["engine.queue_wait_ms.count"] == 5
    assert snap["jit.decode_retraces"] >= 0


def test_stats_interval_emits_line(tiny, capsys):
    cfg, api, params = tiny
    _run(cfg, params, Telemetry(stats_interval_s=1e-9))
    lines = [l for l in capsys.readouterr().out.splitlines()
             if l.startswith("[stats] ")]
    assert lines and "pages_free" in lines[0] and "queue" in lines[0]
