"""Pallas kernels vs pure-jnp oracles: shape / dtype / sparsity sweeps in
interpret mode (CPU), per the assignment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bsr import pack_dense
from repro.core.pruning import PruneConfig, group_mask
from repro.core.quant import (QuantConfig, group_minmax_params, pack_int4,
                              quantize)
from repro.core.saliency import group_saliency
from repro.kernels import ops, ref


def _bsr_case(seed, n, k, g, sparsity, balanced=True):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    gm = group_mask(group_saliency(jnp.square(w), g),
                    PruneConfig(sparsity=sparsity, group_size=g,
                                row_balanced=balanced))
    return w, pack_dense(w, gm, QuantConfig(bits=4, group_size=g))


@pytest.mark.parametrize("n,k,g", [(64, 128, 16), (96, 256, 16),
                                   (128, 128, 8), (32, 512, 32)])
@pytest.mark.parametrize("sparsity", [0.25, 0.5])
def test_gemv_shapes_sparsities(n, k, g, sparsity):
    w, bsr = _bsr_case(0, n, k, g, sparsity)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(2, k)), jnp.float32)
    y_ref = ref.gqsa_gemv_ref(x, bsr)
    y_ker = ops.gqsa_gemv(x, bsr, use_pallas=True, interpret=True,
                          block_n=32, block_m=4)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("balanced", [True, False])
def test_gemv_ragged_rows_task_centric(balanced):
    """Unbalanced (paper-faithful global-threshold) rows exercise the
    Stream-K-style work list with variable chunks per row block."""
    w, bsr = _bsr_case(2, 64, 256, 16, 0.6, balanced=balanced)
    x = jnp.asarray(np.random.default_rng(3).normal(size=(1, 256)),
                    jnp.float32)
    y_ref = ref.gqsa_gemv_ref(x, bsr)
    y_ker = ops.gqsa_gemv(x, bsr, use_pallas=True, interpret=True,
                          block_n=16, block_m=2)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16])
def test_gemv_dtypes(xdtype):
    w, bsr = _bsr_case(4, 64, 128, 16, 0.5)
    x = jnp.asarray(np.random.default_rng(5).normal(size=(4, 128))).astype(
        xdtype)
    y_ref = ref.gqsa_gemv_ref(x, bsr)
    y_ker = ops.gqsa_gemv(x, bsr, use_pallas=True, interpret=True,
                          block_n=32, block_m=4)
    np.testing.assert_allclose(np.asarray(y_ker, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_gemv_equals_dense_matmul_on_decompressed():
    w, bsr = _bsr_case(6, 64, 128, 16, 0.5)
    from repro.core.bsr import to_dense
    x = jnp.asarray(np.random.default_rng(7).normal(size=(3, 128)),
                    jnp.float32)
    y = ops.gqsa_gemv(x, bsr, use_pallas=True, interpret=True,
                      block_n=32, block_m=4)
    y_dense = x @ to_dense(bsr).T
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_dense),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("t,n,k,g", [(8, 64, 128, 16), (16, 32, 256, 32),
                                     (64, 128, 128, 16)])
def test_w4_matmul_shapes(t, n, k, g):
    rng = np.random.default_rng(8)
    w = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    qcfg = QuantConfig(bits=4, group_size=g)
    s, z = group_minmax_params(w, qcfg)
    qw = pack_int4(quantize(w, s, z, qcfg))
    x = jnp.asarray(rng.normal(size=(t, k)), jnp.float32)
    y_ref = ref.w4_matmul_ref(x, qw, s, z, g)
    y_ker = ops.w4_matmul(x, qw, s, z, group_size=g, use_pallas=True,
                          interpret=True, block_t=8, block_n=32, block_k=64)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_w4_matmul_unaligned_shapes_padded():
    rng = np.random.default_rng(9)
    n, k, g, t = 48, 160, 16, 5
    w = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    qcfg = QuantConfig(bits=4, group_size=g)
    s, z = group_minmax_params(w, qcfg)
    qw = pack_int4(quantize(w, s, z, qcfg))
    x = jnp.asarray(rng.normal(size=(t, k)), jnp.float32)
    y_ref = ref.w4_matmul_ref(x, qw, s, z, g)
    y_ker = ops.w4_matmul(x, qw, s, z, group_size=g, use_pallas=True,
                          interpret=True, block_t=8, block_n=32, block_k=160)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_bytes_models_monotone_in_sparsity():
    """fig6 premise: higher sparsity => fewer bytes => faster decode."""
    sizes = []
    for s in (0.2, 0.4, 0.6):
        _, bsr = _bsr_case(1, 128, 512, 16, s)
        sizes.append(ops.gemv_bytes_model(bsr)["total_bytes"])
    assert sizes[0] > sizes[1] > sizes[2]
    dense = ops.dense_bytes_model(128, 512, bits=16)["total_bytes"]
    w4 = ops.dense_bytes_model(128, 512, bits=4, group_size=16)["total_bytes"]
    assert dense > w4 > sizes[1]


@pytest.mark.parametrize("b,s,kh,r,d,bs", [(2, 128, 2, 4, 64, 32),
                                           (1, 256, 4, 2, 128, 64),
                                           (2, 96, 1, 8, 32, 32)])
def test_kv_decode_attention_kernel(b, s, kh, r, d, bs):
    """int8-KV decode attention kernel vs oracle (EXPERIMENTS §Perf cell C)."""
    from repro.kernels.ref import kv_decode_attention_ref
    from repro.models.layers import quantize_kv
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (b, kh, r, d))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (b, s, kh, d))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (b, s, kh, d))
    k_i8, k_sc = quantize_kv(k)
    v_i8, v_sc = quantize_kv(v)
    ln = jnp.int32(s - 17)
    o_ref = kv_decode_attention_ref(q, k_i8, k_sc, v_i8, v_sc, ln)
    o_ker = ops.kv_decode_attention(q, k_i8, k_sc, v_i8, v_sc, ln,
                                    block_s=bs, interpret=True)
    np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)


def test_kv_decode_int8_close_to_fp_attention():
    """int8 cache quantization keeps attention outputs close to fp."""
    from repro.kernels.ref import kv_decode_attention_ref
    from repro.models.layers import decode_attention, quantize_kv
    rng = jax.random.PRNGKey(3)
    b, s, kh, r, d = 2, 64, 2, 4, 32
    q = jax.random.normal(rng, (b, 1, kh * r, d))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (b, s, kh, d))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (b, s, kh, d))
    o_fp = decode_attention(q, k, v, jnp.int32(s))
    k_i8, k_sc = quantize_kv(k)
    v_i8, v_sc = quantize_kv(v)
    # decode_attention groups H as (KH, R) kh-major — same layout as the
    # kernel's [B, KH, R, D]
    o_i8 = kv_decode_attention_ref(q.reshape(b, kh, r, d),
                                   k_i8, k_sc, v_i8, v_sc, jnp.int32(s))
    o_i8 = o_i8.reshape(b, 1, kh * r, d)
    assert float(jnp.max(jnp.abs(o_fp - o_i8))) < 0.05


def test_int8_cache_pallas_path_matches_jnp_in_model():
    """Model-level: the Pallas kv-decode kernel and the jnp int8 path agree
    through a full decode_step."""
    import dataclasses
    from repro.configs import get_config
    from repro.models.registry import get_model
    cfg = dataclasses.replace(get_config("llama2_7b", reduced=True),
                              kv_cache_dtype="int8")
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    tok = jnp.zeros((2, 1), jnp.int32)

    def run(use_pallas):
        cache = api.init_cache(cfg, 2, 8)
        t, logs = tok, []
        for pos in range(3):
            lg, cache = api.decode_step(params, cache, t, jnp.int32(pos),
                                        cfg, use_pallas=use_pallas)
            logs.append(lg)
            t = jnp.argmax(lg[:, -1:, :], -1).astype(jnp.int32)
        return jnp.stack(logs)

    np.testing.assert_allclose(np.asarray(run(True)),
                               np.asarray(run(False)), atol=0.05)
