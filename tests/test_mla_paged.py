"""Paged MLA latent-KV cache (DESIGN.md §9): differential parity of the
paged prefill+decode path against the dense `mla_decode` cache oracle
(dtype x T grid, ragged lengths, sentinel/dead pages), latent-kernel vs
jnp-fallback parity (incl. the lane-tiled D > 128 case and tree ancestor
bitmaps), the registry paged-cache capability flag's error paths, and
the engine pins — latent-page leak-freedom under spec accept/reject
traffic and greedy spec decode (chain AND fanout-1 tree AND a branching
tree) == non-spec, token for token."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # pragma: no cover
    from _hyp import given, settings, st

from repro.configs import get_config
from repro.engine import EngineConfig, InferenceEngine, SamplingParams
from repro.kernels import ops, ref as kref
from repro.models import transformer as T
from repro.models.registry import get_model, paged_families

GREEDY = SamplingParams()


@functools.lru_cache(maxsize=4)
def _tiny(dtype="float32"):
    """Reduced mla_moe cell. Routing drops are disabled (the repo's
    equivalence-check convention, see test_models.py): MoE capacity
    truncation depends on the flattened token count, which differs
    between a T=1 decode step and a T=K+1 verify block — dropless
    routing is what makes the paged-vs-dense and spec-vs-non-spec pins
    exact."""
    cfg = get_config("deepseek_v2_236b", reduced=True)
    cfg = dataclasses.replace(
        cfg, dtype=dtype,
        moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, api, params


@functools.lru_cache(maxsize=4)
def _draft(profile):
    from repro.core.model_compress import compress_draft
    cfg, api, params = _tiny()
    return compress_draft(params, cfg, profile=profile)


def _prompts(vocab, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=l).astype(np.int32) for l in lens]


def _paged_setup(cfg, params, lens, ps=4, mp=6, seed=0):
    """Prefill ragged prompts into a fresh latent pool; returns
    (tokens [B, S], lengths, block_tables, filled cache, last logits)."""
    b = len(lens)
    g = np.random.default_rng(seed)
    s = max(lens)
    toks = np.zeros((b, s), np.int32)
    for i, l in enumerate(lens):
        toks[i, :l] = g.integers(0, cfg.vocab, size=l)
    bt = jnp.asarray(np.arange(b * mp, dtype=np.int32).reshape(b, mp))
    pcache = T.init_paged_cache(cfg, b * mp, ps)
    lengths = jnp.asarray(lens, jnp.int32)
    logits, pcache = T.prefill(params, pcache, jnp.asarray(toks), lengths,
                               bt, cfg)
    return toks, lengths, bt, pcache, logits


# ---------------------------------------------------------------------------
# differential parity: paged prefill + decode vs the dense mla_decode path
# ---------------------------------------------------------------------------

def test_mla_paged_prefill_matches_forward():
    """Paged MLA prefill's last-valid-token logits == full-forward logits
    at each row's own (ragged) length."""
    cfg, api, params = _tiny()
    toks, lengths, bt, pcache, logits = _paged_setup(cfg, params, (7, 4))
    logits_fwd, _ = T.forward(params, jnp.asarray(toks), cfg)
    ref = np.stack([np.asarray(logits_fwd)[i, int(lengths[i]) - 1]
                    for i in range(2)])
    np.testing.assert_allclose(np.asarray(logits)[:, 0], ref,
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype,rtol", [("float32", 2e-4),
                                        ("bfloat16", 4e-2)])
@pytest.mark.parametrize("t", [1, 3])              # decode / K+1 staircase
def test_mla_paged_decode_matches_dense(dtype, rtol, t):
    """The differential grid: paged decode (T=1 and the T=K+1 verify
    staircase, ragged per-slot positions) against the dense
    `mla_cache_init`/`mla_decode` oracle run token-by-token per slot —
    logits agree and the greedy token choice matches token for token."""
    cfg, api, params = _tiny(dtype)
    lens = (7, 4)
    toks, lengths, bt, pcache, _ = _paged_setup(cfg, params, lens)
    g = np.random.default_rng(1)
    feed = jnp.asarray(g.integers(0, cfg.vocab, size=(2, t)).astype(np.int32))
    lg_p, _ = T.decode_step(params, pcache, feed, lengths, cfg,
                            block_tables=bt)
    for i, l in enumerate(lens):
        cache = T.init_cache(cfg, 1, 24)
        for s in range(l):                 # replay the prompt densely
            _, cache = T.decode_step(params, cache, jnp.asarray(
                toks[i:i + 1, s:s + 1]), jnp.int32(s), cfg)
        for tt in range(t):                # then the fed block, one by one
            lg_d, cache = T.decode_step(params, cache, feed[i:i + 1,
                                                           tt:tt + 1],
                                        jnp.int32(l + tt), cfg)
            np.testing.assert_allclose(np.asarray(lg_p)[i, tt],
                                       np.asarray(lg_d)[0, 0],
                                       rtol=rtol, atol=rtol)
            assert int(np.argmax(np.asarray(lg_p)[i, tt])) == \
                int(np.argmax(np.asarray(lg_d)[0, 0]))


def test_mla_paged_decode_kernel_matches_fallback():
    """decode_step logits: Pallas latent-kernel path == jnp gather path,
    T=1 and multi-token, with and without the occupied-page clamp."""
    cfg, api, params = _tiny()
    toks, lengths, bt, pcache, _ = _paged_setup(cfg, params, (7, 4))
    feed = jnp.asarray(np.random.default_rng(2).integers(
        0, cfg.vocab, size=(2, 3)).astype(np.int32))
    outs = []
    for use_pallas in (False, True):
        for mlp in (None, 4):              # full table vs clamped
            lg, _ = T.decode_step(params, pcache, feed, lengths, cfg,
                                  block_tables=bt, use_pallas=use_pallas,
                                  max_live_pages=mlp)
            outs.append(np.asarray(lg))
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# latent kernel vs jnp oracle (ops.paged_latent_attention)
# ---------------------------------------------------------------------------

def _latent_case(seed, b, t, h, dl, v_rank, ps, mp, num_pages,
                 dtype=jnp.float32):
    """Random paged-latent instance: occupied page prefix + sentinel
    tail, ragged staircase lengths inside the occupied span."""
    assert num_pages >= b * mp
    g = np.random.default_rng(seed)
    q = jnp.asarray(g.normal(size=(b, t, h, dl)), dtype)
    lat = jnp.asarray(g.normal(size=(num_pages, ps, dl)), dtype)
    pages = g.permutation(num_pages)[:b * mp].reshape(b, mp).astype(np.int32)
    occ = g.integers(1, mp + 1, size=b)
    bt = np.where(np.arange(mp)[None, :] < occ[:, None], pages, num_pages)
    lengths = np.sort(np.stack(
        [g.integers(1, occ[i] * ps + 1, size=t) for i in range(b)]), axis=1)
    return q, lat, jnp.asarray(lengths.astype(np.int32)), jnp.asarray(bt)


@pytest.mark.parametrize("t", [1, 4])              # decode / K+1 verify
@pytest.mark.parametrize("dl,v_rank", [(40, 32), (160, 140), (320, 256)])
def test_latent_kernel_matches_reference(t, dl, v_rank):
    """Latent-kernel parity across the lane-tiling boundary: dl <= 128 is
    the single-dot program, dl > 128 exercises the 128-wide chunked
    score contraction (incl. a ragged tail chunk)."""
    q, lat, lengths, bt = _latent_case(3 * t + dl, b=3, t=t, h=4, dl=dl,
                                       v_rank=v_rank, ps=8, mp=4,
                                       num_pages=16)
    o_ref = kref.paged_latent_attention_ref(q, lat, lengths, bt, v_rank)
    o_ker = ops.paged_latent_attention(q, lat, lengths, bt, v_rank=v_rank,
                                       use_pallas=True, interpret=True)
    assert o_ker.shape == (3, t, 4, v_rank)
    np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)


def test_latent_kernel_bf16_pages():
    q, lat, lengths, bt = _latent_case(11, b=2, t=2, h=4, dl=40, v_rank=32,
                                       ps=8, mp=4, num_pages=12,
                                       dtype=jnp.bfloat16)
    o_ref = kref.paged_latent_attention_ref(q, lat, lengths, bt, 32)
    o_ker = ops.paged_latent_attention(q, lat, lengths, bt, v_rank=32,
                                       use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                               rtol=2e-2, atol=2e-2)


def test_latent_kernel_all_sentinel_slot_is_finite():
    """A slot whose table is ALL sentinels must stay finite in both
    implementations (same clamped page, masked identically)."""
    q, lat, lengths, bt = _latent_case(17, b=2, t=1, h=4, dl=40, v_rank=32,
                                       ps=8, mp=4, num_pages=16)
    bt = bt.at[1].set(lat.shape[0])
    o_ref = kref.paged_latent_attention_ref(q, lat, lengths, bt, 32)
    o_ker = ops.paged_latent_attention(q, lat, lengths, bt, v_rank=32,
                                       use_pallas=True, interpret=True)
    assert np.isfinite(np.asarray(o_ker)).all()
    assert np.isfinite(np.asarray(o_ref)).all()
    np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)


def test_latent_kernel_tree_ancestor_bitmaps():
    """Token-tree verify on the latent pool: the kernel's ancestor-bitmap
    mask matches the jnp reference (same shared ancestor_mask)."""
    from repro.engine.spec import TreeTemplate
    tpl = TreeTemplate((2, 2))
    w = tpl.n_nodes + 1
    g = np.random.default_rng(23)
    b, h, dl, ps, mp, num_pages = 2, 4, 160, 4, 6, 20
    q = jnp.asarray(g.normal(size=(b, w, h, dl)), jnp.float32)
    lat = jnp.asarray(g.normal(size=(num_pages, ps, dl)), jnp.float32)
    pages = g.permutation(num_pages)[:b * mp].reshape(b, mp).astype(np.int32)
    need = -(-w // ps) + 1
    occ = g.integers(need, mp + 1, size=b)
    bt = jnp.asarray(np.where(np.arange(mp)[None, :] < occ[:, None],
                              pages, num_pages))
    base = jnp.asarray(np.stack(
        [g.integers(0, occ[i] * ps - w + 1) for i in range(b)]), jnp.int32)
    lengths = jnp.broadcast_to((base + w)[:, None], (b, w)).astype(jnp.int32)
    anc = jnp.broadcast_to(jnp.asarray(tpl.anc)[None, :], (b, w))
    o_ref = kref.paged_latent_attention_ref(q, lat, lengths, bt, 140,
                                            anc=anc, anc_base=base,
                                            anc_window=w)
    o_ker = ops.paged_latent_attention(q, lat, lengths, bt, v_rank=140,
                                       anc=anc, anc_base=base, anc_window=w,
                                       use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 3), st.integers(1, 3))
def test_latent_dead_pages_never_change_output(seed, t, mp_extra):
    """Property: widening the block table with sentinel columns and
    scribbling over every page the lengths never reach leaves the latent
    kernel's output BIT-IDENTICAL (dead pages are skipped, not masked)."""
    g = np.random.default_rng(seed)
    q, lat, lengths, bt = _latent_case(seed, b=2, t=t, h=2, dl=40,
                                       v_rank=32, ps=8, mp=3, num_pages=12)
    base = np.asarray(ops.paged_latent_attention(
        q, lat, lengths, bt, v_rank=32, use_pallas=True, interpret=True))
    wide = jnp.concatenate(
        [bt, jnp.full((2, mp_extra), lat.shape[0], jnp.int32)], axis=1)
    out_w = np.asarray(ops.paged_latent_attention(
        q, lat, lengths, wide, v_rank=32, use_pallas=True, interpret=True))
    np.testing.assert_array_equal(out_w, base)

    ps = lat.shape[1]
    bt_np = np.asarray(bt)
    lmax = np.asarray(lengths).max(axis=1)
    seen = np.zeros((lat.shape[0],), bool)
    for i in range(2):
        flat = np.arange(bt_np.shape[1] * ps)
        live = bt_np[i][flat[flat < lmax[i]] // ps]
        seen[live[live < lat.shape[0]]] = True
    noise = jnp.asarray(g.normal(size=lat.shape), lat.dtype)
    lat2 = jnp.where(jnp.asarray(~seen)[:, None, None], noise, lat)
    out_s = np.asarray(ops.paged_latent_attention(
        q, lat2, lengths, bt, v_rank=32, use_pallas=True, interpret=True))
    np.testing.assert_array_equal(out_s, base)


# ---------------------------------------------------------------------------
# registry capability flag: early, listed error paths
# ---------------------------------------------------------------------------

def test_supports_paged_cache_flag():
    """mla_moe is now engine-capable; the families without a paged pool
    report so via the capability flag, and the supported list is what
    every error path quotes."""
    assert get_model(get_config("deepseek_v2_236b",
                                reduced=True)).supports_paged_cache
    assert paged_families() == ["dense", "mla_moe", "moe", "vlm"]
    for arch in ("mamba2_130m", "zamba2_7b", "seamless_m4t_large_v2"):
        assert not get_model(get_config(arch, reduced=True)) \
            .supports_paged_cache


def test_unsupported_family_fails_early_with_supported_list():
    """Engine construction on a family without paged-cache support fails
    BEFORE any device allocation, naming the supported families."""
    cfg = get_config("mamba2_130m", reduced=True)
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(NotImplementedError, match="supported: .*mla_moe"):
        InferenceEngine(cfg, params, EngineConfig(num_slots=1, max_seq=16))
    from repro.engine import PagedKVCache
    with pytest.raises(NotImplementedError, match="supported: .*dense"):
        PagedKVCache(cfg, api, num_slots=1, max_seq=16)


def test_serve_cli_rejects_unsupported_family():
    """serve.py validates the capability flag before building params."""
    from repro.launch import serve
    with pytest.raises(SystemExit):
        serve.main(["--arch", "mamba2_130m", "--reduced",
                    "--compress", "none", "--requests", "1"])


# ---------------------------------------------------------------------------
# engine pins: leak-freedom + greedy spec losslessness on mla_moe
# ---------------------------------------------------------------------------

def _run_engine(seed, max_new, *, spec_k=0, spec_fanout=None, draft=None,
                profile=None, use_pallas=False, num_pages=None,
                prompts_lens=(5, 9, 4)):
    from repro.core.model_compress import draft_layers
    cfg, api, params = _tiny()
    eng = InferenceEngine(
        cfg, params,
        EngineConfig(num_slots=2, max_seq=24, page_size=4,
                     num_pages=num_pages, spec_k=spec_k,
                     spec_fanout=spec_fanout, use_pallas=use_pallas,
                     spec_draft_layers=(draft_layers(cfg, profile)
                                        if profile else None)),
        GREEDY, draft_params=draft)
    prompts = _prompts(cfg.vocab, prompts_lens, seed=seed)
    rids = [eng.submit(p, max_new) for p in prompts]
    res = eng.run()
    out = {r["rid"]: list(r["tokens"]) for r in res["results"]}
    return eng, [out[r] for r in rids], res["metrics"]


def test_mla_engine_full_path_with_eviction():
    """mla_moe runs the full engine path — prefill, paged decode,
    eviction/refill under a pool sized for ~one resident request — and
    matches a naive full-forward greedy loop token for token."""
    cfg, api, params = _tiny()
    eng = InferenceEngine(
        cfg, params,
        EngineConfig(num_slots=2, max_seq=16, page_size=4, num_pages=4))
    prompts = _prompts(cfg.vocab, (5, 6, 7, 5), seed=3)
    rids = [eng.submit(p, 4) for p in prompts]
    res = eng.run()
    assert len(res["results"]) == 4
    assert eng.kv.allocator.num_free == 4

    def ref_generate(prompt):
        toks = list(prompt)
        out = []
        for _ in range(4):
            logits, _ = api.forward(params,
                                    {"tokens": jnp.asarray([toks])}, cfg)
            nxt = int(jnp.argmax(logits[0, -1]))
            out.append(nxt)
            toks.append(nxt)
        return out

    by_rid = {r["rid"]: list(r["tokens"]) for r in res["results"]}
    for rid, p in zip(rids, prompts):
        assert by_rid[rid] == ref_generate(p)


def test_mla_engine_pallas_matches_reference_outputs():
    """Greedy engine generations identical with the latent kernel on."""
    _, toks_ref, _ = _run_engine(7, 5)
    _, toks_ker, _ = _run_engine(7, 5, use_pallas=True)
    assert toks_ref == toks_ker


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 3))
def test_mla_spec_greedy_lossless_chain_and_tree(seed, k):
    """The lossless pin on mla_moe: greedy spec decode — chain K AND the
    degenerate fanout-1 tree — emits exactly the non-spec tokens, and
    chain vs tree leave the latent pool bit-identical (the PR 4
    chain/tree equivalence, now on latent pages)."""
    draft = _draft("w4l50")
    _, base, _ = _run_engine(seed, 6)
    eng_c, chain, m = _run_engine(seed, 6, spec_k=k, draft=draft,
                                  profile="w4l50")
    eng_t, tree, _ = _run_engine(seed, 6, spec_fanout=(1,) * k,
                                 draft=draft, profile="w4l50")
    assert chain == base
    assert tree == base
    assert m["spec_rounds"] > 0
    for lc, lt in zip(jax.tree_util.tree_leaves(eng_c.kv.data),
                      jax.tree_util.tree_leaves(eng_t.kv.data)):
        np.testing.assert_array_equal(np.asarray(lc), np.asarray(lt))


def test_mla_tree_spec_branching_lossless():
    """Greedy losslessness at a real branching fanout on the latent pool
    (tree verify + accepted-path latent compaction)."""
    draft = _draft("w4s75")
    _, base, _ = _run_engine(13, 6)
    _, tree, m = _run_engine(13, 6, spec_fanout=(2, 2), draft=draft,
                             profile="w4s75")
    assert tree == base
    assert m["verify_tokens"] > 0


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2**31 - 1),
       st.sampled_from([("chain", 3), ("tree", (2,)), ("tree", (2, 2))]))
def test_mla_allocator_leak_free_under_spec_traffic(seed, mode):
    """Latent pages never leak: random admission/eviction interleaved
    with spec accept/reject rounds (chain rollback = positional rewind;
    tree additionally compacts the accepted path) drain the free list
    back to its initial state — the pool only fits ~one resident
    request, so requests stream through slots."""
    from repro.core.model_compress import draft_layers
    from repro.engine.spec import TreeTemplate
    cfg, api, params = _tiny()
    kind, spec = mode
    if kind == "chain":
        lookahead, ecfg = spec, dict(spec_k=spec)
    else:
        lookahead, ecfg = TreeTemplate(spec).n_nodes, dict(spec_fanout=spec)
    pages_per_req = -(-(16 + lookahead) // 4)
    eng = InferenceEngine(
        cfg, params,
        EngineConfig(num_slots=2, max_seq=16, page_size=4,
                     num_pages=pages_per_req + 1,
                     spec_draft_layers=draft_layers(cfg, "w4l50"), **ecfg),
        GREEDY, draft_params=_draft("w4l50"))
    initial_free = eng.kv.allocator.num_free
    lens = np.random.default_rng(seed).integers(3, 8, size=4)
    for p in _prompts(cfg.vocab, tuple(lens), seed=seed):
        eng.submit(p, 4)
    res = eng.run()
    assert len(res["results"]) == 4
    assert all(r["n_generated"] == 4 for r in res["results"])
    assert eng.kv.allocator.num_free == initial_free
