"""Data pipeline determinism + optimizer correctness + schedules + fault."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import ByteCorpus, SyntheticLM
from repro.dist.elastic import MeshPlan, degrade_after_failure, plan_mesh
from repro.dist.fault import StepWatchdog, retrying
from repro.optim import adamw
from repro.optim.schedule import warmup_cosine, warmup_stable_decay


def test_synthetic_deterministic_per_step_and_shard():
    d = SyntheticLM(vocab=100, seq_len=16, global_batch=8, seed=1)
    a = d.host_batch(5, host_id=0, n_hosts=2)
    b = d.host_batch(5, host_id=0, n_hosts=2)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = d.host_batch(5, host_id=1, n_hosts=2)
    assert not np.array_equal(a["tokens"], c["tokens"])
    e = d.host_batch(6, host_id=0, n_hosts=2)
    assert not np.array_equal(a["tokens"], e["tokens"])


def test_labels_are_next_tokens():
    d = SyntheticLM(vocab=100, seq_len=16, global_batch=2, seed=0)
    b = d.host_batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_byte_corpus(tmp_path):
    p = tmp_path / "corpus.txt"
    p.write_text("hello world, this is a tiny corpus for testing!" * 20)
    d = ByteCorpus(str(p), seq_len=16, global_batch=4)
    b = d.host_batch(0)
    assert b["tokens"].shape == (4, 16)
    assert b["tokens"].max() < 259


def test_adamw_matches_reference_update():
    """One AdamW step vs a hand-computed reference."""
    cfg = adamw.AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8,
                            weight_decay=0.01, grad_clip=1e9)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.5])}
    st = adamw.init_state(p)
    p2, st2, _ = adamw.apply_updates(p, g, st, cfg)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    exp0 = 1.0 - 0.1 * (mhat / (np.sqrt(vhat) + 1e-8) + 0.01 * 1.0)
    np.testing.assert_allclose(float(p2["w"][0]), exp0, rtol=1e-5)
    assert int(st2["step"]) == 1


def test_grad_clipping():
    cfg = adamw.AdamWConfig(grad_clip=1.0)
    g = {"w": jnp.full((4,), 100.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)


def test_schedules():
    lr = warmup_cosine(1.0, 10, 100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0)
    assert float(lr(100)) == pytest.approx(0.1, rel=1e-2)
    lr2 = warmup_stable_decay(1.0, 10, 100)
    assert float(lr2(50)) == pytest.approx(1.0)
    assert float(lr2(100)) == pytest.approx(0.05, rel=1e-2)


def test_watchdog_flags_stragglers():
    w = StepWatchdog(warmup_steps=0, threshold=2.0)
    for _ in range(5):
        assert not w.observe(1.0)
    assert w.observe(10.0)
    assert w.stragglers == 1
    # EMA not polluted by the straggler
    assert w.ema == pytest.approx(1.0)


def test_retrying_recovers_from_transient():
    calls = {"n": 0}

    def flaky(x):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient link failure")
        return x + 1

    assert retrying(flaky, max_retries=3)(1) == 2
    assert calls["n"] == 3


def test_retrying_gives_up():
    def dead(x):
        raise RuntimeError("broken")
    with pytest.raises(RuntimeError):
        retrying(dead, max_retries=1)(0)


def test_mesh_plans():
    p = plan_mesh(256, model_parallel=16)
    assert p.shape == (16, 16)
    p = plan_mesh(512, model_parallel=16, multi_pod=True)
    assert p.shape == (2, 16, 16) and p.axes == ("pod", "data", "model")
    # losing 3 nodes of 256: data axis shrinks, TP preserved
    d = degrade_after_failure(MeshPlan((16, 16), ("data", "model")), 253)
    assert d.shape[-1] == 16 and d.n_devices <= 253
    # catastrophic loss: TP degrades too
    d = degrade_after_failure(MeshPlan((16, 16), ("data", "model")), 8)
    assert d.n_devices <= 8
