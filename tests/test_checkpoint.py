"""Checkpoint manager: roundtrip, atomicity, GC, restore-onto-new-mesh."""
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(8, 16)), jnp.float32),
            "b": {"w": jnp.asarray(rng.normal(size=(4,)), jnp.bfloat16),
                  "step": jnp.asarray(7, jnp.int32)}}


def test_roundtrip(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), async_save=False)
    t = _tree()
    ckpt.save(3, t)
    r = ckpt.restore(t)
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(r)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_and_gc(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        ckpt.save(s, _tree(s))
    assert ckpt.latest_step() == 4
    kept = sorted(p.name for p in tmp_path.iterdir() if p.is_dir())
    assert kept == ["step_00000003", "step_00000004"]


def test_async_save(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), async_save=True)
    ckpt.save(1, _tree())
    ckpt.wait()
    assert ckpt.latest_step() == 1


def test_no_partial_checkpoint_visible(tmp_path):
    """A .tmp dir must never be considered a checkpoint."""
    ckpt = CheckpointManager(str(tmp_path), async_save=False)
    ckpt.save(1, _tree())
    os.makedirs(tmp_path / "step_00000002.tmp")
    assert ckpt.latest_step() == 1


def test_restore_shape_mismatch_raises(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), async_save=False)
    ckpt.save(1, _tree())
    bad = _tree()
    bad["a"] = jnp.zeros((9, 16))
    with pytest.raises(ValueError):
        ckpt.restore(bad)


def test_restore_with_shardings_single_device(tmp_path):
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    ckpt = CheckpointManager(str(tmp_path), async_save=False)
    t = _tree()
    ckpt.save(1, t)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    sh = jax.tree_util.tree_map(
        lambda l: NamedSharding(mesh, P(*([None] * l.ndim))), t)
    r = ckpt.restore(t, shardings=sh)
    np.testing.assert_array_equal(np.asarray(t["a"]), np.asarray(r["a"]))
