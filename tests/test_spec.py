"""Speculative decoding: rejection-sampling correctness, sampling filter
edge cases, draft profiles, and the spec/ step builders."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # pragma: no cover
    from _hyp import given, settings, st

from repro.engine.sampling import (SamplingParams, filter_logits, sample,
                                   spec_verify)

GREEDY = SamplingParams()


def _ref_greedy_verify(logits, draft):
    """Reference: sequential greedy acceptance, one row."""
    tgt = np.argmax(logits, axis=-1)
    n = 0
    for i in range(draft.shape[0]):
        if draft[i] != tgt[i]:
            break
        n += 1
    return n, list(draft[:n]) + [tgt[n]]


# ---------------------------------------------------------------------------
# spec_verify: greedy path
# ---------------------------------------------------------------------------

def test_spec_verify_greedy_matches_sequential_reference():
    rng = np.random.default_rng(0)
    B, K, V = 4, 4, 16
    logits = rng.normal(size=(B, K + 1, V)).astype(np.float32)
    tgt = logits.argmax(-1)
    # rows: full accept, reject at 0, reject midway, random draft
    draft = np.stack([
        tgt[0, :K],
        (tgt[1, :K] + 1) % V,
        np.concatenate([tgt[2, :2], (tgt[2, 2:K] + 1) % V]),
        rng.integers(0, V, size=K),
    ]).astype(np.int32)
    n_acc, out = spec_verify(jnp.asarray(logits), jnp.asarray(draft),
                             jax.random.PRNGKey(0), GREEDY)
    n_acc, out = np.asarray(n_acc), np.asarray(out)
    assert n_acc[0] == K and n_acc[1] == 0 and n_acc[2] == 2
    for b in range(B):
        n_ref, toks_ref = _ref_greedy_verify(logits[b], draft[b])
        assert n_acc[b] == n_ref
        assert list(out[b, :n_ref + 1]) == toks_ref


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 6), st.integers(2, 33))
def test_spec_verify_greedy_property(seed, k, v):
    """For ANY logits/draft, greedy spec output == sequential greedy:
    accepted prefix is the longest argmax match and the correction IS the
    target argmax at the stop position (losslessness, DESIGN.md §4)."""
    rng = np.random.default_rng(seed)
    logits = rng.normal(size=(2, k + 1, v)).astype(np.float32)
    # half adversarial (copy argmax into the draft), half random
    draft = rng.integers(0, v, size=(2, k)).astype(np.int32)
    match_len = rng.integers(0, k + 1)
    draft[0, :match_len] = logits[0].argmax(-1)[:match_len]
    n_acc, out = spec_verify(jnp.asarray(logits), jnp.asarray(draft),
                             jax.random.PRNGKey(seed), GREEDY)
    n_acc, out = np.asarray(n_acc), np.asarray(out)
    for b in range(2):
        n_ref, toks_ref = _ref_greedy_verify(logits[b], draft[b])
        assert n_acc[b] == n_ref
        assert list(out[b, :n_ref + 1]) == toks_ref


# ---------------------------------------------------------------------------
# spec_verify: rejection sampling preserves the target distribution
# ---------------------------------------------------------------------------

def test_spec_verify_first_token_distribution_preserved():
    """The first emitted token of a round must be distributed exactly as
    the target p(. | prefix) — whatever the draft proposed. Empirical
    check over many rng draws against the analytic target."""
    V, K = 5, 3
    sp = SamplingParams(temperature=1.0)
    logits0 = np.array([2.0, 1.0, 0.5, 0.0, -1.0], np.float32)
    target = np.exp(logits0) / np.exp(logits0).sum()
    logits = jnp.asarray(np.tile(logits0, (1, K + 1, 1)).astype(np.float32))
    n = 4000
    keys = jax.random.split(jax.random.PRNGKey(0), n)
    verify = jax.vmap(lambda key, dr: spec_verify(logits, dr, key, sp)[1],
                      in_axes=(0, None))
    for draft_tok in (0, 4):              # likely and unlikely proposals
        draft = jnp.full((1, K), draft_tok, jnp.int32)
        out = np.asarray(verify(keys, draft))        # [n, 1, K+1]
        freq = np.bincount(out[:, 0, 0], minlength=V) / n
        # ~3 sigma for the largest bin at n=4000 is ~0.023
        np.testing.assert_allclose(freq, target, atol=0.05)


def test_spec_verify_rejection_resample_excludes_draft_token():
    """On rejection the residual distribution zeroes the rejected draft
    token (q is a point mass), so a draft with target probability ~0 can
    never be emitted at its own position."""
    V, K = 4, 2
    sp = SamplingParams(temperature=1.0)
    logits0 = np.array([10.0, 0.0, 0.0, -30.0], np.float32)  # p(3) ~= 0
    logits = jnp.asarray(np.tile(logits0, (1, K + 1, 1)).astype(np.float32))
    draft = jnp.full((1, K), 3, jnp.int32)  # propose the impossible token
    keys = jax.random.split(jax.random.PRNGKey(0), 500)
    n_acc, out = jax.vmap(lambda k: spec_verify(logits, draft, k, sp))(keys)
    assert (np.asarray(n_acc) == 0).all()  # p(draft) ~ 0 -> always rejected
    assert (np.asarray(out)[:, 0, 0] != 3).all()


# ---------------------------------------------------------------------------
# sampling filter edge cases
# ---------------------------------------------------------------------------

def test_top_k_equal_to_vocab_is_disabled():
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))
    key = jax.random.PRNGKey(7)
    full = sample(logits, key, SamplingParams(temperature=1.0, top_k=8))
    off = sample(logits, key, SamplingParams(temperature=1.0, top_k=0))
    np.testing.assert_array_equal(np.asarray(full), np.asarray(off))
    # and the filter itself must keep every logit finite
    f = filter_logits(logits, SamplingParams(temperature=1.0, top_k=8))
    assert np.isfinite(np.asarray(f)).all()


def test_top_p_ties_at_cutoff_keep_all_tied_tokens():
    """Two tokens tie exactly at the nucleus cutoff: both must survive
    (the filter drops strictly-below-cutoff logits only), so sampling
    support is {0, 1} and never collapses to one arbitrary winner."""
    logits = jnp.asarray([[0.0, 0.0, -20.0, -20.0]])
    f = np.asarray(filter_logits(logits,
                                 SamplingParams(temperature=1.0, top_p=0.5)))
    assert np.isfinite(f[0, 0]) and np.isfinite(f[0, 1])
    assert f[0, 2] == -np.inf and f[0, 3] == -np.inf
    draws = {int(sample(logits, jax.random.PRNGKey(i),
                        SamplingParams(temperature=1.0, top_p=0.5))[0])
             for i in range(50)}
    assert draws == {0, 1}


def test_temperature_to_zero_limit_equals_greedy():
    rng = np.random.default_rng(2)
    logits = jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))
    greedy = np.asarray(sample(logits, jax.random.PRNGKey(0),
                               SamplingParams()))
    for t in (1e-3, 1e-5):
        cold = np.asarray(sample(logits, jax.random.PRNGKey(0),
                                 SamplingParams(temperature=t)))
        np.testing.assert_array_equal(cold, greedy)


# ---------------------------------------------------------------------------
# draft profiles
# ---------------------------------------------------------------------------

def test_draft_profiles_pack_and_run():
    import dataclasses
    from repro.configs import get_config
    from repro.core.model_compress import (DRAFT_PROFILES, compress_draft,
                                           draft_layers)
    from repro.models.registry import get_model

    cfg = get_config("llama2_7b", reduced=True)
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.zeros((1, 4), jnp.int32)
    for profile in DRAFT_PROFILES:
        draft = compress_draft(params, cfg, profile=profile)
        dl = draft_layers(cfg, profile)
        assert 1 <= dl <= cfg.n_layers
        dcfg = dataclasses.replace(cfg, n_layers=dl)
        logits, _ = api.forward(draft, {"tokens": toks}, dcfg)
        assert logits.shape == (1, 4, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all()
    with pytest.raises(ValueError):
        compress_draft(params, cfg, profile="nope")
    with pytest.raises(ValueError):
        draft_layers(cfg, "nope")
