"""Group-pruning invariants (paper §3.2) + saliency sanity."""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # property tests skip; the rest of the file runs
    from _hyp import given, settings, st

from repro.core.pruning import (PruneConfig, group_mask,
                                groups_kept_per_row, mask_sparsity,
                                kept_indices_row_balanced, two_four_mask)
from repro.core.saliency import (HessianStats, group_saliency,
                                 weight_saliency)

S = settings(max_examples=15, deadline=None)


@S
@given(st.integers(0, 2**31 - 1), st.sampled_from([0.2, 0.3, 0.4, 0.5]))
def test_row_balanced_keeps_exactly_m_per_row(seed, sparsity):
    gsal = jnp.asarray(np.random.default_rng(seed).random((16, 32)))
    cfg = PruneConfig(sparsity=sparsity, group_size=16, row_balanced=True)
    gm = group_mask(gsal, cfg)
    m = groups_kept_per_row(32 * 16, cfg)
    assert (np.asarray(gm).sum(axis=1) == m).all()


@S
@given(st.integers(0, 2**31 - 1))
def test_row_balanced_keeps_top_saliency(seed):
    gsal = jnp.asarray(np.random.default_rng(seed).random((8, 16)))
    cfg = PruneConfig(sparsity=0.5, group_size=16, row_balanced=True)
    gm = np.asarray(group_mask(gsal, cfg))
    g = np.asarray(gsal)
    for i in range(8):
        kept_min = g[i][gm[i]].min()
        dropped_max = g[i][~gm[i]].max() if (~gm[i]).any() else -np.inf
        assert kept_min >= dropped_max


@S
@given(st.integers(0, 2**31 - 1), st.sampled_from([0.3, 0.5, 0.7]))
def test_global_threshold_hits_target_sparsity(seed, sparsity):
    gsal = jnp.asarray(np.random.default_rng(seed).random((32, 64)))
    cfg = PruneConfig(sparsity=sparsity, group_size=16, row_balanced=False)
    gm = group_mask(gsal, cfg)
    assert abs(mask_sparsity(gm) - sparsity) < 0.02


@S
@given(st.integers(0, 2**31 - 1))
def test_two_four_pattern(seed):
    sal = jnp.asarray(np.random.default_rng(seed).random((8, 64)))
    m = np.asarray(two_four_mask(sal))
    quads = m.reshape(8, 16, 4)
    assert (quads.sum(-1) == 2).all()


def test_kept_indices_sorted():
    gsal = jnp.asarray(np.random.default_rng(0).random((8, 16)))
    cfg = PruneConfig(sparsity=0.5, group_size=16)
    idx, m = kept_indices_row_balanced(gsal, cfg)
    idx = np.asarray(idx)
    assert idx.shape == (8, m)
    assert (np.diff(idx, axis=1) > 0).all()


def test_hessian_saliency_prefers_high_activation_dims():
    """eq. 4: same |w|, 10x larger input activations => higher saliency."""
    k = 32
    w = jnp.ones((4, k))
    x = np.ones((100, k), np.float32)
    x[:, : k // 2] *= 10.0
    stats = HessianStats.init(k, diag_only=True).update(jnp.asarray(x))
    sal = np.asarray(weight_saliency(w, stats))
    assert sal[:, : k // 2].min() > sal[:, k // 2:].max()


def test_exact_vs_diag_hessian_agree_on_diagonal_inputs():
    k = 16
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(500, k)) * rng.uniform(0.5, 2.0, k),
                    jnp.float32)
    w = jnp.asarray(rng.normal(size=(4, k)), jnp.float32)
    st_full = HessianStats.init(k, diag_only=False).update(x)
    sal_exact = np.asarray(weight_saliency(w, st_full, exact=True))
    sal_diag = np.asarray(weight_saliency(w, st_full, exact=False))
    # same ordering on (nearly) independent inputs (manual rank correlation)
    a = sal_exact.ravel().argsort().argsort()
    b = sal_diag.ravel().argsort().argsort()
    corr = np.corrcoef(a, b)[0, 1]
    assert corr > 0.8
