"""Fallback shims for ``hypothesis`` so property tests *skip* (not error)
when the package is absent.

Usage in a test module::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:                       # pragma: no cover
        from _hyp import given, settings, st

The shim ``given`` marks the test as skipped; strategy objects are inert
placeholders that only need to exist at collection time (they support the
chaining used in this repo: ``st.integers(...).map(...)`` etc.). Every
non-property test in the module still runs.
"""
import pytest


class _Strategy:
    """Inert stand-in for a hypothesis strategy."""

    def map(self, fn):
        return self

    def filter(self, fn):
        return self

    def flatmap(self, fn):
        return self


class _St:
    """Attribute access returns a strategy factory: st.anything(...)."""

    def __getattr__(self, name):
        def factory(*args, **kwargs):
            return _Strategy()
        return factory


st = _St()


def given(*args, **kwargs):
    def deco(fn):
        return pytest.mark.skip(
            reason="hypothesis not installed; property test skipped")(fn)
    return deco


def settings(*args, **kwargs):
    """``settings(...)`` is used as a decorator (``S = settings(...); @S``) —
    return identity so it composes with the skip-marking ``given``."""
    def deco(fn):
        return fn
    return deco
