"""Overload resilience (DESIGN.md §12): allocator invariants, typed
submit rejection, KV-pressure preemption with lossless recompute (plain,
chain-spec and tree-spec), deadline shedding as first-class SLO verdicts,
pressure-degraded spec admission, the deterministic chaos harness
(bit-identical replay + greedy losslessness under faults), the overload
cliff, and graceful SIGINT shutdown."""
import os
import signal
import subprocess
import sys
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.engine import (EngineConfig, InferenceEngine, PageAllocator,
                          PagedKVCache, RejectedRequest, SamplingParams,
                          Scheduler)
from repro.engine.loadgen import (SLO, SLOLedger, WorkloadSpec, generate,
                                  make_source)
from repro.engine.resilience import ChaosConfig, ResilienceConfig
from repro.models.registry import get_model

from _engine_utils import ScriptedSource, by_rid as _by_rid, \
    make_prompts as _prompts


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("llama2_7b", reduced=True)
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, api, params


# ---------------------------------------------------------------------------
# allocator invariants (satellite a)
# ---------------------------------------------------------------------------

def test_allocator_rejects_double_free():
    a = PageAllocator(4)
    pages = a.alloc(2)
    a.free(pages)
    with pytest.raises(ValueError):
        a.free(pages)                     # already back in the free list
    assert a.num_free == 4 and a.num_outstanding == 0


def test_allocator_rejects_out_of_range_and_duplicates():
    a = PageAllocator(4)
    pages = a.alloc(3)
    with pytest.raises(ValueError):
        a.free([99])
    with pytest.raises(ValueError):
        a.free([pages[0], pages[0]])
    # failed frees must not have partially applied
    assert a.num_free == 1 and a.num_outstanding == 3
    a.free(pages)
    assert a.num_free == 4


def test_allocator_conservation_under_storm():
    """Randomized alloc/free churn (a preempt/re-admit storm in
    miniature): free + outstanding == pool size at every step, and a
    final drain returns every page exactly once."""
    rng = np.random.default_rng(42)
    a = PageAllocator(16)
    held = []
    for _ in range(500):
        if held and (rng.random() < 0.5 or a.num_free == 0):
            a.free(held.pop(int(rng.integers(0, len(held)))))
        else:
            n = int(rng.integers(1, min(a.num_free, 4) + 1))
            held.append(a.alloc(n))
        assert a.num_free + a.num_outstanding == 16
    for pages in held:
        a.free(pages)
    assert a.num_free == 16 and a.num_outstanding == 0
    assert sorted(a.alloc(16)) == list(range(16))


# ---------------------------------------------------------------------------
# typed submit rejection (satellite b)
# ---------------------------------------------------------------------------

def test_submit_validation_rejects_malformed(tiny):
    cfg, api, params = tiny
    eng = InferenceEngine(cfg, params,
                          EngineConfig(num_slots=1, max_seq=16, page_size=4))
    with pytest.raises(RejectedRequest):
        eng.submit(np.zeros(0, np.int32), 4)          # empty prompt
    with pytest.raises(RejectedRequest):
        eng.submit(np.zeros(4, np.int32), 0)          # no budget
    with pytest.raises(RejectedRequest):
        eng.submit(np.zeros(16, np.int32), 4)         # prompt fills max_seq
    with pytest.raises(RejectedRequest):
        eng.submit(np.zeros(14, np.int32), 4)         # prompt+budget > cap
    # RejectedRequest subclasses ValueError (compat with older callers)
    assert issubclass(RejectedRequest, ValueError)
    assert eng.tel.registry.counter("sched.rejected").value == 4
    assert not eng.scheduler.waiting        # nothing entered the queue


# ---------------------------------------------------------------------------
# preempt-and-recompute: lossless under greedy (tentpole, part 1)
# ---------------------------------------------------------------------------

def _preempt_schedule(vocab):
    """A (prio 0, long) + C (prio 0, short) arrive first and fill the
    pool; B (prio 1, biggest) arrives once decoding is underway and can
    only be served by preempting A."""
    pa, pc, pb = _prompts(vocab, (8, 8, 8), seed=21)
    return [(1, pa, 16, 0), (1, pc, 4, 0), (2, pb, 24, 1)]


def _run_scripted(cfg, params, schedule, draft=None, **ecfg):
    eng = InferenceEngine(
        cfg, params, EngineConfig(num_slots=2, max_seq=32, page_size=4,
                                  **ecfg),
        SamplingParams(), draft_params=draft)
    res = eng.run(source=ScriptedSource(schedule))
    return eng, res


def test_preemption_lossless_plain(tiny):
    """Pool sized so B (higher priority) can only run by preempting A;
    A's re-prefill over (prompt + generated) must resume it exactly —
    greedy outputs bit-identical to an ample-pool run with no
    preemption, and every page returns to the pool."""
    cfg, api, params = tiny
    sched = _preempt_schedule(cfg.vocab)
    base_eng, base = _run_scripted(cfg, params, sched)      # ample pool
    assert base["metrics"]["preemptions"] == 0
    eng, res = _run_scripted(cfg, params, sched, num_pages=9)
    assert eng.scheduler.finished and len(res["results"]) == 3
    assert res["metrics"]["preemptions"] == 1
    preempted = [r for r in eng.scheduler.finished if r.preemptions]
    assert len(preempted) == 1 and preempted[0].folded > 0
    assert _by_rid(res) == _by_rid(base)                    # lossless
    # folding never distorts the reported shapes
    for r in res["results"]:
        assert len(r["tokens"]) == r["n_generated"]
        assert r["prompt_len"] == 8
    assert eng.kv.allocator.num_free == 9                   # no page leak
    assert eng.kv.allocator.num_outstanding == 0


@pytest.fixture(scope="module")
def draft(tiny):
    from repro.core.model_compress import compress_draft
    cfg, api, params = tiny
    return compress_draft(params, cfg, profile="w4")


def test_preemption_lossless_chain_spec(tiny, draft):
    """Same inversion under chain speculative decoding: the spec log's
    per-round accepted slices fold into the prompt correctly."""
    cfg, api, params = tiny
    sched = _preempt_schedule(cfg.vocab)
    rcfg = ResilienceConfig(pressure_degrade=False)   # pin the preempt path
    _, base = _run_scripted(cfg, params, sched, draft=draft, spec_k=2,
                            resilience=rcfg)
    assert base["metrics"]["preemptions"] == 0
    eng, res = _run_scripted(cfg, params, sched, draft=draft, spec_k=2,
                             num_pages=11, resilience=rcfg)
    assert res["metrics"]["preemptions"] >= 1
    assert _by_rid(res) == _by_rid(base)
    assert eng.kv.allocator.num_free == 11


def test_preemption_lossless_tree_spec(tiny, draft):
    """And under token-TREE drafting (the widest spec log layout)."""
    cfg, api, params = tiny
    sched = _preempt_schedule(cfg.vocab)
    rcfg = ResilienceConfig(pressure_degrade=False)
    _, base = _run_scripted(cfg, params, sched, draft=draft,
                            spec_fanout=(2,), resilience=rcfg)
    assert base["metrics"]["preemptions"] == 0
    eng, res = _run_scripted(cfg, params, sched, draft=draft,
                             spec_fanout=(2,), num_pages=11,
                             resilience=rcfg)
    assert res["metrics"]["preemptions"] >= 1
    assert _by_rid(res) == _by_rid(base)
    assert eng.kv.allocator.num_free == 11


def test_equal_priority_never_preempts(tiny):
    """Plain overload (everything priority 0) must queue, not thrash:
    FIFO means every running request arrived before the blocked head."""
    cfg, api, params = tiny
    eng = InferenceEngine(cfg, params,
                          EngineConfig(num_slots=2, max_seq=32, page_size=4,
                                       num_pages=9))
    for p in _prompts(cfg.vocab, (8, 8, 8), seed=4):
        eng.submit(p, 16)                    # 6 pages each: one at a time
    res = eng.run()
    assert len(res["results"]) == 3
    assert res["metrics"]["preemptions"] == 0


# ---------------------------------------------------------------------------
# deadline-aware shedding (tentpole, part 2)
# ---------------------------------------------------------------------------

def test_shed_expired_first_class_verdicts(tiny):
    """Requests whose TTFT deadline already passed are dropped before
    prefill and show up as 'shed' verdicts — met + miss + shed
    partitions the run."""
    cfg, api, params = tiny
    eng = InferenceEngine(cfg, params,
                          EngineConfig(num_slots=2, max_seq=16, page_size=4))
    past = eng.metrics.now() - 1.0
    live = [eng.submit(p, 4) for p in _prompts(cfg.vocab, (4, 6, 5))]
    dead = [eng.submit(p, 4, deadline_t=past)
            for p in _prompts(cfg.vocab, (5, 7), seed=2)]
    res = eng.run()
    assert sorted(r["rid"] for r in res["results"]) == sorted(live)
    assert res["metrics"]["shed"] == 2
    ledger = SLOLedger(SLO(ttft_ms=60_000), registry=eng.tel.registry)
    ledger.judge(eng.metrics)
    s = ledger.summary()
    assert s["requests"] == 5 and s["shed"] == 2 and s["met"] == 3
    by = {v.rid: v for v in ledger.verdicts}
    for rid in dead:
        v = by[rid]
        assert v.verdict == "shed" and not v.met and v.n_tokens == 0
        assert v.shed_reason == "deadline" and v.queue_wait_ms >= 0
    for rid in live:
        assert by[rid].verdict == "met"
    assert eng.tel.registry.counter("slo.requests_shed").value == 2
    assert eng.kv.allocator.num_free == eng.kv.num_pages


def test_default_deadline_from_resilience_config(tiny):
    """deadline_ttft_ms stamps every submit; an already-unmeetable
    deadline (0 ms after a backdated arrival) sheds at the first
    boundary."""
    cfg, api, params = tiny
    eng = InferenceEngine(
        cfg, params, EngineConfig(num_slots=1, max_seq=16, page_size=4,
                                  resilience=ResilienceConfig(
                                      deadline_ttft_ms=0.0)))
    eng.submit(_prompts(cfg.vocab, (5,))[0], 4,
               arrival_t=eng.metrics.now() - 1.0)
    res = eng.run()
    assert res["results"] == [] and res["metrics"]["shed"] == 1


# ---------------------------------------------------------------------------
# pressure-degraded spec admission (tentpole, part 2b)
# ---------------------------------------------------------------------------

def test_pressure_degrade_lossless(tiny, draft):
    """Under pool pressure a new admission reserves lookahead 0 and the
    segment degrades to plain decode instead of preempting — output
    still bit-identical to the ample-pool spec run."""
    cfg, api, params = tiny
    prompts = _prompts(cfg.vocab, (8, 8, 8), seed=13)
    budgets = (24, 4, 24)

    def run(num_pages):
        eng = InferenceEngine(
            cfg, params,
            EngineConfig(num_slots=2, max_seq=40, page_size=4,
                         num_pages=num_pages, spec_k=4),
            SamplingParams(), draft_params=draft)
        for p, m in zip(prompts, budgets):
            eng.submit(p, m)
        return eng, eng.run()

    _, base = run(None)                               # ample pool
    eng, res = run(17)                                # r3 only fits at la=0
    assert _by_rid(res) == _by_rid(base)
    assert eng.tel.registry.counter("resil.degraded_segments").value > 0
    assert res["metrics"]["preemptions"] == 0         # degrade sufficed
    assert eng.kv.allocator.num_free == 17


# ---------------------------------------------------------------------------
# chaos harness: deterministic replay + losslessness (tentpole, part 3)
# ---------------------------------------------------------------------------

CHAOS = ChaosConfig(alloc_fail=0.3, latency=0.1, device_err=0.15,
                    nan_logits=0.15, seed=7, latency_spike_s=1e-4,
                    device_max_retries=6)


def _chaos_run(cfg, params, chaos):
    eng = InferenceEngine(
        cfg, params,
        EngineConfig(num_slots=2, max_seq=24, page_size=4,
                     resilience=ResilienceConfig(chaos=chaos)))
    for p in _prompts(cfg.vocab, (4, 9, 5, 7, 6, 8), seed=31):
        eng.submit(p, 6)
    return eng, eng.run()


def test_chaos_replay_bit_identical(tiny):
    """Same seed, same faults, same recoveries, same tokens: two fresh
    engines under an aggressive chaos mix replay bit-identically, and
    both match the fault-free run (greedy losslessness under faults)."""
    cfg, api, params = tiny
    eng_clean, clean = _chaos_run(cfg, params, None)
    assert eng_clean.chaos is None
    eng1, res1 = _chaos_run(cfg, params, CHAOS)
    eng2, res2 = _chaos_run(cfg, params, CHAOS)
    snap1, snap2 = eng1.chaos.snapshot(), eng2.chaos.snapshot()
    assert snap1 == snap2                              # same fault sequence
    assert sum(snap1.values()) > 0                     # faults actually fired
    assert res1["metrics"]["preemptions"] == res2["metrics"]["preemptions"]
    assert _by_rid(res1) == _by_rid(res2)              # bit-identical replay
    assert _by_rid(res1) == _by_rid(clean)             # lossless recovery
    assert len(res1["results"]) == 6
    assert eng1.kv.allocator.num_free == eng1.kv.num_pages
    assert eng1.kv.allocator.num_outstanding == 0


def test_chaos_nan_quarantine_and_recovery(tiny):
    """nan_logits alone: poisoned segments are dropped, the slot sits
    out admission, the request re-enqueues — and the output is still
    exactly the fault-free greedy output."""
    cfg, api, params = tiny
    nan_only = ChaosConfig(nan_logits=0.5, seed=3)
    _, clean = _chaos_run(cfg, params, None)
    eng, res = _chaos_run(cfg, params, nan_only)
    snap = eng.chaos.snapshot()
    assert snap["nan_logits"] > 0
    assert eng.tel.registry.counter("sched.quarantines").value \
        == snap["nan_logits"]
    assert res["metrics"]["preemptions"] >= snap["nan_logits"]
    assert _by_rid(res) == _by_rid(clean)
    assert eng.kv.allocator.num_free == eng.kv.num_pages


def test_chaos_parse_round_trip():
    c = ChaosConfig.parse(
        "alloc_fail=0.05,latency=0.02,latency_spike_ms=1,retries=3,"
        "backoff_ms=2,quarantine=5", seed=11)
    assert c.alloc_fail == 0.05 and c.latency == 0.02
    assert c.latency_spike_s == pytest.approx(1e-3)
    assert c.device_max_retries == 3
    assert c.device_backoff_s == pytest.approx(2e-3)
    assert c.quarantine_boundaries == 5 and c.seed == 11
    assert c.enabled
    with pytest.raises(ValueError):
        ChaosConfig.parse("bogus=1")
    with pytest.raises(ValueError):
        ChaosConfig(alloc_fail=1.5)


# ---------------------------------------------------------------------------
# overload cliff (satellite d)
# ---------------------------------------------------------------------------

def test_overload_cliff_partitions_and_conserves(tiny):
    """Seeded open-loop burst far beyond sustainable rate against a pool
    sized for ~one resident request: the run terminates, every request
    lands in exactly one of met/miss/shed, goodput stays positive, and
    the page pool drains back to full."""
    cfg, api, params = tiny
    ecfg = dict(num_slots=2, max_seq=32, page_size=4, num_pages=5)
    # warm the jit caches with the exact shapes the burst will hit, so
    # the deadline judges scheduling, not compilation
    warm = InferenceEngine(cfg, params, EngineConfig(**ecfg))
    for p, m in zip(_prompts(cfg.vocab, (2, 3, 5, 6), seed=1),
                    (2, 2, 4, 4)):
        warm.submit(p, m)
    warm.run()
    wl = generate(WorkloadSpec(process="poisson", rate=8000, requests=96,
                               prompt_min=4, prompt_max=8, max_new_min=6,
                               max_new_max=8, seed=9), vocab=cfg.vocab)
    eng = InferenceEngine(
        cfg, params,
        EngineConfig(resilience=ResilienceConfig(deadline_ttft_ms=60),
                     **ecfg))
    eng.run(source=make_source(wl))
    ledger = SLOLedger(SLO(ttft_ms=60), registry=eng.tel.registry)
    ledger.judge(eng.metrics)
    s = ledger.summary()
    n_miss = sum(v.verdict == "miss" for v in ledger.verdicts)
    assert s["requests"] == 96                       # nobody lost
    assert s["met"] + s["shed"] + n_miss == 96       # exact partition
    assert s["met"] >= 1 and s["shed"] >= 1          # cliff, not collapse
    assert s["goodput_tokens"] > 0
    assert eng.kv.allocator.num_free == 5            # no page leak
    assert eng.kv.allocator.num_outstanding == 0


def test_workload_priority_levels_draw_and_replay():
    spec = WorkloadSpec(process="poisson", rate=100, requests=32,
                        priority_levels=3, seed=5)
    wl1, wl2 = generate(spec, vocab=128), generate(spec, vocab=128)
    prios = {g.priority for g in wl1.requests}
    assert prios <= {0, 1, 2} and len(prios) > 1
    for a, b in zip(wl1.requests, wl2.requests):
        assert a.priority == b.priority
        assert np.array_equal(a.prompt, b.prompt)
    # single-band specs draw no priorities; arrivals (drawn up front,
    # before any per-request draw) are invariant to the band count
    base = WorkloadSpec(process="poisson", rate=100, requests=32, seed=5)
    wl0 = generate(base, vocab=128)
    assert all(g.priority == 0 for g in wl0.requests)
    for a, b in zip(wl0.requests, wl1.requests):
        assert a.arrival_s == b.arrival_s
    # and the first request's prompt precedes the first priority draw
    assert np.array_equal(wl0.requests[0].prompt, wl1.requests[0].prompt)


# ---------------------------------------------------------------------------
# graceful shutdown (satellite c)
# ---------------------------------------------------------------------------

def test_sigint_drains_and_flushes(tmp_path):
    """SIGINT mid-serve: the engine sheds its queue, accounts in-flight
    requests, and serve.py still flushes stats + digest (exit 0)."""
    trace = tmp_path / "trace.json"
    slo_json = tmp_path / "slo.json"
    cmd = [sys.executable, "-u", "-m", "repro.launch.serve",
           "--compress", "none", "--slots", "2", "--max-seq", "32",
           "--page-size", "4", "--max-new", "8", "--stats-interval", "0.1",
           "--workload", "process=poisson,rate=4,requests=400,"
           "prompt=4:8,max_new=4:8",
           "--slo", "ttft=200", "--slo-json", str(slo_json),
           "--trace", str(trace)]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True, env=env,
                            cwd=os.path.dirname(__file__))
    lines = []
    deadline = time.time() + 180
    interrupted = False
    for line in proc.stdout:
        lines.append(line)
        if "[stats]" in line and not interrupted:
            proc.send_signal(signal.SIGINT)   # serving underway: interrupt
            interrupted = True
        if time.time() > deadline:
            proc.kill()
            pytest.fail("serve.py did not produce stats output in time:\n"
                        + "".join(lines))
    rc = proc.wait(timeout=60)
    out = "".join(lines)
    assert interrupted, "no [stats] line before the run completed:\n" + out
    assert rc == 0, out
    assert "[interrupted]" in out
    assert "[digest]" in out
    assert "SLO [" in out                     # ledger still judged
    assert trace.exists() and slo_json.exists()
