"""Shared-prefix KV reuse (DESIGN.md §13): refcounted pages, the radix
prefix cache, copy-on-write, tail-only prefill losslessness, eviction
under pressure — plus the two PR-9 admission-path bugfix regressions
(oversized assign must reject BEFORE mutating allocator state; release
telemetry must count actual page returns after the free succeeds)."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.engine import (EngineConfig, InferenceEngine, OversizedRequest,
                          PageAllocator, PagedKVCache, PrefixCache,
                          RejectedRequest, SamplingParams, Scheduler)
from repro.engine.telemetry import MetricsRegistry
from repro.models.registry import get_model

from _engine_utils import ScriptedSource as _PollSource, \
    make_prompts as _prompts, shared_prompts as _shared_prompts


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("llama2_7b", reduced=True)
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, api, params


# ---------------------------------------------------------------------------
# refcounted allocator
# ---------------------------------------------------------------------------

def test_allocator_refcounts():
    a = PageAllocator(8)
    p = a.alloc(2)
    assert all(a.refcount(x) == 1 for x in p)
    a.incref([p[0]])
    assert a.refcount(p[0]) == 2 and a.num_shared == 1
    # decref-all: only the refcount-0 page returns to the free list
    assert a.free(p) == [p[1]]
    assert a.refcount(p[0]) == 1 and a.num_shared == 0
    assert p[0] not in a._free and a.num_outstanding == 1
    assert a.free([p[0]]) == [p[0]]
    assert a.num_free == 8 and a.num_outstanding == 0
    # conservation holds refcount-weighted at every point above
    assert a.num_free + a.num_outstanding == 8


def test_allocator_incref_validation():
    a = PageAllocator(4)
    p = a.alloc(1)
    never_alloced = next(x for x in range(4) if x != p[0])
    with pytest.raises(ValueError):
        a.incref([never_alloced])  # free page: would resurrect under alloc
    with pytest.raises(ValueError):
        a.incref([99])         # out of range
    a.free(p)
    with pytest.raises(ValueError):
        a.incref(p)            # released page


def test_allocator_shared_page_double_decref_caught():
    a = PageAllocator(4)
    p = a.alloc(1)
    a.incref(p)
    a.free(p)
    a.free(p)                  # second reference dropped -> actually freed
    with pytest.raises(ValueError):
        a.free(p)              # third decref is a real double-free


# ---------------------------------------------------------------------------
# bugfix regressions (ISSUE 9 satellites)
# ---------------------------------------------------------------------------

def test_oversized_assign_rejected_before_any_mutation(tiny):
    """PR-9 bugfix: assign(slot, 64) at max_seq=32/page_size=16 used to
    alloc 4 pages, then die in the 2-wide block-table broadcast — pages
    leaked, table all-sentinel, gauges stale. It must now raise a typed
    RejectedRequest-compatible error with state EXACTLY as before."""
    cfg, api, _ = tiny
    reg = MetricsRegistry()
    kv = PagedKVCache(cfg, api, num_slots=2, max_seq=32, page_size=16,
                      registry=reg)
    before_free = kv.allocator.num_free
    before_bt = kv.block_tables.copy()
    before_allocs = reg.counter("kv.page_allocs").value
    before_gauge = reg.gauge("kv.pages_free").value
    with pytest.raises(OversizedRequest):
        kv.assign(0, 64)
    assert issubclass(OversizedRequest, RejectedRequest)
    assert issubclass(OversizedRequest, ValueError)
    assert kv.allocator.num_free == before_free
    assert kv.allocator.num_outstanding == 0
    np.testing.assert_array_equal(kv.block_tables, before_bt)
    assert reg.counter("kv.page_allocs").value == before_allocs
    assert reg.gauge("kv.pages_free").value == before_gauge
    # the slot is still perfectly usable
    kv.assign(0, 32)
    assert kv.allocator.num_outstanding == 2


def test_can_admit_rejects_oversized(tiny):
    cfg, api, _ = tiny
    kv = PagedKVCache(cfg, api, num_slots=2, max_seq=32, page_size=16)
    assert not kv.can_admit(64)     # would raise in assign -> not admissible


def test_release_counts_actual_frees_after_mutation(tiny):
    """PR-9 bugfix: release() used to bump kv.page_frees BEFORE
    allocator.free could raise. The counter must move only when the free
    succeeds, and must count actual page returns (shared pages survive
    their cache reference and are NOT freed by a slot release)."""
    cfg, api, _ = tiny
    reg = MetricsRegistry()
    kv = PagedKVCache(cfg, api, num_slots=2, max_seq=32, page_size=16,
                      registry=reg)
    kv.assign(0, 32)
    pages = list(kv._slot_pages[0])
    kv.allocator.free([pages[0]])   # sabotage: page 0 already returned
    before = reg.counter("kv.page_frees").value
    with pytest.raises(ValueError):
        kv.release(0)               # double-free caught by the allocator
    assert reg.counter("kv.page_frees").value == before

    kv2 = PagedKVCache(cfg, api, num_slots=2, max_seq=32, page_size=16,
                       registry=MetricsRegistry(), prefix_cache=True)
    prompt = np.arange(32, dtype=np.int32)
    kv2.assign(0, 32, prompt=prompt)
    kv2.prefix_insert(0, prompt)    # both full blocks now cache-held
    held = kv2.prefix.cached_pages
    assert held == 2
    frees = kv2._c_frees
    before = frees.value
    kv2.release(0)
    # only the pages the cache does NOT hold actually returned
    assert frees.value - before == 2 - held
    assert kv2.allocator.num_outstanding == held


# ---------------------------------------------------------------------------
# radix cache units
# ---------------------------------------------------------------------------

def test_radix_match_insert():
    a = PageAllocator(16)
    pc = PrefixCache(4, a)
    prompt = np.arange(11, dtype=np.int32)        # blocks [0:4],[4:8]; tail 3
    pages = a.alloc(3)
    assert pc.insert(prompt, pages) == 2          # only FULL blocks cached
    assert [n.page for n in pc.match(prompt)] == pages[:2]
    assert a.refcount(pages[0]) == 2              # slot ref + cache ref
    # a diverging prompt matches only the common full-block prefix
    other = prompt.copy()
    other[6] = 999
    assert len(pc.match(other)) == 1
    # re-insert is idempotent: existing nodes keep their pages, no refs
    assert pc.insert(prompt, a.alloc(3)) == 0


def test_radix_lru_leaf_first_eviction():
    a = PageAllocator(16)
    pc = PrefixCache(4, a)
    p1 = _shared_prompts(100, 8, [0], seed=1)[0]  # 2 blocks: chain A
    p2 = np.arange(50, 58, dtype=np.int32)        # 2 blocks: chain B
    g1, g2 = a.alloc(2), a.alloc(2)
    pc.insert(p1, g1)
    pc.insert(p2, g2)
    a.free(g1)
    a.free(g2)                                    # cache-held only now
    pc.match(p1)                                  # touch chain A: B is LRU
    assert pc.evictable_count() == 4
    pc.evict_for(1)
    # the LRU LEAF went first: chain B's depth-1 node, never a parent
    # with a live child, and never recently-used chain A
    assert len(pc.match(p1)) == 2
    assert len(pc.match(p2, touch=False)) == 1
    pc.evict_for(99)
    assert pc.cached_pages == 0
    assert a.num_free == 16


def test_eviction_excludes_pinned_and_referenced():
    a = PageAllocator(16)
    pc = PrefixCache(4, a)
    prompt = np.arange(8, dtype=np.int32)
    pages = a.alloc(2)
    pc.insert(prompt, pages)
    # slot still references its pages: nothing is evictable
    assert pc.evictable_count() == 0
    assert pc.evict_for(2) == 0
    a.free(pages)
    nodes = pc.match(prompt)
    assert pc.evictable_count(exclude=nodes) == 0   # pinned by admission
    assert pc.evict_for(2, exclude=[nodes[1]]) == 0  # leaf pinned blocks all
    assert pc.evict_for(2) == 2


# ---------------------------------------------------------------------------
# assign-time sharing + copy-on-write
# ---------------------------------------------------------------------------

def test_assign_maps_shared_prefix_and_cows_aligned_hit(tiny):
    cfg, api, _ = tiny
    kv = PagedKVCache(cfg, api, num_slots=3, max_seq=64, page_size=16,
                      prefix_cache=True)
    long, aligned = _shared_prompts(cfg.vocab, 32, [8, 0], seed=2)
    kv.assign(0, len(long) + 8, prompt=long)
    assert kv.slot_shared_tokens(0) == 0          # cold miss
    kv.prefix_insert(0, long)
    # partial hit: both full blocks shared, tail prefills from token 32
    kv.assign(1, len(long) + 8, prompt=long)
    assert kv.slot_shared_tokens(1) == 32
    np.testing.assert_array_equal(kv.block_tables[0, :2],
                                  kv.block_tables[1, :2])
    assert kv.block_tables[0, 2] != kv.block_tables[1, 2]
    # page-aligned full-prompt hit: the clamp forces recomputing the last
    # token, which lives in cached block 1 -> that block is COW-copied
    cow_before = kv._c_cow.value
    kv.assign(2, len(aligned) + 8, prompt=aligned)
    assert kv.slot_shared_tokens(2) == 31
    assert kv._c_cow.value == cow_before + 1
    assert kv.block_tables[2, 0] == kv.block_tables[0, 0]   # block 0 shared
    assert kv.block_tables[2, 1] != kv.block_tables[0, 1]   # block 1 private
    # conservation, refcount-weighted
    alc = kv.allocator
    assert alc.num_free + alc.num_outstanding == kv.num_pages
    for s in range(3):
        kv.release(s)
    assert alc.num_outstanding == kv.prefix.cached_pages


def test_assign_alloc_failure_rolls_back_increfs(tiny):
    cfg, api, _ = tiny
    kv = PagedKVCache(cfg, api, num_slots=2, max_seq=64, page_size=16,
                      num_pages=4, prefix_cache=True)
    prompt = np.arange(20, dtype=np.int32)
    kv.assign(0, 28, prompt=prompt)               # 2 pages, 2 left free
    kv.prefix_insert(0, prompt)                   # block 0 cached (rc 2)
    rc = kv.allocator.refcount(int(kv.block_tables[0, 0]))
    with pytest.raises(RuntimeError):
        # matches the cached block (incref) but needs 3 own pages with 2
        # free and nothing evictable (slot 0 still references all of its
        # pages) -> alloc raises AFTER the incref, which must roll back
        kv.assign(1, 64, prompt=prompt)
    assert kv.allocator.refcount(int(kv.block_tables[0, 0])) == rc
    assert kv.allocator.num_free + kv.allocator.num_outstanding \
        == kv.num_pages


# ---------------------------------------------------------------------------
# engine end-to-end: losslessness + telemetry + eviction under pressure
# ---------------------------------------------------------------------------

def _run_engine(cfg, params, prompts, max_new, prefix, draft_params=None,
                **ekw):
    eng = InferenceEngine(
        cfg, params,
        EngineConfig(num_slots=2, max_seq=32, page_size=4,
                     prefix_cache=prefix, **ekw),
        SamplingParams(), draft_params=draft_params)
    for p in prompts:
        eng.submit(p.copy(), max_new)
    out = eng.run()
    alc = eng.kv.allocator
    assert alc.num_free + alc.num_outstanding == eng.kv.num_pages
    return eng, out


def test_prefix_cache_greedy_bit_identical_plain(tiny):
    """Greedy outputs must be bit-identical with the prefix cache on vs
    off — shared pages + COW + tail-only prefill are pure plumbing."""
    cfg, api, params = tiny
    # short and long tails fill the first (cold) admission wave; the
    # aligned 0-tail prompt arrives warm, so its full-prompt hit COWs
    prompts = _shared_prompts(cfg.vocab, 8, [3, 9, 0], seed=4) \
        + _prompts(cfg.vocab, (6,), seed=5)
    _, off = _run_engine(cfg, params, prompts, 6, prefix=False)
    eng, on = _run_engine(cfg, params, prompts, 6, prefix=True)
    for a, b in zip(off["results"], on["results"]):
        assert a["rid"] == b["rid"]
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
    reg = eng.tel.registry
    assert reg.counter("prefix.hits").value > 0
    assert reg.counter("prefix.cow_copies").value > 0


@pytest.mark.parametrize("mode", ["chain", "tree"])
def test_prefix_cache_greedy_bit_identical_spec(tiny, mode):
    """The on/off pin across both speculative regimes: the tail prefill
    feeds the same decode-path K/V the verify staircase writes, so
    acceptance decisions (and outputs) cannot move."""
    cfg, api, params = tiny
    from repro.core.model_compress import compress_draft, draft_layers
    draft = compress_draft(params, cfg, profile="w4s75")
    kw = dict(spec_draft_layers=draft_layers(cfg, "w4s75"))
    if mode == "chain":
        kw["spec_k"] = 2
    else:
        kw["spec_fanout"] = (2, 2)
    prompts = _shared_prompts(cfg.vocab, 8, [0, 5, 2], seed=6)
    _, off = _run_engine(cfg, params, prompts, 6, prefix=False,
                         draft_params=draft, **kw)
    _, on = _run_engine(cfg, params, prompts, 6, prefix=True,
                        draft_params=draft, **kw)
    for a, b in zip(off["results"], on["results"]):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_prefix_cache_reduces_page_allocs(tiny):
    """The point of the PR: pages-per-request drops when prompts share a
    prefix (TTFT drops with it — the bench sweep measures that side)."""
    cfg, api, params = tiny
    prompts = _shared_prompts(cfg.vocab, 8, [2, 3, 4, 2], seed=7)
    eng_off, _ = _run_engine(cfg, params, prompts, 4, prefix=False)
    eng_on, _ = _run_engine(cfg, params, prompts, 4, prefix=True)
    allocs_off = eng_off.tel.registry.counter("kv.page_allocs").value
    allocs_on = eng_on.tel.registry.counter("kv.page_allocs").value
    assert allocs_on < allocs_off
    assert eng_on.tel.registry.counter("prefix.hit_tokens").value > 0


def test_cached_prefixes_evicted_under_pool_pressure(tiny):
    """Pool sized for ~one resident request: distinct prompts stream
    through with the cache on, so admission must EVICT stale cached
    prefixes (instead of deadlocking on cache-held pages) and the run
    must drain completely."""
    cfg, api, params = tiny
    prompts = _prompts(cfg.vocab, (9, 10, 11, 9), seed=8)
    eng = InferenceEngine(
        cfg, params,
        EngineConfig(num_slots=2, max_seq=16, page_size=4, num_pages=4,
                     prefix_cache=True),
        SamplingParams())
    for p in prompts:
        eng.submit(p, 4)
    res = eng.run()
    assert len(res["results"]) == 4
    assert all(r["n_generated"] == 4 for r in res["results"])
    reg = eng.tel.registry
    assert reg.counter("prefix.evicted_pages").value > 0
    alc = eng.kv.allocator
    assert alc.num_free + alc.num_outstanding == eng.kv.num_pages
    assert alc.num_outstanding == eng.kv.prefix.cached_pages


def test_prefix_cache_with_preemption_lossless(tiny):
    """Preempt-and-recompute under the prefix cache: the victim's decref
    leaves shared pages alive for their other references, and its folded
    re-admission re-matches the cached prefix — greedy outputs still
    bit-identical to the cache-off run."""
    cfg, api, params = tiny
    shared = _shared_prompts(cfg.vocab, 6, [0, 1], seed=9)
    big = np.arange(10, dtype=np.int32)
    # the low-pri shared pair fills the 9-page pool first (6 + 3 pages);
    # the prio-1 request arrives at poll 2 (decoding underway) and its 7
    # pages can only be served by preempting a low-priority victim
    sched = [(1, shared[0], 16, 0), (1, shared[1], 4, 0), (2, big, 16, 1)]

    def run(prefix):
        eng = InferenceEngine(
            cfg, params,
            EngineConfig(num_slots=2, max_seq=32, page_size=4,
                         num_pages=9, prefix_cache=prefix),
            SamplingParams())
        out = eng.run(source=_PollSource(sched))
        return eng, out

    eng_off, off = run(False)
    eng_on, on = run(True)
    assert eng_off.metrics.summary()["preemptions"] > 0
    assert eng_on.metrics.summary()["preemptions"] > 0
    off_by = {r["rid"]: r["tokens"] for r in off["results"]}
    assert len(on["results"]) == len(off_by) == 3
    for r in on["results"]:
        np.testing.assert_array_equal(r["tokens"], off_by[r["rid"]])


# ---------------------------------------------------------------------------
# conservation storm
# ---------------------------------------------------------------------------

def test_refcount_conservation_storm(tiny):
    """Randomized admit/insert/release/evict churn under prefix-share
    traffic: ``num_free + num_outstanding == num_pages`` after EVERY
    operation, zero leaked pages at drain, refcounts all 0 or cache-held."""
    cfg, api, _ = tiny
    rng = np.random.default_rng(0)
    kv = PagedKVCache(cfg, api, num_slots=4, max_seq=32, page_size=4,
                      num_pages=24, prefix_cache=True)
    pool = _shared_prompts(cfg.vocab, 8, [0, 2, 5, 7], seed=10) \
        + _prompts(cfg.vocab, (6, 9), seed=11)
    live = {}
    for step in range(300):
        op = rng.random()
        free_slots = [s for s in range(4) if s not in live]
        if op < 0.5 and free_slots:
            slot = int(rng.choice(free_slots))
            prompt = pool[int(rng.integers(len(pool)))]
            n = len(prompt) + int(rng.integers(1, 8))
            if kv.can_admit(n, prompt=prompt):
                kv.assign(slot, n, prompt=prompt)
                kv.prefix_insert(slot, prompt)
                live[slot] = prompt
        elif op < 0.85 and live:
            slot = int(rng.choice(list(live)))
            kv.release(slot)                      # finish OR preempt: same
            del live[slot]                        # decref path either way
        elif kv.prefix.cached_pages:
            kv.prefix.evict_for(int(rng.integers(1, 3)))
        alc = kv.allocator
        assert alc.num_free + alc.num_outstanding == kv.num_pages, step
    for slot in list(live):
        kv.release(slot)
    alc = kv.allocator
    assert alc.num_free + alc.num_outstanding == kv.num_pages
    # every outstanding page is cache-held (refcount exactly 1)...
    assert alc.num_outstanding == kv.prefix.cached_pages
    assert alc.num_shared == 0
    # ...and flushing the cache returns the pool to fully free: zero leaks
    kv.prefix.flush()
    assert alc.num_free == kv.num_pages
    assert all(alc.refcount(p) == 0 for p in range(kv.num_pages))
