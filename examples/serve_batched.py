"""Batched serving with GQSA-compressed weights through the
continuous-batching engine: compare FP vs W4 vs GQSA-W4S50 throughput,
TTFT and TPOT at equal slots/requests — plus the same GQSA deployment
with self-speculative decoding (--spec K drafts per round from a second,
more aggressively compressed cut of the same checkpoint; the multi-token
verify keeps the output token-for-token identical to plain GQSA serving).

    PYTHONPATH=src python examples/serve_batched.py [--spec 4]
    PYTHONPATH=src python examples/serve_batched.py --spec 4 \
        --draft-profile w4s75

Observability (DESIGN.md §10): pass ``--trace out.json`` to any
``repro.launch.serve`` run to export a Chrome trace of the engine's
phase spans (prefill / decode_segment / draft / verify / sync / evict)
with per-request flow arrows — load it at https://ui.perfetto.dev —
and ``--stats-interval 2`` to print a one-line [stats] snapshot (queue
depth, free KV pages, spec acceptance/ladder) every 2 seconds:

    PYTHONPATH=src python -m repro.launch.serve --requests 8 --spec 4 \
        --trace /tmp/serve_trace.json --stats-interval 2

Load-conditioned serving (DESIGN.md §11): instead of submitting every
request up front, ``--workload`` replays a seeded arrival process
(open-loop Poisson/bursty or a closed-loop user population) through the
engine's timed-admission path, and ``--slo`` judges each request
against TTFT/TPOT/e2e deadlines — printing attainment, goodput (tokens
delivered within SLO per second) and per-miss phase attribution:

    PYTHONPATH=src python -m repro.launch.serve \
        --workload 'process=poisson,rate=20,requests=16,prompt=4:12' \
        --slo ttft=500,tpot=50 --slo-json /tmp/slo.json

Overload resilience (DESIGN.md §12): ``--deadline MS`` gives every
request a TTFT deadline — queued requests that provably cannot meet it
are shed pre-prefill and show up as first-class ``shed`` verdicts in
the SLO ledger (distinct from ``miss``), while KV-pool pressure first
degrades the speculative ladder and then preempts lower-priority slots
losslessly (generated tokens fold into the prompt and re-prefill
resumes bit-identically). ``--chaos SPEC`` injects seeded faults
(alloc_fail / latency / device_err / nan_logits) to exercise those
recovery paths; two runs with the same ``--seed`` replay bit-identically
(compare the printed ``[digest]`` lines):

    PYTHONPATH=src python -m repro.launch.serve \
        --workload 'process=poisson,rate=200,requests=32,prompt=4:12' \
        --deadline 100 --slo ttft=100 \
        --chaos alloc_fail=0.05,latency=0.02,nan_logits=0.05 --seed 11

Shared-prefix KV reuse (DESIGN.md §13): ``--prefix-cache`` turns on
the radix prefix cache over refcounted copy-on-write pages — requests
whose prompts share full token blocks (system prompts, few-shot
templates, the ``prefix_share``/``prefix_pool`` workload knobs above)
map the shared pages into their block tables and prefill only the
unshared tail, so TTFT and page traffic drop with the share ratio
while greedy outputs stay bit-identical to a cache-off run (same
``[digest]``). The run prints a ``[prefix]`` hit/miss/COW/eviction
summary:

    PYTHONPATH=src python -m repro.launch.serve --prefix-cache \
        --page-size 4 --workload 'process=poisson,rate=50,requests=16,\
prompt=24:24,prefix_share=0.8,prefix_pool=4,prefix_len=20'

Chunked prefill (DESIGN.md §14): ``--chunked-prefill N`` splits each
admitted prompt into N-token chunks fed one per scheduling boundary,
interleaved into one-step decode segments — a monolithic admission no
longer stalls co-resident decodes for the whole prompt's prefill in
one inter-token gap. Pure scheduling: greedy outputs (and the printed
``[digest]``) are bit-identical to a monolithic run in every mode,
and ``--slo stall=MS`` gates the worst single stall a request saw
(needs ``--trace``):

    PYTHONPATH=src python -m repro.launch.serve --chunked-prefill 8 \
        --trace /tmp/trace.json --slo stall=50 \
        --workload 'process=poisson,rate=20,requests=16,prompt=24:64'
"""
import argparse

from repro.launch import serve


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec", type=int, default=4,
                    help="draft length K for the speculative run (0: skip)")
    ap.add_argument("--draft-profile", default="w4",
                    help="draft compression profile for the speculative run")
    args = ap.parse_args(argv)

    base = ["--arch", "llama2_7b", "--reduced",
            "--requests", "6", "--slots", "3", "--max-new", "8",
            "--max-seq", "48", "--page-size", "8"]
    runs = [("none", []), ("w4", []), ("gqsa", [])]
    if args.spec > 0:
        runs.append(("gqsa", ["--spec", str(args.spec),
                              "--draft-profile", args.draft_profile]))

    results = {}
    for comp, extra in runs:
        label = comp if not extra else f"{comp}+spec{args.spec}"
        print(f"\n=== compress={label} ===")
        results[label] = serve.main(base + ["--compress", comp] + extra)
    print("\nsummary (CPU wall-clock; on TPU the GQSA bytes win dominates):")
    for label, r in results.items():
        line = (f"  {label:10s}: {r['tok_per_s']:6.1f} tok/s | "
                f"TTFT p50 {r['ttft_ms_p50']:7.1f}ms | "
                f"TPOT p50 {r['tpot_ms_p50']:6.2f}ms")
        if r.get("spec_rounds"):
            line += f" | acceptance {r['acceptance_rate']:.0%}"
        print(line)


if __name__ == "__main__":
    main()
