"""Batched serving with GQSA-compressed weights through the
continuous-batching engine: compare FP vs W4 vs GQSA-W4S50 throughput,
TTFT and TPOT at equal slots/requests.

    PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch import serve


def main():
    results = {}
    for comp in ("none", "w4", "gqsa"):
        print(f"\n=== compress={comp} ===")
        results[comp] = serve.main([
            "--arch", "llama2_7b", "--reduced", "--compress", comp,
            "--requests", "6", "--slots", "3", "--max-new", "8",
            "--max-seq", "48", "--page-size", "8"])
    print("\nsummary (CPU wall-clock; on TPU the GQSA bytes win dominates):")
    for comp, r in results.items():
        print(f"  {comp:5s}: {r['tok_per_s']:6.1f} tok/s | "
              f"TTFT p50 {r['ttft_ms_p50']:7.1f}ms | "
              f"TPOT p50 {r['tpot_ms_p50']:6.2f}ms")


if __name__ == "__main__":
    main()
