"""Quickstart: the GQSA public API in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. build a small LM, 2. compress one linear layer with GQSA, 3. compress the
whole model, 4. compare outputs and footprints, 5. decode with packed
weights.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.gqs_layer import (GQSAConfig, apply_linear, compress_linear)
from repro.core.model_compress import compress_params, compression_report
from repro.core.pruning import PruneConfig
from repro.core.quant import QuantConfig
from repro.core.saliency import HessianStats
from repro.models.registry import get_model

# --- 1. a single linear layer --------------------------------------------
rng = np.random.default_rng(0)
w = jnp.asarray(rng.normal(size=(256, 512)), jnp.float32)   # [out, in]
x = jnp.asarray(rng.normal(size=(4, 512)), jnp.float32)

# calibrate on representative inputs (Hessian-diag saliency, paper eq. 4)
stats = HessianStats.init(512, diag_only=True).update(x)

gqsa = GQSAConfig(
    quant=QuantConfig(bits=4, group_size=16),        # W4, groups of 16
    prune=PruneConfig(sparsity=0.5, group_size=16),  # drop 50% of groups
)
packed = compress_linear(w, stats, gqsa)
y_fp = x @ w.T
y_gqsa = apply_linear(packed, x)
bsr = packed["bsr"]
print(f"one linear: rel err "
      f"{float(jnp.linalg.norm(y_gqsa - y_fp) / jnp.linalg.norm(y_fp)):.3f}, "
      f"kept groups/row {bsr.idx.shape[1]}/{512 // 16}")

# --- 2. a whole model ------------------------------------------------------
cfg = get_config("llama2_7b", reduced=True)   # tiny variant of the paper's
api = get_model(cfg)                          # own benchmark model
params = api.init_params(jax.random.PRNGKey(0), cfg)
packed_model = compress_params(params, cfg, gqsa)
rep = compression_report(params["layers"], packed_model["layers"])
print(f"model blocks: fp16-equiv {rep['fp16_bytes']/1e6:.2f} MB -> "
      f"packed {rep['packed_bytes']/1e6:.2f} MB "
      f"({rep['ratio_vs_fp16']:.2f}x vs fp16)")

# --- 3. decode with packed weights ----------------------------------------
tokens = jnp.zeros((2, 1), jnp.int32)
cache = api.init_cache(cfg, 2, 16)
for pos in range(4):
    logits, cache = api.decode_step(packed_model, cache, tokens,
                                    jnp.int32(pos), cfg)
    tokens = jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32)
print("decoded 4 tokens with GQSA weights:", np.asarray(tokens).ravel())
