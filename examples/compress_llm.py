"""Full GQSA pipeline (paper Figure 2) on a freshly trained small LM:

    train FP -> calibrate -> group-prune -> BQPO -> E2E-OQP -> pack -> serve

    PYTHONPATH=src python examples/compress_llm.py [--sparsity 0.5]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.bqpo import BQPOConfig
from repro.core.e2e_oqp import E2EConfig
from repro.core.gqs_layer import GQSAConfig
from repro.core.pipeline import gqsa_compress, oneshot
from repro.core.pruning import PruneConfig
from repro.data.pipeline import SyntheticLM
from repro.launch.steps import build_train_step, make_dist
from repro.models.registry import get_model, lm_loss
from repro.optim import adamw
from repro.optim.schedule import warmup_cosine

CFG = ModelConfig(name="compress-demo", family="dense",
                  n_layers=3, d_model=96, n_heads=4, n_kv_heads=2,
                  d_ff=256, vocab=256, dtype="float32",
                  attn_block_q=64, attn_block_k=64, remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--train-steps", type=int, default=400)
    args = ap.parse_args()

    cfg = CFG
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    data = SyntheticLM(cfg.vocab, 64, 16, seed=0)

    # 1. train the FP model
    step = jax.jit(build_train_step(
        cfg, make_dist(cfg, None), adamw.AdamWConfig(lr=5e-3),
        lr_fn=warmup_cosine(5e-3, 20, args.train_steps)))
    opt = adamw.init_state(params)
    t0 = time.time()
    for i in range(args.train_steps):
        batch = {k: jnp.asarray(v) for k, v in data.host_batch(i).items()}
        params, opt, m = step(params, opt, batch)
    print(f"trained FP model: loss {float(m['loss']):.3f} "
          f"({time.time()-t0:.0f}s)")

    calib = [{k: jnp.asarray(v) for k, v in data.host_batch(1000 + i).items()}
             for i in range(4)]
    ev = [{k: jnp.asarray(v) for k, v in data.host_batch(2000 + i).items()}
          for i in range(4)]

    def ppl(p):
        import numpy as np
        ls = [float(lm_loss(api.forward(p, b, cfg)[0], b["labels"]))
              for b in ev]
        return float(np.exp(np.mean(ls)))

    print(f"FP held-out ppl: {ppl(params):.3f}")

    gqsa = GQSAConfig(prune=PruneConfig(sparsity=args.sparsity,
                                        group_size=16))

    # 2. one-shot baseline (no optimization)
    p0 = oneshot(params, calib, cfg, gqsa)
    print(f"one-shot W4S{int(args.sparsity*100)} ppl: {ppl(p0):.3f}")

    # 3. the paper's two-stage pipeline
    t0 = time.time()
    packed, report = gqsa_compress(
        params, calib, cfg, gqsa,
        bqpo_cfg=BQPOConfig(steps=40, lr=1e-4),
        e2e_cfg=E2EConfig(steps=80, lr=5e-4), verbose=True)
    print(f"BQPO+E2E-OQP W4S{int(args.sparsity*100)} ppl: {ppl(packed):.3f} "
          f"({time.time()-t0:.0f}s)")
    print(f"e2e loss {report['e2e_loss'][0]:.3f} -> "
          f"{report['e2e_loss'][-1]:.3f}")


if __name__ == "__main__":
    main()
