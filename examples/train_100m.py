"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the production train loop (checkpointing, watchdog, cosine schedule).

NOTE: ~100M params on one CPU core is slow; the default invocation uses
--scale 0.25 (~7M params) to finish in minutes. Pass --scale 1.0 for the
full 100M run (identical code path).

    PYTHONPATH=src python examples/train_100m.py [--scale 1.0] [--steps 300]
"""
import argparse
import dataclasses

from repro.configs.base import ModelConfig
from repro.launch import train as T

BASE = ModelConfig(name="lm-100m", family="dense",
                   n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                   d_ff=2048, vocab=32000, dtype="float32", remat=False,
                   attn_block_q=128, attn_block_k=128)


def scaled(scale: float) -> ModelConfig:
    return dataclasses.replace(
        BASE,
        n_layers=max(2, int(BASE.n_layers * scale)),
        d_model=max(64, int(BASE.d_model * scale) // 16 * 16),
        n_heads=max(2, int(BASE.n_heads * scale)),
        n_kv_heads=max(1, int(BASE.n_kv_heads * scale)),
        d_ff=max(128, int(BASE.d_ff * scale) // 16 * 16),
        vocab=max(512, int(BASE.vocab * scale)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
    args = ap.parse_args()

    cfg = scaled(args.scale)
    n = cfg.n_params()
    print(f"model: {cfg.n_layers}L d{cfg.d_model} ff{cfg.d_ff} "
          f"v{cfg.vocab} ~= {n/1e6:.1f}M params")

    # register the scaled config under a temporary name and drive the
    # production launcher
    import repro.configs.registry as R
    import types
    mod = types.ModuleType("repro.configs.lm_100m")
    mod.full = lambda: cfg
    mod.reduced = lambda: cfg
    import sys
    sys.modules["repro.configs.lm_100m"] = mod
    R.ARCH_IDS.append("lm_100m")

    T.main(["--arch", "lm_100m", "--steps", str(args.steps),
            "--batch", "8", "--seq", "256", "--lr", "3e-4",
            "--warmup", "30", "--ckpt-dir", args.ckpt_dir,
            "--save-every", "100", "--log-every", "20"])


if __name__ == "__main__":
    main()
