"""Paper Figure 8: PPL vs sparsity level (left) and vs group size (right).
Reproduced claims: robust <=50% sparsity, degrading beyond 60% but no
collapse at 80%; smaller groups quantize/prune better."""
from benchmarks.common import (calib_batches, emit, eval_ppl,
                               held_out_batches, trained_tiny_model)
from repro.core.gqs_layer import GQSAConfig
from repro.core.model_compress import compress_params
from repro.core.pruning import PruneConfig
from repro.core.quant import QuantConfig


def main():
    cfg, params = trained_tiny_model()
    ev = held_out_batches(cfg)

    for s in (0.2, 0.4, 0.5, 0.6, 0.8):
        gq = compress_params(params, cfg, GQSAConfig(
            prune=PruneConfig(sparsity=s, group_size=16)))
        emit(f"fig8/sparsity_{int(s*100)}", 0,
             f"ppl={eval_ppl(gq, cfg, ev):.3f}")

    for g in (8, 16, 32):  # 64 does not divide bench d_ff=352
        gq = compress_params(params, cfg, GQSAConfig(
            quant=QuantConfig(bits=4, group_size=g),
            prune=PruneConfig(sparsity=0.5, group_size=g)))
        emit(f"fig8/group_{g}", 0, f"ppl={eval_ppl(gq, cfg, ev):.3f}")


if __name__ == "__main__":
    main()
