"""Paper Table 6 / Appendix B: BQPO vs BQPO+E2E-OQP ablation (plus the
no-optimization oneshot arm). Reproduced claim: each stage improves PPL."""
from benchmarks.common import (calib_batches, emit, eval_ppl,
                               held_out_batches, trained_tiny_model)
from repro.core.bqpo import BQPOConfig
from repro.core.e2e_oqp import E2EConfig
from repro.core.pipeline import gqsa_compress, oneshot, stage1_only


def main():
    cfg, params = trained_tiny_model()
    ev = held_out_batches(cfg)
    calib = calib_batches(cfg)

    p0 = oneshot(params, calib, cfg)
    emit("table6/oneshot_w4s50", 0, f"ppl={eval_ppl(p0, cfg, ev):.3f}")

    p1 = stage1_only(params, calib, cfg, bqpo_cfg=BQPOConfig(steps=40,
                                                             lr=5e-4))
    emit("table6/bqpo_w4s50", 0, f"ppl={eval_ppl(p1, cfg, ev):.3f}")

    p2, _ = gqsa_compress(params, calib, cfg,
                          bqpo_cfg=BQPOConfig(steps=40, lr=5e-4),
                          e2e_cfg=E2EConfig(steps=60, lr=5e-4))
    emit("table6/bqpo_e2e_w4s50", 0, f"ppl={eval_ppl(p2, cfg, ev):.3f}")


if __name__ == "__main__":
    main()
