"""Paper Table 13: serving throughput (tokens/s), FP vs W4 vs W4S50,
via the continuous-batching serve loop."""
from benchmarks.common import emit
from repro.launch import serve


def main():
    for comp in ("none", "w4", "gqsa"):
        res = serve.main(["--arch", "llama2_7b", "--reduced",
                          "--compress", comp, "--requests", "6",
                          "--slots", "3", "--max-new", "8",
                          "--max-seq", "48"])
        emit(f"table13/{comp}", 1e6 / max(res["tok_per_s"], 1e-9),
             f"tok_per_s={res['tok_per_s']:.1f}")


if __name__ == "__main__":
    main()
