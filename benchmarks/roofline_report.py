"""Aggregate the dry-run artifacts into the roofline table (§Roofline)."""
import json
from pathlib import Path

from benchmarks.common import emit

DRY = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


def main():
    if not DRY.is_dir():
        print("# no dry-run artifacts; run repro.launch.dryrun --all")
        return
    for f in sorted(DRY.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") != "ok":
            emit(f"roofline/{f.stem}", 0, "status=fail")
            continue
        r = rec["roofline"]
        dom = r["dominant"]
        dom_s = r[f"{dom}_s"]
        emit(f"roofline/{f.stem}", dom_s * 1e6,
             f"dominant={dom};compute_s={r['compute_s']:.3g};"
             f"memory_s={r['memory_s']:.3g};"
             f"collective_s={r['collective_s']:.3g};"
             f"useful={r.get('useful_ratio') or 0:.3f}")


if __name__ == "__main__":
    main()
