"""Bench-regression gate: compare BENCH_serve.json against a baseline.

The serve trajectory (BENCH_serve.json) is only a guarded signal if a
regression FAILS CI instead of silently shifting the committed numbers.
This gate compares two bench snapshots record-by-record with per-metric
*noise tolerances*:

* **timing-class** metrics (wall-clock dependent: ``us_per_call``,
  ``*_ms*``, ``tok_per_s``, goodput, speedups, SLO attainment) get a
  generous relative tolerance — CI runners are noisy and slower than
  dev machines, so only order-of-magnitude regressions should trip;
* **quality-class** metrics (deterministic given seeds: acceptance
  rates, accepted lengths, compression ratios, traffic models,
  bytes/token) get a tight tolerance — these should not move at all
  unless the algorithm changed.

Direction matters: ``tok_per_s`` dropping is a regression,
``us_per_call`` dropping is an improvement. Keys the gate doesn't
recognize are informational and never gated (``derived`` strings,
``schema``, workload-shape constants).

    python benchmarks/check_bench.py --baseline old.json --current new.json
    python benchmarks/check_bench.py --self-test   # gate-of-the-gate

``--self-test`` proves the gate mechanism on the committed baseline:
baseline-vs-itself must pass, and an injected synthetic regression must
be caught (also pinned by unit tests in ``tests/test_check_bench.py``).
Exit codes: 0 clean, 1 regression(s), 2 usage/self-test-mechanism error.
"""
from __future__ import annotations

import argparse
import copy
import dataclasses
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional

REPO_BENCH = Path(__file__).resolve().parent.parent / "BENCH_serve.json"

# substring patterns, first match wins: (pattern, direction, tol_class)
# direction +1 = higher is better, -1 = lower is better
_RULES = (
    ("us_per_call", -1, "timing"),
    # TPOT p99 from the chunked-prefill sweep (DESIGN.md §14): named
    # without the _ms infix so this row, not the generic _ms row, is
    # what documents the guarded statistic — the inter-token tail
    # chunked admission exists to bound
    ("tpot_p99", -1, "timing"),
    ("_ms", -1, "timing"),
    ("itl", -1, "timing"),
    ("goodput", +1, "timing"),
    ("tok_per_s", +1, "timing"),
    ("attainment", +1, "timing"),      # deadline hits ride the wall clock
    ("speedup", +1, "timing"),         # a ratio of two timings
    ("acceptance_rate", +1, "quality"),
    ("accepted_len", +1, "quality"),
    ("compression", +1, "quality"),
    ("traffic_ratio", +1, "quality"),
    ("bytes_per_token", -1, "quality"),
    # prefix-cache effectiveness (DESIGN.md §13): offline runs are
    # deterministic given the workload seed, so page traffic per request
    # and cache hits are quality-class, not wall-clock
    ("pages_per_request", -1, "quality"),
    ("prefix_hits", +1, "quality"),
)


def classify(key: str):
    """``(direction, tol_class)`` for a metric key, or None if the key
    is informational (never gated)."""
    for pat, direction, cls in _RULES:
        if pat in key:
            return direction, cls
    return None


@dataclasses.dataclass
class Regression:
    record: str
    key: str
    baseline: float
    current: float
    change: float                      # signed relative, + = increased
    tolerance: float
    direction: int

    def __str__(self):
        worse = "rose" if self.direction < 0 else "fell"
        return (f"{self.record}.{self.key}: {worse} "
                f"{self.baseline:g} -> {self.current:g} "
                f"({self.change:+.1%}, tolerance {self.tolerance:.0%})")


def compare(baseline: Dict, current: Dict, tol_timing: float = 0.5,
            tol_quality: float = 0.05,
            require_all: bool = False) -> List[Regression]:
    """Gated-metric comparison of two bench snapshots (dicts keyed by
    record name, records as emitted by ``benchmarks.common.emit``).
    Records present only in the baseline are skipped unless
    ``require_all`` (CI currents are merged supersets); records present
    only in the current are new and always fine."""
    out: List[Regression] = []
    tol = {"timing": tol_timing, "quality": tol_quality}
    for name, brec in sorted(baseline.items()):
        crec = current.get(name)
        if crec is None:
            if require_all:
                out.append(Regression(name, "<record>", 1.0, 0.0, -1.0,
                                      0.0, +1))
            continue
        for key, bval in brec.items():
            if not isinstance(bval, (int, float)) \
                    or isinstance(bval, bool):
                continue
            rule = classify(key)
            if rule is None:
                continue
            cval = crec.get(key)
            if not isinstance(cval, (int, float)) \
                    or isinstance(cval, bool):
                continue                 # metric dropped: informational
            if bval == 0.0:
                continue                 # no relative scale to judge by
            direction, cls = rule
            change = (cval - bval) / abs(bval)
            if direction * change < -tol[cls]:
                out.append(Regression(name, key, float(bval), float(cval),
                                      change, tol[cls], direction))
    return out


def _load(path) -> Dict:
    doc = json.loads(Path(path).read_text())
    if not isinstance(doc, dict):
        raise ValueError(f"{path}: want a dict of records")
    return doc


def inject_regression(records: Dict, factor: float = 10.0,
                      key: Optional[str] = None):
    """Return a deep copy with one gated metric degraded by ``factor``
    (in its bad direction) — the synthetic regression the self-test and
    unit tests feed the gate. Returns (copy, record_name, key)."""
    bad = copy.deepcopy(records)
    for name, rec in sorted(bad.items()):
        for k, v in rec.items():
            if key is not None and k != key:
                continue
            if not isinstance(v, (int, float)) or isinstance(v, bool) \
                    or v == 0.0:
                continue
            rule = classify(k)
            if rule is None:
                continue
            direction, _ = rule
            rec[k] = v * factor if direction < 0 else v / factor
            return bad, name, k
    raise ValueError("no gated metric found to inject a regression into")


def self_test(baseline_path) -> int:
    base = _load(baseline_path)
    clean = compare(base, base)
    if clean:
        print("self-test FAILED: baseline vs itself reported regressions:")
        for r in clean:
            print(f"  {r}")
        return 2
    bad, name, key = inject_regression(base)
    caught = compare(base, bad)
    if not any(r.record == name and r.key == key for r in caught):
        print(f"self-test FAILED: injected 10x regression on "
              f"{name}.{key} was not caught")
        return 2
    print(f"self-test ok: baseline clean, injected regression on "
          f"{name}.{key} caught ({len(base)} records)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=str(REPO_BENCH),
                    help="baseline snapshot (default: committed "
                         "BENCH_serve.json)")
    ap.add_argument("--current", default=str(REPO_BENCH),
                    help="snapshot to gate (default: BENCH_serve.json)")
    ap.add_argument("--tol-timing", type=float, default=0.5,
                    help="relative tolerance for wall-clock metrics")
    ap.add_argument("--tol-quality", type=float, default=0.05,
                    help="relative tolerance for deterministic metrics")
    ap.add_argument("--require-all", action="store_true",
                    help="a baseline record missing from the current "
                         "snapshot is a failure")
    ap.add_argument("--self-test", action="store_true",
                    help="verify the gate mechanism on the baseline: "
                         "clean at baseline, catches an injected "
                         "synthetic regression")
    args = ap.parse_args(argv)

    if args.self_test:
        return self_test(args.baseline)

    try:
        base, cur = _load(args.baseline), _load(args.current)
    except (OSError, ValueError) as e:
        print(f"check_bench: {e}")
        return 2
    regs = compare(base, cur, tol_timing=args.tol_timing,
                   tol_quality=args.tol_quality,
                   require_all=args.require_all)
    n_gated = sum(1 for rec in base.values() for k in rec
                  if classify(k) is not None)
    if regs:
        print(f"BENCH REGRESSION: {len(regs)} metric(s) beyond tolerance "
              f"(of {n_gated} gated):")
        for r in regs:
            print(f"  {r}")
        return 1
    print(f"bench gate clean: {n_gated} gated metrics across "
          f"{len(base)} baseline records within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
