"""Beyond-paper ablation: saliency criterion for group pruning.

Paper eq. 4 uses s_i = w_i^2/[H^-1]_ii^2 (diag => w^2 * H_ii^2). Because the
Hessian factor is SHARED across output rows, on narrow from-scratch models
it correlates the row masks (whole input dims get pruned) — magnitude wins
one-shot; after the two-stage pipeline the criteria converge.
"""
import dataclasses

from benchmarks.common import (calib_batches, emit, eval_ppl,
                               held_out_batches, trained_tiny_model)
from repro.core.bqpo import BQPOConfig, bqpo, calibrate_block_stats, \
    block_to_fake_quant, capture_block_io
from repro.core.e2e_oqp import E2EConfig
from repro.core.gqs_layer import GQSAConfig
from repro.core.pipeline import gqsa_compress, oneshot


def main():
    cfg, params = trained_tiny_model()
    ev = held_out_batches(cfg)
    calib = calib_batches(cfg)

    for mode in ("hessian", "wanda", "magnitude"):
        gq = GQSAConfig(saliency=mode)
        p0 = oneshot(params, calib, cfg, gq)
        emit(f"fig_saliency/{mode}_oneshot", 0,
             f"ppl={eval_ppl(p0, cfg, ev):.3f}")

    # the two-stage pipeline washes the criterion difference out
    for mode in ("hessian", "magnitude"):
        gq = GQSAConfig(saliency=mode)
        p2, _ = gqsa_compress(params, calib, cfg, gq,
                              bqpo_cfg=BQPOConfig(steps=30, lr=1e-4),
                              e2e_cfg=E2EConfig(steps=60, lr=5e-4))
        emit(f"fig_saliency/{mode}_2stage", 0,
             f"ppl={eval_ppl(p2, cfg, ev):.3f}")


if __name__ == "__main__":
    main()
