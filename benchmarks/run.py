"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (plus '#' comment lines)."""
import argparse
import importlib
import sys
import traceback

MODULES = [
    "table1_quality",      # Table 1/14/15: PPL vs W2 / 2:4 / sparsity grid
    "table6_stages",       # Table 6: BQPO vs +E2E-OQP
    "fig8_ablation",       # Figure 8: sparsity & group-size ablations
    "fig6_kernel",         # Figure 6: GEMV kernel vs sparsity/group
    "fig5_balance",        # Figure 5: task-centric load balance
    "table4_latency",      # Table 4/16: decode latency fp/w4/gqsa
    "table10_tradeoff",    # Table 10/11: quant-only vs sparse-only vs GQSA
    "table13_throughput",  # Table 13: serving tokens/s
    "tableC_wa_quant",     # Appendix C: W4A8S50
    "fig_saliency",        # beyond-paper: saliency-criterion ablation
    "roofline_report",     # EXPERIMENTS.md §Roofline source
]

# serving-regime group (--serve): engine-path benchmarks that write the
# BENCH_serve.json trajectory gated by benchmarks/check_bench.py. Their
# main() takes an argv list (defaults apply when given []).
SERVE_MODULES = [
    "serve_engine",        # engine vs seed loop, load sweep, SLO goodput
    "spec_decode",         # self-speculative serving ladder
    "paged_attn",          # paged decode-attention kernel
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of modules")
    ap.add_argument("--serve", action="store_true",
                    help="run the serving-regime group (engine, spec "
                         "decode, paged attention) instead of the "
                         "paper-table group")
    args = ap.parse_args()
    mods = args.only.split(",") if args.only \
        else (SERVE_MODULES if args.serve else MODULES)
    print("name,us_per_call,derived")
    failures = 0
    for m in mods:
        try:
            fn = importlib.import_module(f"benchmarks.{m}").main
            fn([]) if m in SERVE_MODULES else fn()
        except Exception:
            failures += 1
            print(f"# FAILED {m}")
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
