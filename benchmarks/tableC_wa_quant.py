"""Paper Appendix C: GQSA under weight-activation quantization (W4A8S50).
Activations are int8-quantized per tensor at GQS layer inputs."""
import jax
import jax.numpy as jnp

from benchmarks.common import (emit, eval_ppl, held_out_batches,
                               trained_tiny_model)
from repro.core import gqs_layer
from repro.core.gqs_layer import GQSAConfig
from repro.core.model_compress import compress_params
from repro.core.quant import int8_symmetric_dequant, int8_symmetric_quant


def main():
    cfg, params = trained_tiny_model()
    ev = held_out_batches(cfg)
    packed = compress_params(params, cfg, GQSAConfig())

    emit("tableC/w4a16s50", 0, f"ppl={eval_ppl(packed, cfg, ev):.3f}")

    # monkey-patch the linear entry to fake-quantize activations to int8
    orig = gqs_layer.apply_linear

    def a8_linear(p, x, **kw):
        if isinstance(p, dict) and "bsr" in p:
            q, s = int8_symmetric_quant(x)
            x = int8_symmetric_dequant(q, s, x.dtype)
        return orig(p, x, **kw)

    gqs_layer.apply_linear = a8_linear
    # model modules hold their own reference; patch at call sites
    import repro.models.layers as L
    orig_L = L.apply_linear
    L.apply_linear = a8_linear
    try:
        emit("tableC/w4a8s50", 0, f"ppl={eval_ppl(packed, cfg, ev):.3f}")
    finally:
        gqs_layer.apply_linear = orig
        L.apply_linear = orig_L


if __name__ == "__main__":
    main()
