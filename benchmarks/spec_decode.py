"""Speculative decoding speedup vs the PR-1 non-speculative engine.

One trained checkpoint (the cached benchmark LM), two parameter sets: the
deployed GQSA-W4S50 target and an aggressively compressed draft profile.
The engine drafts K greedy tokens per round in one fused call and
verifies them in one multi-token target call, so a round costs ~2
dispatches for 1..K+1 tokens — the speedup is governed by the acceptance
rate, which we sweep across draft profiles (the paper's
quality/compression trade becomes a pure throughput knob: the verify
step pins output quality to the target regardless of draft quality).

Decode throughput (decode-phase wall time / decoded tokens) is compared
at equal slots/requests/budgets; the acceptance bar is >= 1.5x at the
default draft profile AND greedy spec output identical to non-spec.

    PYTHONPATH=src python benchmarks/spec_decode.py [--spec-k 4]
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.bqpo import BQPOConfig
from repro.core.e2e_oqp import E2EConfig
from repro.core.gqs_layer import GQSAConfig
from repro.core.model_compress import compress_draft, draft_layers
from repro.core.pipeline import gqsa_compress
from repro.core.pruning import PruneConfig
from repro.core.quant import QuantConfig
from repro.engine import (EngineConfig, InferenceEngine, SamplingParams,
                          Telemetry)

try:
    from benchmarks.common import (calib_batches, emit, held_out_batches,
                                   trained_spec_model, write_bench_json)
except ImportError:      # direct `python benchmarks/spec_decode.py` run
    from common import (calib_batches, emit, held_out_batches,
                        trained_spec_model, write_bench_json)

# the regime the headline speedup + acceptance bar are measured at:
# depth-pruned drafter (first layer of the dual-exit checkpoint) — the
# only profile whose draft STEP is structurally cheaper than a target
# step in every cost regime (ops, FLOPs and bytes), which is what
# converts acceptance into wall-clock speedup
DEFAULT_DRAFT_PROFILE = "w4l12"
# acceptance regimes: draft == target (ceiling), full-depth quant-only
# (high acceptance, expensive drafts), aggressive width sparsity, and
# the depth-pruned default
PROFILES = ("self", "w4", "w4s75", "w4l12")
# token-tree sweep (DESIGN.md §8): trees buy accepted length exactly
# where chains collapse — a drafter whose top-1 acceptance is poor but
# whose top-k sibling set covers the target. w2s75 is that drafter here
# (chain top-1 acceptance ~0.3; top-2-per-depth coverage ~0.7), and the
# comparison currency is mean ACCEPTED DRAFTS PER VERIFY DISPATCH at
# EQUAL verify token budget: tree fanout F (N nodes, T = N+1) vs chain
# K = N (same T). Greedy, so accepted lengths are deterministic.
TREE_DRAFT_PROFILE = "w2s75"
TREE_FANOUTS = ((2, 2), (4, 2), (2, 2, 2))
HEADLINE_FANOUT = (2, 2, 2)


def bench_prompts(cfg, n, lens=(12, 20, 8, 16)):
    """In-distribution prompts sliced from a held-out synthetic batch."""
    toks = np.asarray(held_out_batches(cfg, n=1)[0]["tokens"])
    return [toks[i % toks.shape[0], :lens[i % len(lens)]].astype(np.int32)
            for i in range(n)]


def make_runner(cfg, params, prompts, *, slots, max_new, max_seq, spec_k=0,
                spec_fanout=None, draft=None, draft_layers=None):
    def once(telemetry=None):
        eng = InferenceEngine(
            cfg, params,
            EngineConfig(num_slots=slots, max_seq=max_seq, spec_k=spec_k,
                         spec_fanout=spec_fanout,
                         spec_draft_layers=draft_layers),
            SamplingParams(), draft_params=draft, telemetry=telemetry)
        for p in prompts:
            eng.submit(p, max_new)
        out = eng.run()
        return out["metrics"], out["results"]
    return once


def best_of(runs):
    """Keep the fastest decode phase (the host is noisy; acceptance and
    outputs are deterministic across repeats, only wall-clock varies)."""
    return min(runs, key=lambda mr: mr[0]["itl_ms_mean"])


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec-k", type=int, default=4)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--repeats", type=int, default=3)
    args, _ = ap.parse_known_args(argv)

    # serving-regime checkpoint: decode steps are dispatch/op-bound (like
    # DMA-bound real-size decode, the K+1-token verify costs about one
    # step) and the dual-exit training makes the depth-pruned drafter of
    # the SAME checkpoint accurate -> honest acceptance
    cfg, fp_params = trained_spec_model()
    # deployed W4S50 target through the FULL pipeline (calibrate -> BQPO
    # -> E2E-OQP -> pack): the paper's deployment, and what makes verify
    # against the target meaningful (one-shot W4S50 wrecks a tiny model)
    target, _ = gqsa_compress(
        fp_params, calib_batches(cfg, n=4), cfg,
        GQSAConfig(quant=QuantConfig(bits=4, group_size=16),
                   prune=PruneConfig(sparsity=0.5, group_size=16)),
        bqpo_cfg=BQPOConfig(steps=40, lr=5e-4),
        e2e_cfg=E2EConfig(steps=60, lr=5e-4))
    prompts = bench_prompts(cfg, args.requests)
    kw = dict(slots=args.slots, max_new=args.max_new, max_seq=args.max_seq)

    runners = {"baseline": make_runner(cfg, target, prompts, **kw)}
    for profile in PROFILES:
        if profile == "self":
            draft, dl = target, None
        else:
            draft = compress_draft(fp_params, cfg, profile=profile)
            dl = draft_layers(cfg, profile)
        runners[profile] = make_runner(cfg, target, prompts,
                                       spec_k=args.spec_k, draft=draft,
                                       draft_layers=dl, **kw)
    for once in runners.values():
        once()                           # compile everything up front
    # interleaved passes: host load drift hits every config equally
    runs = {name: [] for name in runners}
    for _ in range(args.repeats):
        for name, once in runners.items():
            runs[name].append(once())

    base_m, base_r = best_of(runs["baseline"])
    base_itl = base_m["itl_ms_mean"]
    base_out = {r["rid"]: list(r["tokens"]) for r in base_r}
    emit("spec_decode_baseline", base_itl * 1e3,
         f"{1e3 / base_itl:.1f} decode tok/s (non-speculative engine)",
         decode_tok_per_s=1e3 / base_itl, tok_per_s=base_m["tok_per_s"],
         ttft_ms_p50=base_m["ttft_ms_p50"],
         tpot_ms_p50=base_m["tpot_ms_p50"])

    speedups = {}
    for profile in PROFILES:
        m, r = best_of(runs[profile])
        itl = m["itl_ms_mean"]
        speedup = base_itl / itl
        speedups[profile] = speedup
        lossless = ({q["rid"]: list(q["tokens"]) for q in r} == base_out)
        assert lossless, f"spec output diverged from target ({profile})"
        emit(f"spec_decode_{profile}", itl * 1e3,
             f"{1e3 / itl:.1f} decode tok/s ({speedup:.2f}x, "
             f"acceptance {m['acceptance_rate']:.0%}, "
             f"TTFT p50 {m['ttft_ms_p50']:.0f}ms)",
             decode_tok_per_s=1e3 / itl, speedup_vs_nonspec=speedup,
             acceptance_rate=m["acceptance_rate"],
             tok_per_s=m["tok_per_s"], ttft_ms_p50=m["ttft_ms_p50"],
             spec_k=args.spec_k, lossless=lossless)

    default = speedups[DEFAULT_DRAFT_PROFILE]
    print(f"# speculative decode speedups vs PR-1 engine (K={args.spec_k}): "
          + ", ".join(f"{p}={s:.2f}x" for p, s in speedups.items()))
    print(f"# default profile {DEFAULT_DRAFT_PROFILE}: {default:.2f}x "
          f"(bar: >= 1.5x)")

    # where a speculative round spends its wall clock (telemetry phase
    # spans, DESIGN.md §10): one traced post-warmup pass of the default
    # profile. draft/verify spans are dispatch-side, the segment's sync
    # span holds the blocked device time — together the Table-6-style
    # stage decomposition. Not a per-call timing (timed=False).
    tel = Telemetry(trace=True)
    mphase, _ = runners[DEFAULT_DRAFT_PROFILE](tel)
    totals = tel.tracer.phase_totals()
    emit("spec_decode_phase_breakdown", 0.0,
         f"phase ms of a traced K={args.spec_k} "
         f"{DEFAULT_DRAFT_PROFILE} run: "
         + ", ".join(f"{k} {v['ms']:.0f}ms"
                     for k, v in sorted(totals.items(),
                                        key=lambda kv: -kv[1]["ms"])[:4]),
         timed=False, spec_k=args.spec_k,
         draft_profile=DEFAULT_DRAFT_PROFILE,
         acceptance_rate=mphase["acceptance_rate"],
         **{f"{k}_ms": v["ms"] for k, v in totals.items()})

    tree_results = tree_sweep(cfg, fp_params, target, prompts, args,
                              base_out)
    write_bench_json()
    return speedups, tree_results


def tree_sweep(cfg, fp_params, target, prompts, args, base_out):
    """Token-tree fanout sweep (DESIGN.md §8): for each fanout F (N
    nodes) run the tree AND the equal-verify-budget chain K = N with the
    same weak drafter, and emit accepted drafts per verify dispatch +
    decode tok/s. Greedy acceptance is deterministic, so the headline
    bar — tree accepted length STRICTLY above the chain at equal budget
    — needs no repeat averaging; wall-clock keeps the best of 2 passes
    (1 compile + 1 timed) like the profile sweep."""
    from repro.engine.spec import TreeTemplate

    draft = compress_draft(fp_params, cfg, profile=TREE_DRAFT_PROFILE)
    dl = draft_layers(cfg, TREE_DRAFT_PROFILE)
    kw = dict(slots=args.slots, max_new=args.max_new, max_seq=args.max_seq)
    results = {}
    for fanout in TREE_FANOUTS:
        n = TreeTemplate(fanout).n_nodes
        tree_once = make_runner(cfg, target, prompts, spec_fanout=fanout,
                                draft=draft, draft_layers=dl, **kw)
        chain_once = make_runner(cfg, target, prompts, spec_k=n,
                                 draft=draft, draft_layers=dl, **kw)
        mt, rt = best_of([tree_once() for _ in range(2)])
        mk, rk = best_of([chain_once() for _ in range(2)])
        assert {q["rid"]: list(q["tokens"]) for q in rt} == base_out, \
            f"tree spec output diverged from target ({fanout})"
        assert {q["rid"]: list(q["tokens"]) for q in rk} == base_out, \
            f"chain-{n} spec output diverged from target"
        name = "x".join(str(f) for f in fanout)
        results[fanout] = (mt["accepted_len_mean"], mk["accepted_len_mean"])
        emit(f"spec_tree_{name}", mt["itl_ms_mean"] * 1e3,
             f"{1e3 / mt['itl_ms_mean']:.1f} decode tok/s, "
             f"{mt['accepted_len_mean']:.2f} accepted/verify vs "
             f"{mk['accepted_len_mean']:.2f} chain-K{n} at equal "
             f"T={n + 1} verify budget ({TREE_DRAFT_PROFILE})",
             decode_tok_per_s=1e3 / mt["itl_ms_mean"],
             accepted_len_per_verify=mt["accepted_len_mean"],
             chain_equal_budget_accepted_len=mk["accepted_len_mean"],
             chain_equal_budget_tok_per_s=1e3 / mk["itl_ms_mean"],
             verify_tokens_per_round=n + 1,
             acceptance_rate=mt["acceptance_rate"],
             draft_profile=TREE_DRAFT_PROFILE, lossless=True)
    tree_alen, chain_alen = results[HEADLINE_FANOUT]
    print("# token-tree accepted/verify vs equal-budget chain "
          f"({TREE_DRAFT_PROFILE}): "
          + ", ".join(f"{f}={t:.2f} (chain {c:.2f})"
                      for f, (t, c) in results.items()))
    assert tree_alen > chain_alen, (
        f"tree {HEADLINE_FANOUT} accepted length {tree_alen:.3f} not above "
        f"equal-budget chain {chain_alen:.3f}")
    print(f"# headline {HEADLINE_FANOUT}: {tree_alen:.2f} accepted/verify "
          f"> chain {chain_alen:.2f} at equal verify token budget")
    return results


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
