"""Engine throughput: continuous-batching engine vs the seed decode loop.

The seed loop (pre-engine ``launch/serve.py``) fed prompts one token per
step through a shared position counter, popped the request queue LIFO and
round-tripped every token through ``int()`` on the host. The engine
prefills whole prompts in one batched call, tracks per-slot positions over
a paged KV cache and feeds sampled tokens back on device. Equal
slots/requests/budgets on the reduced config; the acceptance bar is
>= 2x engine tokens/s over the seed loop.

    PYTHONPATH=src python benchmarks/serve_engine.py [--compress gqsa,none]
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.engine import (EngineConfig, InferenceEngine, ResilienceConfig,
                          SamplingParams, Telemetry)
from repro.engine.loadgen import (SLO, SLOLedger, WorkloadSpec, generate,
                                  make_source)
from repro.launch.serve import compressed_params, make_requests
from repro.models.registry import get_model

try:
    from benchmarks.common import emit, write_bench_json
except ImportError:      # direct `python benchmarks/serve_engine.py` run
    from common import emit, write_bench_json


def decode_attention_series(cfg, ctx: int = 1024, page_size: int = 16):
    """Per-step decode-attention time at the serve model's head geometry
    (one layer, ragged [B=4] batch at ``ctx``): dense full-table gather
    (pre-PR hot path) vs the occupied-page-clamped reference the engine
    now runs off-TPU. Tracks the decode-attention share of the serve
    trajectory across PRs (the fused kernel's own win is O(live tokens)
    HBM traffic — see benchmarks/paged_attn.py, whose ``make_case``
    supplies the workload so the table/sentinel convention has one
    definition)."""
    try:
        from benchmarks.paged_attn import make_case, time_dense_vs_clamped
    except ImportError:
        from paged_attn import make_case, time_dense_vs_clamped
    case = make_case(ctx, page_size, 1, b=4, kh=cfg.n_kv_heads,
                     r=cfg.n_heads // cfg.n_kv_heads, d=cfg.hd)
    us_dense, us_clamp = time_dense_vs_clamped(case)
    emit("serve_decode_attn_dense", us_dense,
         f"per-layer decode attention, dense [B,{case[4].shape[1]}]-page "
         f"gather @ ctx {ctx}")
    emit("serve_decode_attn_clamped", us_clamp,
         f"occupied-page clamp: {us_dense / max(us_clamp, 1e-9):.2f}x "
         f"vs dense @ ctx {ctx}",
         speedup_vs_dense=us_dense / max(us_clamp, 1e-9))


def mla_series(slots: int = 2, requests: int = 6, max_new: int = 8,
               max_seq: int = 64, seed: int = 0):
    """mla_moe serve series (DESIGN.md §9): the paged LATENT cache vs a
    hypothetical dense-KV MLA cache.

    Bytes/token are static math at FULL deepseek-v2-236b geometry (the
    memory claim the latent layout exists for: kv_lora_rank + qk_rope_dim
    floats per token per layer, vs K = H * (nope + rope) plus
    V = H * v_dim for an engine that up-projected at write time); the
    decode tok/s is measured on the reduced config through the full
    engine path (prefill -> paged latent decode -> eviction)."""
    full = get_config("deepseek_v2_236b")
    m = full.mla
    el = jnp.dtype(full.dtype).itemsize
    latent_bt = (m.kv_lora_rank + m.qk_rope_dim) * el * full.n_layers
    dense_bt = full.n_heads * (m.qk_nope_dim + m.qk_rope_dim
                               + m.v_dim) * el * full.n_layers
    # not a timing (timed=False): the payload rides in the
    # machine-readable extras
    emit("serve_mla_latent_bytes_per_token", 0.0,
         f"{latent_bt / 1024:.1f} KiB/token paged latent row, "
         f"deepseek-v2-236b geometry "
         f"({dense_bt / latent_bt:.1f}x below dense KV)", timed=False,
         latent_bytes_per_token=float(latent_bt),
         dense_bytes_per_token=float(dense_bt),
         compression_vs_dense=dense_bt / latent_bt)

    cfg = get_config("deepseek_v2_236b", reduced=True)
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(seed), cfg)
    prompts = make_requests(requests, cfg.vocab,
                            np.random.default_rng(seed))
    eng = engine_run(cfg, params, prompts, slots, max_new, max_seq)
    emit("serve_engine_mla_moe",
         eng["seconds"] * 1e6 / max(eng["tokens"], 1),
         f"{eng['tok_per_s']:.1f} tok/s on the paged latent cache "
         f"(reduced cell, TTFT p50 {eng['ttft_ms_p50']:.0f}ms)",
         tok_per_s=eng["tok_per_s"], ttft_ms_p50=eng["ttft_ms_p50"],
         tpot_ms_p50=eng["tpot_ms_p50"])


def seed_loop(cfg, params, prompts: List[np.ndarray], slots: int,
              max_new: int, max_seq: int) -> dict:
    """The seed repo's serving loop, verbatim semantics: shared position
    counter, one-token-per-step prompt feeding, LIFO queue, per-token
    host syncs."""
    api = get_model(cfg)
    queue = list(prompts)
    cache = api.init_cache(cfg, slots, max_seq)

    @jax.jit
    def decode(params, cache, tokens, pos):
        logits, cache = api.decode_step(params, cache, tokens, pos, cfg)
        return jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32), cache

    active = [None] * slots
    produced = [0] * slots
    outputs = []
    tokens = jnp.zeros((slots, 1), jnp.int32)
    n_tokens = 0
    pos = 0

    def refill(slot):
        nonlocal tokens
        if queue:
            req = queue.pop()            # the seed's LIFO bug, kept as-is
            active[slot] = req
            produced[slot] = 0
            tokens = tokens.at[slot, 0].set(int(req[0]))

    for s in range(slots):
        refill(s)
    # warmup compile outside the timed region (same courtesy the engine
    # gets via its own warmup below)
    jax.block_until_ready(decode(params, cache, tokens, jnp.int32(0))[0])

    t_start = time.perf_counter()
    while any(a is not None for a in active) and pos < max_seq - 1:
        next_tok, cache = decode(params, cache, tokens, jnp.int32(pos))
        pos += 1
        for s in range(slots):
            if active[s] is None:
                continue
            req = active[s]
            if pos < len(req):
                tokens = tokens.at[s, 0].set(int(req[pos]))
            else:
                tokens = tokens.at[s, 0].set(int(next_tok[s]))
                produced[s] += 1
                n_tokens += 1
                if produced[s] >= max_new:
                    outputs.append((len(req), produced[s]))
                    active[s] = None
                    refill(s)
    dt = time.perf_counter() - t_start
    return {"requests": len(outputs), "tokens": n_tokens, "seconds": dt,
            "tok_per_s": n_tokens / max(dt, 1e-9)}


def engine_run(cfg, params, prompts, slots, max_new, max_seq,
               warmup: bool = True, telemetry=None) -> dict:
    def once(tel=None):
        eng = InferenceEngine(
            cfg, params, EngineConfig(num_slots=slots, max_seq=max_seq),
            SamplingParams(), telemetry=tel)
        for p in prompts:
            eng.submit(p, max_new)
        return eng.run()["metrics"]
    if warmup:
        once()                           # compile prefill/decode once
    return once(telemetry)


def phase_breakdown_series(cfg, params, prompts, slots, max_new, max_seq):
    """Where a post-warmup serve run spends its wall clock, by engine
    phase span (telemetry tracer, DESIGN.md §10) — the Table-6-style
    stage decomposition of the serve trajectory. Not a per-call timing
    (timed=False): the payload is the per-phase totals."""
    tel = Telemetry(trace=True)
    m = engine_run(cfg, params, prompts, slots, max_new, max_seq,
                   telemetry=tel)
    totals = tel.tracer.phase_totals()
    top = sorted(totals.items(), key=lambda kv: -kv[1]["ms"])[:3]
    emit("serve_engine_phase_breakdown", 0.0,
         "phase ms of a traced serve run: "
         + ", ".join(f"{k} {v['ms']:.0f}ms" for k, v in top),
         timed=False, tok_per_s=m["tok_per_s"],
         **{f"{k}_ms": v["ms"] for k, v in totals.items()})


def load_sweep_series(cfg, params, slots, max_seq, seed=0):
    """Load-conditioned serve trajectory (DESIGN.md §11): the same
    seeded workload replayed open-loop at increasing offered rates
    through the engine's timed-admission path, each run judged against
    one fixed SLO. A batch-everything-at-t0 run measures capacity; this
    sweep measures what load does to it — tok/s, TTFT p99, SLO
    attainment and goodput vs offered req/s, plus one bursty point
    (gamma arrivals, same mean rate) for the clumped-arrival tail."""
    slo = SLO.parse("ttft=2000,tpot=500")
    # compile the engine path for THESE params and the sweep's exact
    # prompt shapes outside the recorded runs: replay the workload once
    # at a fast rate (arrival draws consume the same rng budget at any
    # rate, so the prompt draws — and hence the padded prefill shapes —
    # match every swept run of the same seed)
    warm = generate(WorkloadSpec(process="poisson", rate=64.0, requests=8,
                                 prompt_min=4, prompt_max=10,
                                 max_new_min=6, max_new_max=6, seed=seed),
                    cfg.vocab)
    InferenceEngine(cfg, params,
                    EngineConfig(num_slots=slots, max_seq=max_seq),
                    SamplingParams()).run(source=make_source(warm))
    sweeps = [("poisson", r, 1.0) for r in (2.0, 8.0, 32.0)]
    sweeps.append(("bursty", 8.0, 0.25))
    for process, rate, burstiness in sweeps:
        spec = WorkloadSpec(process=process, rate=rate,
                            burstiness=burstiness, requests=8,
                            prompt_min=4, prompt_max=10,
                            max_new_min=6, max_new_max=6, seed=seed)
        wl = generate(spec, cfg.vocab)
        eng = InferenceEngine(
            cfg, params, EngineConfig(num_slots=slots, max_seq=max_seq),
            SamplingParams())
        m = eng.run(source=make_source(wl))["metrics"]
        ledger = SLOLedger(slo)
        ledger.judge(eng.metrics)
        s = ledger.summary()
        emit(f"serve_load_{process}_r{rate:g}",
             m["seconds"] * 1e6 / max(m["tokens"], 1),
             f"offered {wl.offered_rate:.1f} req/s -> "
             f"{m['tok_per_s']:.1f} tok/s, TTFT p99 "
             f"{m['ttft_ms_p99']:.0f}ms, attainment {s['attainment']:.0%}, "
             f"goodput {s['goodput_tok_per_s']:.1f} tok/s",
             offered_req_per_s=wl.offered_rate, tok_per_s=m["tok_per_s"],
             ttft_ms_p99=m["ttft_ms_p99"], attainment=s["attainment"],
             goodput_tok_per_s=s["goodput_tok_per_s"])


def overload_sweep_series(cfg, params, slots, max_seq, seed=0):
    """Overload cliff (DESIGN.md §12): the same seeded workload offered
    at rates up to far beyond sustainable, against a per-request TTFT
    deadline and a KV pool sized for ~two resident requests. The ladder
    (shed -> degrade -> preempt) turns saturation into bounded verdicts
    instead of unbounded queue wait: goodput holds as offered load
    climbs, the excess lands in ``sheds``. Sheds/preemptions ride as
    machine-readable extras (unclassified by the regression gate —
    counts, not timings); goodput/attainment stay timing-class."""
    slo = SLO.parse("ttft=50")
    rcfg = ResilienceConfig(deadline_ttft_ms=50.0)
    # two priority bands: the high band preempts the low one under pool
    # pressure, so the sweep exercises the whole ladder, not just sheds
    wargs = dict(requests=64, prompt_min=4, prompt_max=10,
                 max_new_min=6, max_new_max=12, priority_levels=2,
                 seed=seed)
    ecfg = EngineConfig(num_slots=slots, max_seq=max_seq, num_pages=3,
                        resilience=rcfg)
    # compile outside the recorded runs (same prompt-shape argument as
    # the load sweep's warmup), without the deadline so every shape the
    # swept runs can hit is actually reached
    warm = generate(WorkloadSpec(process="poisson", rate=64.0, **wargs),
                    cfg.vocab)
    InferenceEngine(cfg, params,
                    dataclasses.replace(ecfg, resilience=None),
                    SamplingParams()).run(source=make_source(warm))
    for rate in (8.0, 64.0, 2000.0):
        wl = generate(WorkloadSpec(process="poisson", rate=rate, **wargs),
                      cfg.vocab)
        eng = InferenceEngine(cfg, params, ecfg, SamplingParams())
        m = eng.run(source=make_source(wl))["metrics"]
        ledger = SLOLedger(slo)
        ledger.judge(eng.metrics)
        s = ledger.summary()
        emit(f"serve_overload_r{rate:g}",
             m["seconds"] * 1e6 / max(m["tokens"], 1),
             f"offered {wl.offered_rate:.0f} req/s -> goodput "
             f"{s['goodput_tok_per_s']:.1f} tok/s, attainment "
             f"{s['attainment']:.0%}, {s['shed']} shed, "
             f"{int(m['preemptions'])} preempted",
             offered_req_per_s=wl.offered_rate, tok_per_s=m["tok_per_s"],
             goodput_tok_per_s=s["goodput_tok_per_s"],
             attainment=s["attainment"], sheds=float(s["shed"]),
             preemptions=float(m["preemptions"]))


def prefix_sweep_series(cfg, params, slots, max_seq, seed=0,
                        prompt_len=120, prefix_len=116):
    """Shared-prefix KV reuse (DESIGN.md §13): the same seeded prompt
    mix replayed offline (submit-everything — deterministic, so page
    traffic is quality-class) through the prefix-cached engine at
    increasing prefix-share ratios. TTFT falls with share (admission
    prefills only the unshared tail) and so does page traffic per
    request (shared blocks are mapped, not re-allocated); both ride the
    records the regression gate guards. Each recorded run gets its own
    same-share warmup engine so compilation of the tail-prefill buckets
    never lands in the timed region."""
    page_size = 4                        # full blocks inside the prefix
    # one slot, not args.slots: an admission wave's requests all stamp
    # their first token when the whole wave's prefill completes, so a
    # wave mixing cold and warm requests charges every member BOTH
    # group dispatches — single-request waves keep each TTFT the cost
    # of that request's own prefill. Long prefix, short tail: a warm
    # admission runs the 4-token tail staircase instead of the padded
    # full-prompt prefill, and the prompt is long enough (its own
    # max_seq, not the serve default) that the compute gap clears
    # per-dispatch host overhead on CPU runners
    slots = 1
    seq = prompt_len + 8
    # fixed seed offset: a representative template draw (the half-share
    # point actually lands 8-of-16 shared, so the sweep measures the
    # share ratio, not one seed's binomial luck)
    wargs = dict(process="poisson", rate=64.0, requests=16,
                 prompt_min=prompt_len, prompt_max=prompt_len,
                 max_new_min=8, max_new_max=8, seed=seed + 6)
    for share in (0.0, 0.5, 1.0):
        pargs = dict(wargs)
        if share > 0:
            pargs.update(prefix_share=share, prefix_pool=2,
                         prefix_len=prefix_len)
        wl = generate(WorkloadSpec(**pargs), cfg.vocab)

        def once():
            eng = InferenceEngine(
                cfg, params,
                EngineConfig(num_slots=slots, max_seq=seq,
                             page_size=page_size, prefix_cache=True,
                             # every request's prefix stays cached (the
                             # unique ones insert too): the sweep
                             # measures reuse, not LRU eviction — the
                             # eviction path has its own tests
                             num_pages=(len(wl.requests) + 2)
                             * (seq // page_size)),
                SamplingParams())
            for r in wl.requests:
                eng.submit(r.prompt, r.max_new, priority=r.priority)
            m = eng.run()["metrics"]
            return m, eng
        once()                           # compile this share's buckets
        m, eng = once()
        reg = eng.tel.registry
        n = len(wl.requests)
        pages = reg.counter("kv.page_allocs").value / n
        hits = reg.counter("prefix.hits").value
        # admission-to-first-token, mean: queue wait at this tiny scale
        # is host-noise-dominated and would bury the prefill savings;
        # the mean (not a p50) interpolates with the warm fraction
        # instead of sitting on the cold side of the mixture
        ttft = 1e3 * sum(rt.first_token_t - rt.admit_t for rt in
                         eng.metrics.requests.values()) / n
        emit(f"serve_prefix_share{int(share * 100)}",
             m["seconds"] * 1e6 / max(m["tokens"], 1),
             f"prefix share {share:.0%}: {pages:.1f} pages/request, "
             f"{int(hits)} prefix hits, TTFT mean "
             f"{ttft:.1f}ms, {m['tok_per_s']:.1f} tok/s",
             pages_per_request=pages, prefix_hits=float(hits),
             prefix_hit_tokens=float(
                 reg.counter("prefix.hit_tokens").value),
             ttft_ms_mean=ttft, tok_per_s=m["tok_per_s"])


def chunked_sweep_series(cfg, params, max_seq, seed=0, budget=8):
    """Chunked prefill vs monolithic admission under load (DESIGN.md
    §14): the same seeded Poisson workload served both ways at rates
    8 and 32 req/s. Two slots and staggered decode budgets keep decode
    occupancy high, so every admission prefill lands inside a live
    decode window — monolithic admission stalls the co-resident's next
    token for the whole prompt's prefill in ONE gap, chunking bounds
    that gap to one budget's worth. The worst such gap rides as
    ``stall_ms_max`` (the ledger's longest-single-prefill-span-overlap
    statistic). Caveats at CPU bench scale: spans are sync-inclusive
    (a final chunk's first-token block drains the two-deep pipeline
    into its span), and reduced-model prefills are so cheap that the
    stall separation only shows under sustained co-residency — the
    conformance test (tests/test_chunked_prefill.py) pins it there;
    these records track the trajectory. TPOT p99 rides as ``tpot_p99``
    (its own check_bench rule) next to TTFT p99 and tok/s, and sits
    slightly HIGHER chunked-on at this scale (per-chunk dispatch
    overhead) — the trade the stall bound buys."""
    slots = 2
    seq = 128
    wargs = dict(process="poisson", requests=16, prompt_min=24,
                 prompt_max=64, max_new_min=4, max_new_max=16, seed=seed)
    for chunk in (0, budget):
        mode = "on" if chunk else "off"
        ecfg = EngineConfig(num_slots=slots, max_seq=seq,
                            prefill_chunk_tokens=chunk)
        # compile this mode's buckets outside the recorded runs (draws
        # consume the same rng budget at any rate, so prompt shapes —
        # and hence the jit buckets — match every swept rate)
        warm = generate(WorkloadSpec(rate=64.0, **wargs), cfg.vocab)
        InferenceEngine(cfg, params, ecfg,
                        SamplingParams()).run(source=make_source(warm))
        for rate in (8.0, 32.0):
            wl = generate(WorkloadSpec(rate=rate, **wargs), cfg.vocab)
            # traced both modes alike (host-append only): the stall
            # statistic is measured from prefill-span overlaps
            tel = Telemetry(trace=True)
            eng = InferenceEngine(cfg, params, ecfg, SamplingParams(),
                                  telemetry=tel)
            m = eng.run(source=make_source(wl))["metrics"]
            stalls = [v.stall_ms for v in
                      SLOLedger(SLO(stall_ms=1e9)).judge(eng.metrics,
                                                         tel.tracer)
                      if v.stall_ms == v.stall_ms]
            stall = max(stalls) if stalls else 0.0
            emit(f"serve_chunked_{mode}_r{rate:g}",
                 m["seconds"] * 1e6 / max(m["tokens"], 1),
                 f"chunked={mode} @ {wl.offered_rate:.1f} req/s: "
                 f"stall max {stall:.1f}ms, TPOT p99 "
                 f"{m['tpot_ms_p99']:.1f}ms, TTFT p99 "
                 f"{m['ttft_ms_p99']:.0f}ms, {m['tok_per_s']:.1f} tok/s",
                 stall_ms_max=stall, tpot_p99=m["tpot_ms_p99"],
                 ttft_ms_p99=m["ttft_ms_p99"], tok_per_s=m["tok_per_s"],
                 offered_req_per_s=wl.offered_rate)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--compress", default="gqsa,w4,none")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args, _ = ap.parse_known_args(argv)

    cfg = get_config("llama2_7b", reduced=True)
    speedups = []
    for comp in args.compress.split(","):
        cargs = argparse.Namespace(compress=comp, sparsity=0.5,
                                   group_size=16)
        params = compressed_params(cfg, cargs, jax.random.PRNGKey(0))
        prompts = make_requests(args.requests, cfg.vocab,
                                np.random.default_rng(args.seed))
        seed = seed_loop(cfg, params, prompts, args.slots, args.max_new,
                         args.max_seq)
        eng = engine_run(cfg, params, prompts, args.slots, args.max_new,
                         args.max_seq)
        speedup = eng["tok_per_s"] / max(seed["tok_per_s"], 1e-9)
        speedups.append(speedup)
        emit(f"serve_seed_loop_{comp}",
             seed["seconds"] * 1e6 / max(seed["tokens"], 1),
             f"{seed['tok_per_s']:.1f} tok/s",
             tok_per_s=seed["tok_per_s"])
        emit(f"serve_engine_{comp}",
             eng["seconds"] * 1e6 / max(eng["tokens"], 1),
             f"{eng['tok_per_s']:.1f} tok/s ({speedup:.1f}x seed, "
             f"TTFT p50 {eng['ttft_ms_p50']:.0f}ms, "
             f"TPOT p50 {eng['tpot_ms_p50']:.1f}ms)",
             tok_per_s=eng["tok_per_s"], speedup_vs_seed=speedup,
             ttft_ms_p50=eng["ttft_ms_p50"],
             tpot_ms_p50=eng["tpot_ms_p50"])
    # phase breakdown of the last compress config's serve run
    phase_breakdown_series(cfg, params, prompts, args.slots,
                           args.max_new, args.max_seq)
    decode_attention_series(cfg)
    # load sweep on the paper configuration (GQSA-compressed serve)
    gq = argparse.Namespace(compress="gqsa", sparsity=0.5, group_size=16)
    gq_params = compressed_params(cfg, gq, jax.random.PRNGKey(0))
    load_sweep_series(cfg, gq_params, args.slots, args.max_seq,
                      seed=args.seed)
    overload_sweep_series(cfg, gq_params, args.slots, args.max_seq,
                          seed=args.seed)
    chunked_sweep_series(cfg, gq_params, args.max_seq, seed=args.seed)
    prefix_sweep_series(cfg, gq_params, args.slots, args.max_seq,
                        seed=args.seed)
    mla_series(slots=args.slots, requests=args.requests,
               max_new=args.max_new, max_seq=args.max_seq, seed=args.seed)
    print(f"# engine vs seed-loop speedups: "
          f"{', '.join(f'{s:.1f}x' for s in speedups)}")
    write_bench_json()
    return speedups


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
