"""Paper Table 1 (and 14/15 in-kind): held-out PPL of GQSA W4S{20..50}
vs FP16 / W4 / W2 / 2:4 semi-structured pruning.

Reproduced claims: (a) GQSA W4S50 beats W2 by a wide margin; (b) GQSA tracks
2:4-pattern quality while compressing ~3x more; (c) PPL degrades smoothly
with sparsity.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (calib_batches, emit, eval_ppl,
                               held_out_batches, trained_tiny_model)
from repro.core.gqs_layer import GQSAConfig
from repro.core.model_compress import compress_params, compress_params_w4
from repro.core.pruning import PruneConfig, two_four_mask
from repro.core.quant import QuantConfig


def two_four_params(params, cfg):
    """Magnitude 2:4 semi-structured baseline (kept FP16-equivalent)."""
    import jax
    from repro.core.model_compress import COMPRESSIBLE, EXCLUDED, _walk

    def fn(pstr, node):
        w = node["w"]
        lead = w.shape[:-2]
        n, k = w.shape[-2:]
        flat = jnp.reshape(w, (-1, n, k))
        outs = [flat[i] * two_four_mask(jnp.abs(flat[i])).astype(w.dtype)
                for i in range(flat.shape[0])]
        return {"w": jnp.stack(outs).reshape(w.shape)}
    return _walk(params, "", fn)


def main():
    cfg, params = trained_tiny_model()
    ev = held_out_batches(cfg)

    ppl_fp = eval_ppl(params, cfg, ev)
    emit("table1/fp16", 0, f"ppl={ppl_fp:.3f}")

    w4 = compress_params_w4(params, cfg, QuantConfig(bits=4, group_size=16))
    emit("table1/w4", 0, f"ppl={eval_ppl(w4, cfg, ev):.3f}")

    w2 = compress_params_w4(params, cfg, QuantConfig(bits=2, group_size=16))
    emit("table1/w2", 0, f"ppl={eval_ppl(w2, cfg, ev):.3f}")

    tf = two_four_params(params, cfg)
    emit("table1/2to4_fp16", 0, f"ppl={eval_ppl(tf, cfg, ev):.3f}")

    for s in (0.2, 0.3, 0.4, 0.5):
        gq = compress_params(params, cfg, GQSAConfig(
            prune=PruneConfig(sparsity=s, group_size=16)))
        emit(f"table1/gqsa_w4s{int(s*100)}_oneshot", 0,
             f"ppl={eval_ppl(gq, cfg, ev):.3f}")

    # the paper's headline arm: W4S50 *with* the two-stage optimization
    from repro.core.bqpo import BQPOConfig
    from repro.core.e2e_oqp import E2EConfig
    from repro.core.pipeline import gqsa_compress
    gq2, _ = gqsa_compress(params, calib_batches(cfg), cfg,
                           bqpo_cfg=BQPOConfig(steps=60, lr=5e-4),
                           e2e_cfg=E2EConfig(steps=80, lr=5e-4))
    emit("table1/gqsa_w4s50_2stage", 0,
         f"ppl={eval_ppl(gq2, cfg, ev):.3f}")


if __name__ == "__main__":
    main()
