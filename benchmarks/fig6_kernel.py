"""Paper Figure 6: GEMV kernel speed vs sparsity and group size.

No TPU here, so two views are reported per point:
  * measured CPU wall-clock of the jitted XLA reference path (relative
    ordering: higher sparsity => fewer bytes => faster), and
  * the derived TPU byte-traffic model (kernels/ops.gemv_bytes_model) +
    v5e HBM roofline time — the quantity the paper's figure actually tracks,
    since decode GEMV is bandwidth-bound.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core.bsr import pack_dense
from repro.core.pruning import PruneConfig, group_mask
from repro.core.quant import QuantConfig, group_minmax_params, pack_int4, \
    quantize
from repro.core.saliency import group_saliency
from repro.kernels import ops, ref
from repro.launch.hlo_analysis import HBM_BW

N = K = 1024  # paper uses 4096x4096; scaled for CPU wall-clock


def main():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(N, K)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, K)), jnp.float32)

    # dense fp baseline
    dense = jax.jit(lambda xx: xx @ w.T)
    us = time_call(dense, x)
    bts = ops.dense_bytes_model(N, K, bits=16)
    emit("fig6/fp16_dense", us,
         f"tpu_us={bts['total_bytes']/HBM_BW*1e6:.2f};"
         f"bytes={bts['total_bytes']}")

    # W4 dense baseline
    qcfg = QuantConfig(bits=4, group_size=16)
    s, z = group_minmax_params(w, qcfg)
    qw = pack_int4(quantize(w, s, z, qcfg))
    w4 = jax.jit(lambda xx: ref.w4_matmul_ref(xx, qw, s, z, 16))
    us = time_call(w4, x)
    bts = ops.dense_bytes_model(N, K, bits=4, group_size=16)
    emit("fig6/w4_dense", us,
         f"tpu_us={bts['total_bytes']/HBM_BW*1e6:.2f};"
         f"bytes={bts['total_bytes']}")

    for g in (8, 16, 32):
        for sp in (0.25, 0.5, 0.75):
            gm = group_mask(group_saliency(jnp.square(w), g),
                            PruneConfig(sparsity=sp, group_size=g))
            bsr = pack_dense(w, gm, QuantConfig(bits=4, group_size=g))
            f = jax.jit(lambda xx: ref.gqsa_gemv_ref(xx, bsr))
            us = time_call(f, x)
            bts = ops.gemv_bytes_model(bsr)
            emit(f"fig6/gqsa_g{g}_s{int(sp*100)}", us,
                 f"tpu_us={bts['total_bytes']/HBM_BW*1e6:.2f};"
                 f"bytes={bts['total_bytes']}")


if __name__ == "__main__":
    main()
