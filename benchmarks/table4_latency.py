"""Paper Table 4/16: end-to-end decode latency, FP vs W4 vs GQSA-W4S50,
across cache lengths. Measured: serve_step wall-clock on CPU (XLA path).
Derived: modeled TPU per-step weight+cache bytes / HBM bandwidth."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call, trained_tiny_model
from repro.core.gqs_layer import GQSAConfig
from repro.core.model_compress import compress_params, compress_params_w4
from repro.core.quant import QuantConfig
from repro.launch.hlo_analysis import HBM_BW
from repro.launch.steps import build_serve_step, make_dist
from repro.models.registry import get_model


def _weight_bytes(tree) -> int:
    return sum(np.prod(l.shape) * jnp.dtype(l.dtype).itemsize
               for l in jax.tree_util.tree_leaves(tree))


def main():
    cfg, params = trained_tiny_model()
    api = get_model(cfg)
    dist = make_dist(cfg, None)
    B = 4

    variants = {
        "fp32": params,
        "w4": compress_params_w4(params, cfg, QuantConfig(group_size=16)),
        "gqsa_w4s50": compress_params(params, cfg, GQSAConfig()),
    }
    for seq in (128, 256, 512):
        for name, p in variants.items():
            cache = api.init_cache(cfg, B, seq)
            step = jax.jit(build_serve_step(cfg, dist))
            tok = jnp.zeros((B, 1), jnp.int32)
            us = time_call(step, p, cache, tok, jnp.int32(seq - 2))
            wb = _weight_bytes(p)
            cb = _weight_bytes(cache)
            tpu_us = (wb + cb) / HBM_BW * 1e6
            emit(f"table4/{name}_seq{seq}", us,
                 f"tpu_us={tpu_us:.1f};weight_bytes={wb};cache_bytes={cb}")


if __name__ == "__main__":
    main()
