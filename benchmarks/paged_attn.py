"""Paged decode attention: dense per-step page gather vs the fused kernel.

Sweeps context length x page size x T (decode / speculative verify) on a
continuous-batching-shaped workload: a ragged batch where one slot sits at
the sweep's context length and the rest are 8x shorter, with block tables
sized for a 2x larger max_seq (the engine's worst-case reservation) — the
regime where the dense gather pays O(B * max_pages) per layer per step.

Three series per point, emitted into BENCH_serve.json via ``common.emit``:

* ``ref_dense``   — the pre-PR hot path: gather ALL table entries
  (sentinels included) into a dense [B, MP*ps, KH, D] copy, then attend.
* ``ref_clamped`` — the jnp fallback after the occupied-page clamp
  (``decode_step(max_live_pages=...)``): gather only allocated pages.
  This is a *measured* wall-clock speedup on any backend.
* ``kernel``      — the Pallas kernel's HBM traffic model (it streams
  only live pages; O(live tokens)), as a dense/kernel byte ratio. The
  kernel itself is parity-checked here at a small shape — wall-clock is
  only meaningful on a real TPU (interpret mode is a Python emulator).

    PYTHONPATH=src python benchmarks/paged_attn.py [--quick]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref as kref

try:
    from benchmarks.common import emit, time_call, write_bench_json
except ImportError:      # direct `python benchmarks/paged_attn.py` run
    from common import emit, time_call, write_bench_json

B, KH, R, D = 4, 2, 4, 64          # decode-shaped GQA attention


def kv_bytes_per_token(kh: int, d: int) -> int:
    """K + V bytes per cached token at deployed bf16 width."""
    return 2 * kh * d * 2


def make_case(ctx: int, page_size: int, t: int, seed: int = 0,
              b: int = B, kh: int = KH, r: int = R, d: int = D):
    """Ragged batch: slot 0 at ``ctx`` tokens, the rest at ctx/8; tables
    sized for 2*ctx (reservation) so MP = 2 * ctx/ps table entries.
    Shared with ``benchmarks/serve_engine.decode_attention_series`` so
    the table/sentinel convention lives in one place."""
    g = np.random.default_rng(seed)
    mp = 2 * ctx // page_size                       # table width (max_seq)
    lens = np.asarray([ctx] + [max(ctx // 8, t)] * (b - 1), np.int64)
    occ = -(-lens // page_size)                     # occupied pages
    num_pages = int(occ.sum()) + 1
    # distinct pages per slot, occupied prefix + sentinel tail
    ids = np.split(g.permutation(num_pages - 1).astype(np.int32),
                   np.cumsum(occ)[:-1])
    bt = np.full((b, mp), num_pages, np.int32)
    for i, pg in enumerate(ids):
        bt[i, :len(pg)] = pg
    lengths = (lens[:, None] - (t - 1) + np.arange(t)[None, :]).clip(1)
    q = jnp.asarray(g.normal(size=(b, t, kh * r, d)), jnp.float32)
    kp = jnp.asarray(g.normal(size=(num_pages, page_size, kh, d)) * 0.1,
                     jnp.float32)
    vp = jnp.asarray(g.normal(size=(num_pages, page_size, kh, d)) * 0.1,
                     jnp.float32)
    return (q, kp, vp, jnp.asarray(lengths.astype(np.int32)),
            jnp.asarray(bt), int(occ.max()), lens)


def time_dense_vs_clamped(case):
    """Wall-clock the jnp reference over a ``make_case`` workload: full
    table (dense gather) vs occupied-page clamp. Shared with
    ``serve_engine.decode_attention_series``."""
    q, kp, vp, lengths, bt, occ, _ = case
    ref = jax.jit(lambda *a: kref.paged_attention_ref(*a))
    us_dense = time_call(ref, q, kp, vp, lengths, bt)
    us_clamp = time_call(ref, q, kp, vp, lengths, bt[:, :occ])
    return us_dense, us_clamp


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smallest sweep point only (CI smoke)")
    args, _ = ap.parse_known_args(argv)

    # parity gate first: the kernel must match the oracle before any
    # traffic claim is emitted (interpret mode, small shape)
    q, kp, vp, lengths, bt, occ, _ = make_case(64, 16, 3, seed=7)
    o_ref = kref.paged_attention_ref(q, kp, vp, lengths, bt)
    o_ker = ops.paged_decode_attention(q, kp, vp, lengths, bt,
                                       use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)
    print("# kernel parity vs dense-gather reference: OK (max|err| "
          f"{float(jnp.max(jnp.abs(o_ker - o_ref))):.2e})")

    sweep = [(1024, 16, 1)] if args.quick else [
        (1024, 16, 1), (1024, 16, 4), (1024, 128, 1),
        (8192, 16, 1), (8192, 16, 4), (8192, 128, 1),
    ]
    speedup_8k = []
    for ctx, ps, t in sweep:
        case = make_case(ctx, ps, t)
        q, kp, vp, lengths, bt, occ, lens = case
        mp = bt.shape[1]
        us_dense, us_clamp = time_dense_vs_clamped(case)
        wall = us_dense / max(us_clamp, 1e-9)
        # HBM byte model: dense gather touches every table entry; the
        # kernel streams each slot's live pages only
        item = kv_bytes_per_token(KH, D)
        dense_bytes = B * mp * ps * item
        live_bytes = int((-(-lens // ps) * ps).sum()) * item
        traffic = dense_bytes / max(live_bytes, 1)
        tag = f"c{ctx}_ps{ps}_t{t}"
        emit(f"paged_attn_ref_dense_{tag}", us_dense,
             f"dense gather [B,{mp}*{ps}] ({dense_bytes/2**20:.1f} MiB KV "
             f"read/layer/step)", kv_bytes=dense_bytes)
        emit(f"paged_attn_ref_clamped_{tag}", us_clamp,
             f"occupied-page clamp: {wall:.2f}x vs dense",
             kv_bytes=occ * B * ps * item, speedup_vs_dense=wall)
        emit(f"paged_attn_kernel_{tag}", 0.0,
             f"live-page stream: {traffic:.2f}x less KV traffic than "
             f"dense ({live_bytes/2**20:.2f} MiB)", timed=False,
             kv_bytes=live_bytes, traffic_ratio_vs_dense=traffic)
        if ctx >= 8192:
            speedup_8k.append(wall)
        print(f"#   ctx={ctx} ps={ps} T={t}: dense {us_dense:.0f}us, "
              f"clamped {us_clamp:.0f}us ({wall:.2f}x), kernel traffic "
              f"{traffic:.2f}x less")
    if speedup_8k:
        emit("paged_attn_speedup_8k", 0.0,
             f"min measured clamped-vs-dense speedup at 8k ctx: "
             f"{min(speedup_8k):.2f}x", timed=False,
             speedup=round(min(speedup_8k), 2))
    write_bench_json()


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
