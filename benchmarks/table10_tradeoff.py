"""Paper Table 10/11: joint accuracy/speed trade-off grid — quantization
only, sparsity only, and GQSA combined. Reproduced claim: combining the two
dimensions dominates either alone at equal compression."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (emit, eval_ppl, held_out_batches,
                               trained_tiny_model)
from repro.core.gqs_layer import GQSAConfig
from repro.core.model_compress import (COMPRESSIBLE, _walk, compress_params,
                                       compress_params_w4)
from repro.core.pruning import PruneConfig, group_mask
from repro.core.quant import QuantConfig
from repro.core.saliency import group_saliency


def sparsity_only(params, cfg, s):
    """FP16 weights, group-pruned only (the paper's S% rows)."""
    def fn(pstr, node):
        w = node["w"]
        lead = w.shape[:-2]
        n, k = w.shape[-2:]
        flat = jnp.reshape(w, (-1, n, k))
        outs = []
        for i in range(flat.shape[0]):
            gm = group_mask(group_saliency(jnp.square(flat[i]), 16),
                            PruneConfig(sparsity=s, group_size=16))
            outs.append(flat[i] * jnp.repeat(gm, 16, axis=1).astype(w.dtype))
        return {"w": jnp.stack(outs).reshape(w.shape)}
    return _walk(params, "", fn)


def _bytes(tree):
    return sum(np.prod(l.shape) * jnp.dtype(l.dtype).itemsize
               for l in jax.tree_util.tree_leaves(tree))


def main():
    cfg, params = trained_tiny_model()
    ev = held_out_batches(cfg)
    base = _bytes(params) / 2   # fp16-equivalent baseline

    for s in (0.2, 0.5):
        p = sparsity_only(params, cfg, s)
        emit(f"table10/s{int(s*100)}_only", 0,
             f"ppl={eval_ppl(p, cfg, ev):.3f};compress=1.0x(dense-stored)")
    for bits in (8, 4, 2):
        if bits > 4:
            # nibble packing holds codes < 16: W8 uses the dense
            # quant-dequant representation (same math, fp storage)
            from repro.core.quant import fake_quant
            def fn(pstr, node, _b=bits):
                return {"w": fake_quant(node["w"],
                                        QuantConfig(bits=_b, group_size=16))}
            p = _walk(params, "", fn)
        else:
            p = compress_params_w4(params, cfg,
                                   QuantConfig(bits=bits, group_size=16))
        emit(f"table10/w{bits}_only", 0,
             f"ppl={eval_ppl(p, cfg, ev):.3f}")
    for s in (0.5,):
        p = compress_params(params, cfg, GQSAConfig(
            prune=PruneConfig(sparsity=s, group_size=16)))
        ratio = base / _bytes(p)
        emit(f"table10/gqsa_w4s{int(s*100)}", 0,
             f"ppl={eval_ppl(p, cfg, ev):.3f};compress={ratio:.2f}x")


if __name__ == "__main__":
    main()
