"""Paper Figure 5 / §3.5: task-centric vs data-centric work decomposition.

Derived (structural) result: with ragged global-threshold pruning, the
data-centric schedule (one output tile per grid slot, slot latency = its
group count) is bottlenecked by the heaviest row block; the task-centric
flattened work list makes every slot equal. We report the modeled pipeline
imbalance factor = max_work / mean_work, and the work-item count.
"""
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.bsr import build_work_list, pack_dense
from repro.core.pruning import PruneConfig, group_mask
from repro.core.quant import QuantConfig
from repro.core.saliency import group_saliency

N, K, G, BN, BM = 1024, 1024, 16, 8, 8


def main():
    rng = np.random.default_rng(0)
    # heavy-tailed saliency => very ragged rows (the straggler regime)
    w = jnp.asarray(rng.standard_t(df=2, size=(N, K)).astype(np.float32))
    for sparsity in (0.5, 0.7):
        gm = group_mask(group_saliency(jnp.square(w), G),
                        PruneConfig(sparsity=sparsity, group_size=G,
                                    row_balanced=False))
        bsr = pack_dense(w, gm, QuantConfig(group_size=G))
        idx = np.asarray(bsr.idx)
        npad = (-idx.shape[0]) % BN
        mpad = (-idx.shape[1]) % BM
        idx = np.pad(idx, ((0, npad), (0, mpad)), constant_values=-1)
        # data-centric: one slot per row block; slot latency ~= the max
        # group count among its rows. Imbalance = max/mean slot latency —
        # the pipeline-bubble factor of a tile-per-slot schedule.
        counts = (idx >= 0).sum(axis=1).reshape(-1, BN).max(axis=1)
        imbalance = counts.max() / max(counts.mean(), 1e-9)
        per_block = counts
        wl = build_work_list(jnp.asarray(idx), BN, BM)
        emit(f"fig5/data_centric_s{int(sparsity*100)}", 0,
             f"imbalance={imbalance:.2f};slots={per_block.size}")
        emit(f"fig5/task_centric_s{int(sparsity*100)}", 0,
             f"imbalance=1.00;slots={wl.n_items}")


if __name__ == "__main__":
    main()
