"""Shared benchmark substrate: one small LM trained once on the synthetic
corpus (cached on disk), held-out perplexity, timing helpers, CSV output.

Quality numbers are IN-KIND reproductions of the paper's tables: the paper
measures WikiText2 PPL on pretrained LLaMA; offline we measure held-out PPL
of a from-scratch tiny LM on the deterministic synthetic corpus. Relative
orderings (FP < W4 < GQSA-W4S50 < W2, 2:4 vs GQSA, stage ablations) are the
reproduced claims.
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data.pipeline import SyntheticLM
from repro.launch.steps import build_train_step, make_dist
from repro.models.registry import get_model, lm_loss
from repro.optim import adamw
from repro.optim.schedule import warmup_cosine

BENCH_DIR = Path(__file__).resolve().parent.parent / "experiments" / "bench"

BENCH_CFG = ModelConfig(
    name="bench-tiny-llama", family="dense",
    n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=352, vocab=256,
    dtype="float32", attn_block_q=64, attn_block_k=64, remat=False)

SEQ = 64
BATCH = 16
TRAIN_STEPS = 1500


def trained_tiny_model(steps: int = TRAIN_STEPS):
    """Train (or load cached) the benchmark LM. Returns (cfg, params)."""
    cfg = BENCH_CFG
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    ckpt = CheckpointManager(str(BENCH_DIR / "model"), async_save=False)
    if ckpt.latest_step() == steps:
        return cfg, ckpt.restore(params, steps)
    step = jax.jit(build_train_step(
        cfg, make_dist(cfg, None), adamw.AdamWConfig(lr=6e-3),
        lr_fn=warmup_cosine(6e-3, 50, steps)))
    opt = adamw.init_state(params)
    data = SyntheticLM(cfg.vocab, SEQ, BATCH, seed=0)
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.host_batch(i).items()}
        params, opt, m = step(params, opt, batch)
    print(f"# trained bench model: final loss {float(m['loss']):.4f}")
    ckpt.save(steps, params)
    return cfg, params


def held_out_batches(cfg, n=8, seed=10_000):
    data = SyntheticLM(cfg.vocab, SEQ, BATCH, seed=seed)
    return [{k: jnp.asarray(v) for k, v in data.host_batch(i).items()}
            for i in range(n)]


def calib_batches(cfg, n=4, seed=777):
    data = SyntheticLM(cfg.vocab, SEQ, BATCH, seed=seed)
    return [{k: jnp.asarray(v) for k, v in data.host_batch(i).items()}
            for i in range(n)]


def eval_ppl(params, cfg, batches) -> float:
    api = get_model(cfg)

    @jax.jit
    def nll(p, batch):
        logits, _ = api.forward(p, batch, cfg)
        return lm_loss(logits, batch["labels"])

    losses = [float(nll(params, b)) for b in batches]
    return float(np.exp(np.mean(losses)))


def time_call(fn, *args, warmup=2, iters=5) -> float:
    """Median wall-clock microseconds per call (blocks on outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
