"""Shared benchmark substrate: one small LM trained once on the synthetic
corpus (cached on disk), held-out perplexity, timing helpers, CSV output.

Quality numbers are IN-KIND reproductions of the paper's tables: the paper
measures WikiText2 PPL on pretrained LLaMA; offline we measure held-out PPL
of a from-scratch tiny LM on the deterministic synthetic corpus. Relative
orderings (FP < W4 < GQSA-W4S50 < W2, 2:4 vs GQSA, stage ablations) are the
reproduced claims.
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data.pipeline import SyntheticLM
from repro.launch.steps import build_train_step, make_dist
from repro.models.registry import get_model, lm_loss
from repro.optim import adamw
from repro.optim.schedule import warmup_cosine

BENCH_DIR = Path(__file__).resolve().parent.parent / "experiments" / "bench"

BENCH_CFG = ModelConfig(
    name="bench-tiny-llama", family="dense",
    n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=352, vocab=256,
    dtype="float32", attn_block_q=64, attn_block_k=64, remat=False)

# serving-regime benchmark LM for speculative decoding: small enough
# that a decode step is dispatch/op-bound rather than FLOP-bound (the
# regime the engine targets — at real sizes decode is DMA-bound on TPU,
# which tiny CPU models emulate via per-op overhead, not GEMM time), and
# deep enough that a depth-pruned draft profile (first layer only) is a
# genuinely cheaper model. Trained with a LayerSkip-style dual-exit
# loss so the shallow exit of the SAME checkpoint drafts accurately.
SPEC_BENCH_CFG = ModelConfig(
    name="bench-spec-llama", family="dense",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=176, vocab=256,
    dtype="float32", attn_block_q=64, attn_block_k=64, remat=False)
SPEC_EXIT_LAYER = 1            # the draft profile's depth (w4l12 on 8 layers)
SPEC_EXIT_WEIGHT = 0.5

SEQ = 64
BATCH = 16
TRAIN_STEPS = 1500


def trained_tiny_model(steps: int = TRAIN_STEPS, cfg: ModelConfig = BENCH_CFG,
                       cache: str = "model"):
    """Train (or load cached) a benchmark LM. Returns (cfg, params)."""
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    ckpt = CheckpointManager(str(BENCH_DIR / cache), async_save=False)
    if ckpt.latest_step() == steps:
        return cfg, ckpt.restore(params, steps)
    step = jax.jit(build_train_step(
        cfg, make_dist(cfg, None), adamw.AdamWConfig(lr=6e-3),
        lr_fn=warmup_cosine(6e-3, 50, steps)))
    opt = adamw.init_state(params)
    data = SyntheticLM(cfg.vocab, SEQ, BATCH, seed=0)
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.host_batch(i).items()}
        params, opt, m = step(params, opt, batch)
    print(f"# trained bench model: final loss {float(m['loss']):.4f}")
    ckpt.save(steps, params)
    return cfg, params


def trained_spec_model(steps: int = TRAIN_STEPS):
    """Train (or load cached) the speculative-decoding benchmark LM.

    Same corpus as :func:`trained_tiny_model`, but the loss is dual-exit
    (LayerSkip-style): CE at the final layer + SPEC_EXIT_WEIGHT * CE at
    SPEC_EXIT_LAYER through the SHARED final norm + unembedding. One
    checkpoint then yields both the serving target (all layers, GQSA
    W4S50) and an accurate shallow drafter (first SPEC_EXIT_LAYER
    layers — draft profile w4l12 on the 8-layer config) — depth pruning
    as the draft's structured sparsity. Returns (cfg, params).
    """
    import repro.models.transformer as T
    from repro.models.registry import lm_loss

    cfg = SPEC_BENCH_CFG
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    ckpt = CheckpointManager(str(BENCH_DIR / "spec_model"), async_save=False)
    if ckpt.latest_step() == steps:
        return cfg, ckpt.restore(params, steps)

    def loss_fn(p, batch):
        h = T.embed_tokens(p, batch["tokens"], cfg)
        b, s, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

        def body(hh, lp):
            hh, _ = T._block(lp, hh, positions, cfg, None, False)
            return hh, hh

        h_final, h_all = jax.lax.scan(body, h, p["layers"])
        h_exit = h_all[SPEC_EXIT_LAYER - 1]
        loss = lm_loss(T.unembed(p, h_final, cfg), batch["labels"])
        loss_e = lm_loss(T.unembed(p, h_exit, cfg), batch["labels"])
        return loss + SPEC_EXIT_WEIGHT * loss_e, (loss, loss_e)

    lr_fn = warmup_cosine(6e-3, 50, steps)
    ocfg = adamw.AdamWConfig(lr=6e-3)

    @jax.jit
    def step(p, opt, batch):
        (_, (loss, loss_e)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(p, batch)
        p, opt, _ = adamw.apply_updates(p, grads, opt, ocfg,
                                        lr_fn(opt["step"]))
        return p, opt, loss, loss_e

    opt = adamw.init_state(params)
    data = SyntheticLM(cfg.vocab, SEQ, BATCH, seed=0)
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.host_batch(i).items()}
        params, opt, loss, loss_e = step(params, opt, batch)
    print(f"# trained spec bench model: final loss {float(loss):.4f}, "
          f"exit-layer-{SPEC_EXIT_LAYER} loss {float(loss_e):.4f}")
    ckpt.save(steps, params)
    return cfg, params


def held_out_batches(cfg, n=8, seed=10_000):
    data = SyntheticLM(cfg.vocab, SEQ, BATCH, seed=seed)
    return [{k: jnp.asarray(v) for k, v in data.host_batch(i).items()}
            for i in range(n)]


def calib_batches(cfg, n=4, seed=777):
    data = SyntheticLM(cfg.vocab, SEQ, BATCH, seed=seed)
    return [{k: jnp.asarray(v) for k, v in data.host_batch(i).items()}
            for i in range(n)]


def eval_ppl(params, cfg, batches) -> float:
    api = get_model(cfg)

    @jax.jit
    def nll(p, batch):
        logits, _ = api.forward(p, batch, cfg)
        return lm_loss(logits, batch["labels"])

    losses = [float(nll(params, b)) for b in batches]
    return float(np.exp(np.mean(losses)))


def time_call(fn, *args, warmup=2, iters=5) -> float:
    """Median wall-clock microseconds per call (blocks on outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


_EMITTED: dict = {}

# record-shape tag carried INSIDE every record (with its own name), so a
# record pulled out of the merged snapshot — by the check_bench gate, a
# plotting notebook, a grep — is self-describing without its dict key or
# this file. Bump on incompatible record-shape changes.
BENCH_SCHEMA = "repro-bench-record/v1"


def emit(name: str, us_per_call: float, derived: str, timed: bool = True,
         **metrics):
    """CSV line to stdout + an in-memory record for :func:`write_bench_json`.

    ``metrics`` are machine-readable extras (tok_per_s, ttft_ms_p50,
    acceptance_rate, ...) so the perf trajectory is comparable across PRs
    without parsing the human-oriented ``derived`` string.

    ``timed=False`` marks a record whose payload is the derived metrics,
    not a wall-clock measurement (traffic models, byte ratios): the JSON
    record carries ``"timed": false`` INSTEAD of a ``us_per_call`` key,
    so trend tooling never mistakes the 0.0 placeholder for a real
    latency regression to compare against. The CSV stdout line keeps its
    three-column shape either way.
    """
    print(f"{name},{us_per_call:.1f},{derived}")
    rec = {"name": name, "schema": BENCH_SCHEMA}
    if timed:
        rec["us_per_call"] = round(float(us_per_call), 1)
    else:
        rec["timed"] = False
    rec["derived"] = derived
    rec.update({k: (round(float(v), 4) if isinstance(v, float) else v)
                for k, v in metrics.items()})
    _EMITTED[name] = rec


def write_bench_json(filename: str = "BENCH_serve.json") -> Path:
    """Write every emitted record to ``<repo root>/<filename>`` (merging
    with an existing file, so serve benchmarks that run separately build
    up one tracked snapshot). Legacy merged records are normalized to
    the self-describing shape (``name`` + ``schema`` inside the record)
    on the way through."""
    import json
    path = Path(__file__).resolve().parent.parent / filename
    merged = {}
    if path.exists():
        try:
            merged = json.loads(path.read_text())
        except (ValueError, OSError):
            merged = {}
    merged.update(_EMITTED)
    for name, rec in merged.items():
        rec.setdefault("name", name)
        rec.setdefault("schema", BENCH_SCHEMA)
    path.write_text(json.dumps(merged, indent=2, sort_keys=True) + "\n")
    print(f"# wrote {len(_EMITTED)} benchmark records -> {path}")
    return path
